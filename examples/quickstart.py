"""Quickstart: the paper's pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Generate a synthetic portfolio (YET / ELTs / financial terms).
2. Run Aggregate Risk Analysis under a 2-tenant sequential-staging plan.
3. Report PML/TVaR risk metrics.
4. Ask the deployment planner what the paper-scale optimum would be.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.risk_app import RiskAppConfig
from repro.core import perfmodel as pm
from repro.core.planner import plan
from repro.core.tenancy import TenancyConfig
from repro.risk import metrics
from repro.risk.analysis import AggregateRiskAnalysis
from repro.risk.tables import generate


def main():
    # 1. a small portfolio (paper-scale: 1M trials x 1000 events, 4 GB)
    cfg = dataclasses.replace(RiskAppConfig().reduced(),
                              num_trials=512, events_per_trial=64)
    tables = generate(cfg, seed=0)
    print(f"YET {tables.yet.shape}, ELTs {tables.elt_losses.shape}, "
          f"{tables.nbytes()['yet'] / 1e6:.2f} MB")

    # 2. multi-tenant analysis: 2 virtual devices on 1 physical device
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(
        n_pdev=1, tenants_per_pdev=2, transfer_mode="sequential"))
    report = ara.run_tenant_chunked(tables)
    print(f"analysed {cfg.num_trials} trials in {report.wall_s * 1e3:.1f} ms "
          f"({len(report.per_tenant_s)} tenants)")

    # 3. risk metrics from the Year Loss Table
    for name, value in metrics.summary(jnp.asarray(report.ylt)).items():
        print(f"  {name:>8}: {float(value):>14,.0f}")

    # 4. what should production look like? (paper Figs 17-22)
    m = pm.PerfModelInputs(net=pm.FDR)
    for objective in ("time", "energy", "edp"):
        d = plan(m, objective)
        print(f"paper-scale {objective:>6}-optimal deployment: "
              f"{d.n_pdev} pdev x {d.tenants_per_pdev} tenants "
              f"-> {d.exec_time_s:.2f} s, {d.energy_ws:.0f} Ws")


if __name__ == "__main__":
    main()
