"""Real-time risk pricing scenario (paper §IV): a burst of what-if requests,
each re-running the analysis with perturbed financial terms, served under the
multi-tenant plan the planner picked.

    PYTHONPATH=src python examples/risk_realtime.py [--requests 8]
"""
import argparse
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.configs.risk_app import RiskAppConfig
from repro.core import perfmodel as pm
from repro.core.planner import plan
from repro.core.tenancy import TenancyConfig
from repro.distributed.fault import StragglerDetector
from repro.risk import metrics
from repro.risk.analysis import AggregateRiskAnalysis
from repro.risk.tables import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(RiskAppConfig().reduced(),
                              num_trials=1024, events_per_trial=64)
    tables = generate(cfg, seed=0)

    # the planner picks the tenancy for the real-time burst
    d = plan(pm.PerfModelInputs(net=pm.FDR), "time")
    tenants = min(d.tenants_per_pdev, 4)
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, tenants))
    detector = StragglerDetector()
    print(f"planner: {d.n_pdev} pdev x {d.tenants_per_pdev} tenants "
          f"(running {tenants} tenants on this 1-device host)")

    rng = np.random.default_rng(0)
    lat = []
    for i in range(args.requests):
        # client varies the layer terms (online pricing: what-if reinsurance)
        t = dataclasses.replace(tables,
                                agg_ret=float(tables.agg_ret *
                                              rng.uniform(0.5, 1.5)),
                                agg_lim=float(tables.agg_lim *
                                              rng.uniform(0.8, 1.2)))
        t0 = time.perf_counter()
        rep = ara.run_tenant_chunked(
            t, straggler_hist=detector.staging_priority() or None)
        dt = time.perf_counter() - t0
        lat.append(dt)
        detector.update(rep.per_tenant_s)
        pml250 = float(metrics.pml(jnp.asarray(rep.ylt), (250,))[250])
        print(f"req {i}: AggR={t.agg_ret:,.0f} -> PML250={pml250:,.0f} "
              f"({dt * 1e3:.0f} ms)")
    print(f"\np50 latency {np.percentile(lat, 50) * 1e3:.0f} ms, "
          f"p95 {np.percentile(lat, 95) * 1e3:.0f} ms "
          f"(first request includes jit compile)")


if __name__ == "__main__":
    main()
