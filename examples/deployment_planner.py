"""Deployment planner walk-through (paper §V-F, Figs 17-22).

    PYTHONPATH=src python examples/deployment_planner.py

Prints the execution-time / energy / EDP surfaces over (#pdev x tenants) for
QDR and FDR InfiniBand with the paper's Table II constants, marks the paper's
reported optima, then re-targets the model to the TPU-v5e staging profile.
"""
from repro.core import energymodel as em
from repro.core import perfmodel as pm
from repro.core.planner import full_surface, plan


def surface_text(m, pw, max_p=12, max_t=6):
    surf = full_surface(m, pw, max_pdev=max_p, max_tenants=max_t)
    best = plan(m, "time")
    lines = ["tenants:" + "".join(f"{v:>9}" for v in range(1, max_t + 1))]
    for p in range(1, max_p + 1):
        row = [f"p={p:<3}"]
        for v in range(1, max_t + 1):
            d = surf.get((p, v))
            if d is None:
                row.append("      oom")
            else:
                mark = "*" if (p, v) == (best.n_pdev,
                                         best.tenants_per_pdev) else " "
                row.append(f"{d.exec_time_s:>8.2f}{mark}")
        lines.append("".join(row))
    return "\n".join(lines)


def main():
    for net, paper_opt in ((pm.QDR, "7x2"), (pm.FDR, "9x2")):
        m = pm.PerfModelInputs(net=net)
        print(f"=== {net.name} — execution time [s] "
              f"(paper optimum {paper_opt}) ===")
        print(surface_text(m, em.K20))
        t = plan(m, "time")
        e = plan(m, "energy")
        x = plan(m, "edp")
        print(f"time-opt  {t.n_pdev}x{t.tenants_per_pdev} = "
              f"{t.exec_time_s:.3f}s   energy-opt {e.n_pdev}x"
              f"{e.tenants_per_pdev} = {e.energy_ws:.0f}Ws   "
              f"edp-opt {x.n_pdev}x{x.tenants_per_pdev}\n")

    print("=== TPU v5e staging profile (beyond-paper target) ===")
    m = pm.PerfModelInputs(net=pm.V5E, compute_time_1pdev=0.35)
    t = plan(m, "time", max_pdev=16)
    print(f"v5e: time-opt {t.n_pdev} chips x {t.tenants_per_pdev} tenants "
          f"-> {t.exec_time_s * 1e3:.0f} ms "
          f"(risk analysis becomes real-time at pod scale)")


if __name__ == "__main__":
    main()
