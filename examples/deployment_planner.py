"""Deployment planner walk-through (paper §V-F, Figs 17-22).

    PYTHONPATH=src python examples/deployment_planner.py

Prints the execution-time / energy / EDP surfaces over (#pdev x tenants) for
QDR and FDR InfiniBand with the paper's Table II constants, marks the paper's
reported optima, then re-targets the model to the TPU-v5e staging profile.

Closes with the telemetry-driven path: a few deployments are replayed onto
a telemetry plane as spans (the stand-in for a profiled production run),
`plan_from_telemetry` fits `PerfModelInputs`/`PowerParams` back out of the
spans by least squares and re-plans — recovering the same optimum the
static Table II constants give, which is the falsifiable check that the
observability layer carries enough signal to drive capacity planning.
"""
from repro.core import energymodel as em
from repro.core import perfmodel as pm
from repro.core.planner import full_surface, plan, plan_from_telemetry
from repro.core.simulator import SimInputs
from repro.core.tenancy import TenancyConfig
from repro.obs.fit import replay_sim_run
from repro.obs.telemetry import Telemetry


def surface_text(m, pw, max_p=12, max_t=6):
    surf = full_surface(m, pw, max_pdev=max_p, max_tenants=max_t)
    best = plan(m, "time")
    lines = ["tenants:" + "".join(f"{v:>9}" for v in range(1, max_t + 1))]
    for p in range(1, max_p + 1):
        row = [f"p={p:<3}"]
        for v in range(1, max_t + 1):
            d = surf.get((p, v))
            if d is None:
                row.append("      oom")
            else:
                mark = "*" if (p, v) == (best.n_pdev,
                                         best.tenants_per_pdev) else " "
                row.append(f"{d.exec_time_s:>8.2f}{mark}")
        lines.append("".join(row))
    return "\n".join(lines)


def main():
    for net, paper_opt in ((pm.QDR, "7x2"), (pm.FDR, "9x2")):
        m = pm.PerfModelInputs(net=net)
        print(f"=== {net.name} — execution time [s] "
              f"(paper optimum {paper_opt}) ===")
        print(surface_text(m, em.K20))
        t = plan(m, "time")
        e = plan(m, "energy")
        x = plan(m, "edp")
        print(f"time-opt  {t.n_pdev}x{t.tenants_per_pdev} = "
              f"{t.exec_time_s:.3f}s   energy-opt {e.n_pdev}x"
              f"{e.tenants_per_pdev} = {e.energy_ws:.0f}Ws   "
              f"edp-opt {x.n_pdev}x{x.tenants_per_pdev}\n")

    print("=== TPU v5e staging profile (beyond-paper target) ===")
    m = pm.PerfModelInputs(net=pm.V5E, compute_time_1pdev=0.35)
    t = plan(m, "time", max_pdev=16)
    print(f"v5e: time-opt {t.n_pdev} chips x {t.tenants_per_pdev} tenants "
          f"-> {t.exec_time_s * 1e3:.0f} ms "
          f"(risk analysis becomes real-time at pod scale)")

    telemetry_replan_demo()


def telemetry_replan_demo():
    """Fit the model back out of span telemetry and re-plan (obs/fit.py)."""
    print("\n=== plan from telemetry (FDR, fitted from replayed spans) ===")
    m = pm.PerfModelInputs(net=pm.FDR)
    tel = Telemetry(enabled=True)
    # replay a small deployment sweep onto the plane — the stand-in for a
    # profiled production run (live serving spans work the same way)
    for nv in (1, 2, 4, 8, 16):
        si = SimInputs(TenancyConfig(1, nv, "sequential"), net=m.net,
                       compute_time_1pdev=m.compute_time_1pdev,
                       yet_mb=m.yet_mb, elt_mb=m.elt_mb, pf_mb=m.pf_mb,
                       power=em.K20)
        replay_sim_run(tel, si, pw=em.K20)
    tp = plan_from_telemetry(tel)
    st = plan(m, "time")
    d = tp.deployment
    print(f"fitted:  t_4gb={tp.m.net.t_4gb:.4f}s "
          f"overhead={tp.m.net.per_vdev_overhead:.5f}s "
          f"c1={tp.m.compute_time_1pdev:.3f}s "
          f"p_busy={tp.pw.p_busy:.1f}W p_idle={tp.pw.p_idle_assigned:.1f}W")
    print(f"         residuals: transfer_rms={tp.transfer_rms_s:.2e}s "
          f"compute_rms={tp.compute_rms_s:.2e}s")
    print(f"plan:    telemetry -> {d.n_pdev}x{d.tenants_per_pdev} "
          f"({tp.transfer_mode}, {d.exec_time_s:.3f}s)   "
          f"static Table II -> {st.n_pdev}x{st.tenants_per_pdev} "
          f"({st.exec_time_s:.3f}s)")
    agree = (d.n_pdev, d.tenants_per_pdev) == (st.n_pdev,
                                               st.tenants_per_pdev)
    print(f"         optima {'agree' if agree else 'DISAGREE'}")


if __name__ == "__main__":
    main()
