"""Multi-tenant serving: three applications share one accelerator.

    PYTHONPATH=src python examples/serve_multitenant.py

The scheduler round-robins tenant slots on the engine's dispatch/await
halves: tenant k+1's batch assembly and staging are enqueued while tenant
k's on-device ``lax.scan`` decode loop is still running — the paper's
transfer-under-compute multi-tenancy applied to inference serving.  Prints
per-tenant utilisation (cf. paper Fig 14) and the realised overlap pairs,
then replays the same workload under continuous batching for comparison.

Continuous vs slot-based serving
--------------------------------
The *slot-based* schedules (``mode="overlapped"`` / ``"blocking"``) serve
one tenant batch at a time: every row in the batch is padded to the longest
prompt and decoded for the batch-max ``max_new_tokens``, and the device
drains completely between batches.  With ragged request mixes that padding
is pure waste — a 4-token dashboard query rides along for a 16-token
report's full decode.

``mode="continuous"`` instead keeps a fixed-capacity slot table resident on
the device (``repro.serving.continuous.ContinuousBatchingEngine``).  Each
outer step admits queued requests into free slots (prefill + scatter into a
paged KV-cache, ``repro.serving.kvcache.PagedKVCache``), runs one masked
fixed-step decode micro-round over *all* slots, and retires rows that hit
their budget, returning their cache pages to a free list.  Requests from
different tenants, with different prompt lengths and token budgets, decode
side by side; a finished row's lane is refilled within a round or two
instead of padding out the batch.  The decode step is shape-stable (paged
gather/scatter, fixed capacity), so the ragged mix costs one compile total
— and greedy decoding stays token-exact with the blocking engine on the
same padded prompt.  The trade-offs: per-request (not per-batch) prefill,
and lanes are masked rather than compacted, so very low occupancy wastes
compute on dead rows.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import timeline_overlaps
from repro.core.tenancy import TenancyConfig
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request

WORKLOADS = {"pricing-desk": (12, 24, 8),     # requests, prompt, new
             "batch-report": (6, 48, 16),
             "dashboard": (18, 12, 4)}


def submit_all(sched, cfg, seed=7):
    rng = np.random.default_rng(seed)
    for tenant, (n, plen, new) in WORKLOADS.items():
        for _ in range(n):
            sched.submit(Request(tenant,
                                 rng.integers(1, cfg.vocab_size,
                                              plen).astype(np.int32),
                                 max_new_tokens=new))


def report(sched, responses, label):
    print(f"\n=== {label}: served {len(responses)} requests across "
          f"{len(WORKLOADS)} tenants ===")
    print(f"{'tenant':>14} {'reqs':>5} {'tokens':>7} {'busy ms':>8} "
          f"{'share':>6}")
    for t, rep in sorted(sched.utilization_report().items()):
        print(f"{t:>14} {rep['requests']:>5.0f} {rep['tokens']:>7.0f} "
              f"{rep['busy_s'] * 1e3:>8.0f} {rep['busy_share'] * 100:>5.1f}%")
    lat = np.asarray([r.latency_s for r in responses])
    print(f"latency p50 {np.percentile(lat, 50) * 1e3:.0f} ms, "
          f"p99 {np.percentile(lat, 99) * 1e3:.0f} ms")
    ov = timeline_overlaps(sched.timeline)
    print(f"overlap pairs (staging k+1 inside decode k): {sum(ov)}/{len(ov)}")


def main():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params, temperature=0.8)

    # slot-based: tenant batches staged under the running decode
    sched = MultiTenantScheduler(engine, max_batch=4,
                                 tenancy=TenancyConfig(1, 3))
    submit_all(sched, cfg)
    report(sched, sched.drain(), "slot-based (overlapped)")

    # continuous batching: paged KV-cache + persistent slot table
    sched = MultiTenantScheduler(
        engine, tenancy=TenancyConfig(1, 3), mode="continuous",
        continuous=dict(capacity=6, page_size=16, inner_steps=4,
                        max_prompt_len=64))
    submit_all(sched, cfg)
    report(sched, sched.drain(), "continuous (paged KV-cache)")
    eng = sched.continuous_engine
    print(f"micro-rounds={eng.rounds} x {eng.inner_steps} steps, "
          f"slot occupancy={eng.occupancy()*100:.1f}%, "
          f"pages reused={eng.kv.pages_reused}/{eng.kv.pages_allocated}")


if __name__ == "__main__":
    main()
