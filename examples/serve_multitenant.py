"""Multi-tenant serving: three applications share one accelerator.

    PYTHONPATH=src python examples/serve_multitenant.py

The scheduler round-robins tenant slots on the engine's dispatch/await
halves: tenant k+1's batch assembly and staging are enqueued while tenant
k's on-device ``lax.scan`` decode loop is still running — the paper's
transfer-under-compute multi-tenancy applied to inference serving.  Prints
per-tenant utilisation (cf. paper Fig 14) and the realised overlap pairs.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.tenancy import TenancyConfig
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request


def main():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params, temperature=0.8)
    sched = MultiTenantScheduler(engine, max_batch=4,
                                 tenancy=TenancyConfig(1, 3))

    rng = np.random.default_rng(7)
    workloads = {"pricing-desk": (12, 24, 8),     # requests, prompt, new
                 "batch-report": (6, 48, 16),
                 "dashboard": (18, 12, 4)}
    for tenant, (n, plen, new) in workloads.items():
        for _ in range(n):
            sched.submit(Request(tenant,
                                 rng.integers(1, cfg.vocab_size,
                                              plen).astype(np.int32),
                                 max_new_tokens=new))

    responses = sched.drain()
    print(f"served {len(responses)} requests across "
          f"{len(workloads)} tenants\n")
    print(f"{'tenant':>14} {'reqs':>5} {'tokens':>7} {'busy ms':>8} "
          f"{'share':>6}")
    for t, rep in sorted(sched.utilization_report().items()):
        print(f"{t:>14} {rep['requests']:>5.0f} {rep['tokens']:>7.0f} "
              f"{rep['busy_s'] * 1e3:>8.0f} {rep['busy_share'] * 100:>5.1f}%")
    lat = np.asarray([r.latency_s for r in responses])
    print(f"\nlatency p50 {np.percentile(lat, 50) * 1e3:.0f} ms, "
          f"p99 {np.percentile(lat, 99) * 1e3:.0f} ms")
    from repro.core.pipeline import timeline_overlaps
    ov = timeline_overlaps(sched.timeline)
    print(f"overlap pairs (staging k+1 inside decode k): {sum(ov)}/{len(ov)}")


if __name__ == "__main__":
    main()
