"""Multi-tenant serving: three applications share one accelerator.

    PYTHONPATH=src python examples/serve_multitenant.py

The scheduler round-robins tenant slots on the engine's dispatch/await
halves: tenant k+1's batch assembly and staging are enqueued while tenant
k's on-device ``lax.scan`` decode loop is still running — the paper's
transfer-under-compute multi-tenancy applied to inference serving.  Prints
per-tenant utilisation (cf. paper Fig 14) and the realised overlap pairs,
then replays the same workload under continuous batching for comparison.

Continuous vs slot-based serving
--------------------------------
The *slot-based* schedules (``mode="overlapped"`` / ``"blocking"``) serve
one tenant batch at a time: every row in the batch is padded to the longest
prompt and decoded for the batch-max ``max_new_tokens``, and the device
drains completely between batches.  With ragged request mixes that padding
is pure waste — a 4-token dashboard query rides along for a 16-token
report's full decode.

``mode="continuous"`` instead keeps a fixed-capacity slot table resident on
the device (``repro.serving.continuous.ContinuousBatchingEngine``).  Each
outer step admits queued requests into free slots (prefill + scatter into a
paged KV-cache, ``repro.serving.kvcache.PagedKVCache``), runs one masked
fixed-step decode micro-round over *all* slots, and retires rows that hit
their budget, returning their cache pages to a free list.  Requests from
different tenants, with different prompt lengths and token budgets, decode
side by side; a finished row's lane is refilled within a round or two
instead of padding out the batch.  The decode step is shape-stable (paged
gather/scatter, fixed capacity), so the ragged mix costs one compile total
— and greedy decoding stays token-exact with the blocking engine on the
same padded prompt.  Admissions picked in one scheduling step are batched:
same-bucket prompts share a single prefill call.  The trade-off: lanes are
masked rather than compacted, so very low occupancy wastes compute on dead
rows.

One pool, many state kinds
--------------------------
The pool behind the slot table is a *paged-state pool*, not just an
attention KV-cache: every arch config registers the state kinds its slots
carry (``repro.serving.kvcache.state_kinds``) and ``mode="continuous"``
serves all of them.  Attention KV pages behave exactly as above;
encoder-decoder archs (whisper) add per-request read-only cross-attention
pages, written once at admission and gathered each decode step; SSM and
hybrid archs (mamba2, jamba) keep their recurrent slot state resident in
the slot table and checkpoint it as fixed-width host records on
swap-out, scattering it back bitwise on restore.  Preemption victims are
chosen regardless of kind — an SSM row swaps out and resumes
token-exactly just like an attention row — and the page/record ledger is
audited per kind at drain.  ``ContinuousBatchingEngine.supported_modes(
cfg)`` (or ``python -m repro.launch.serve --list-archs``) reports each
arch's state kinds, preemptability and exactness class without building
the model; per-request non-token inputs (vision patch embeds, encoder
frames) ride on ``Request.extra_inputs``.

Prefix sharing (refcounts + copy-on-write)
------------------------------------------
Real tenant traffic repeats itself: every pricing-desk query carries the
same system prompt, dashboards re-issue identical requests.  With
``prefix_sharing=True`` (the default) the paged pool is *content-shared*:
each page-aligned block of the padded prompt is keyed by the bytes of the
whole prompt up to its end, admission maps already-registered blocks onto
the existing pages (refcount++) instead of allocating and re-prefilling
them, and the first decode write into a shared page forks it (copy page,
remap the writer's table slot) so neighbours never see the divergence.  A
request whose entire padded prompt is registered skips its prefill call
outright, reusing the cached first-token logits.  Greedy decode stays
bit-identical to the unshared path — blocks are shared only when their
full token prefix is byte-equal, which makes the page contents bitwise
interchangeable.  Sliding-window archs participate too: their chain keys
are salted with the window phase (ring length + block offset), so pages
whose contents depend on which tokens the window has wrapped past only
match when the whole wrapped prefix matches — a byte-identical refresh
admitted while the original is in flight shares its ring pages and skips
prefill (the original's ring writes then CoW-fork), while
shared-system-prompt mixes with distinct suffixes correctly never share.
The final section replays a shared-system-prompt workload with sharing
off and on and prints the pages/prefill saved.

Paged-attention backends (jnp gather vs fused Pallas)
-----------------------------------------------------
Within a decode micro-round the paged pool can be read two ways
(``backend=`` on the continuous engine, ``--kernel-backend`` on the launch
driver):

* ``"jnp"`` (default) gathers each row's full logical window into a dense
  ``[capacity, bucket, Hkv, D]`` tensor per decode step.  Simple, bitwise
  the PR-3 math — but it moves O(bucket) pool bytes per emitted token even
  when most lanes are short or masked: the exact redundant-transfer tax
  the paper's sequential staging removes for the risk pipeline.
* ``"pallas"`` streams page-sized KV blocks in place through the fused
  paged-attention kernel (``repro.kernels.paged_attention``): the page
  table is a scalar-prefetch operand, so each grid cell's index map routes
  straight to its physical page and only referenced pages are ever read;
  online softmax accumulates across pages, and admission KV scatters
  page-granularly into its allocated pages.  Bytes per round drop to
  O(live tokens), and greedy decode stays token-exact with the jnp path
  (locked in by ``tests/test_paged_attention.py``).

When does which win?  On a real TPU the pallas backend is the one that
scales: the gather path's dense materialisation is pure HBM traffic the
fused kernel never issues, and its advantage grows with bucket length and
lane raggedness.  On CPU, Pallas runs in *interpret mode* — every grid
cell is emulated — so its wall time there is an artefact (often slower
than jnp); use jnp for CPU throughput, pallas to validate kernel semantics
and to track the bytes-moved structure (``bench_paged_attention`` carries
both columns).  The backends section decodes one workload on both and
checks the tokens agree.

Overload: priorities, preemption, shedding
------------------------------------------
The last section oversubscribes a deliberately tiny engine (2 slots) with
long tier-1 report jobs, then lands a tier-0 dashboard query mid-flight.
With preemption on, the scheduler swaps a tier-1 victim's KV pages out to
the host store, serves the tier-0 request in the freed slot, and restores
the victim token-exactly afterwards — the victim's final tokens are
bitwise what an uninterrupted run produces.  A ``max_backlog`` bound sheds
the lowest-priority queued work with an explicit REJECTED outcome instead
of letting the queue grow past the SLO; every submitted request always
reaches exactly one terminal outcome (completed / rejected / failed).

Crash safety: journal, checkpoint, recover
------------------------------------------
The same swap machinery doubles as the crash-recovery data plane.  Arm it
by giving the scheduler a durable write-ahead journal and a checkpoint
cadence::

    sched = MultiTenantScheduler(engine, mode="continuous", ...,
                                 journal="state/journal.jsonl",
                                 checkpoint_dir="state/checkpoints",
                                 checkpoint_every=8)

Every ``submit`` is fsync'd to the journal *before* the request is queued
(so a crash between the two re-queues it on recovery — never a lost
request), every collected micro-round appends a ROUND_COMMIT with
cumulative per-request token counts, and every terminal outcome is
journalled with its tokens.  Every ``checkpoint_every`` committed rounds
the scheduler quiesces the engine (one pipeline bubble) and snapshots the
*whole* serving state to disk: each live slot as the same per-kind
``SwapRecord`` preemption takes (attention pages, cross-attention pages,
SSM slot state — whatever the arch registers), the host swap tier under
its original tickets, the queued requests, the restore queue, and the
prefix-trie chain keys.  After a crash — SIGKILL included, mid-round or
mid-preemption — a *fresh* scheduler over the same journal rebuilds
everything::

    sched = MultiTenantScheduler(engine2, mode="continuous", ...,
                                 journal="state/journal.jsonl",
                                 checkpoint_dir="state/checkpoints")
    summary = sched.recover()      # then sched.drain() as usual

Checkpointed live slots re-enter the pool through the ordinary restore
jit (same staging lanes — a checkpoint taken on a 1x8 mesh restores on
any mesh), requests submitted after the checkpoint re-queue from the
journal, and the rounds committed after the checkpoint are *replayed*.

The exactness contract: decode is deterministic under seeded sampling
(the per-slot PRNG key folds in the emitted-token index, independent of
round composition), so for every non-MoE arch the replayed rounds
regenerate **bitwise-identical tokens** — a recovered request finishes
with exactly the tokens an uninterrupted run produces, and post-
checkpoint RETIRE records in the journal double as a cross-check oracle
(``summary.replay_check``).  MoE archs recover completion-level exact,
matching their ``supported_modes()`` exactness class.  On the launch
driver the equivalent knobs are ``--journal-dir`` /
``--checkpoint-every`` / ``--recover``.

Observability
-------------
Every layer this example exercises is instrumented against the telemetry
plane in ``repro.obs`` (disabled by default — the runs here cost nothing
extra).  Enabling it *before* building the stack lights up everything:

    from repro.obs import TELEMETRY
    TELEMETRY.enable()
    ... build engine/scheduler, run a workload ...
    from repro.obs.export import write_chrome_trace, prometheus_text
    write_chrome_trace(TELEMETRY, "trace.json")   # open in ui.perfetto.dev
    print(prometheus_text(TELEMETRY))             # counters/gauges/summaries

The span tree nests ``sched.step`` > ``round.dispatch`` > ``round.jit``
with retrospective ``round.device`` windows, ``kv.*`` counters for page
alloc/share/CoW-fork/evict, ``swap.*`` spans for preemption tiering and
``transfer.stage`` spans per staging lane (see ``repro/obs/__init__.py``
for the full naming scheme).  The same spans drive capacity planning:
``repro.core.planner.plan_from_telemetry`` least-squares-fits the paper's
perf/energy model from them and re-plans (#pdev, tenancy, transfer mode)
— ``examples/deployment_planner.py`` closes with that loop.  On the
launch driver the equivalent knobs are ``--trace-out`` /
``--metrics-out`` / ``--stats-every N``.
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import timeline_overlaps
from repro.core.tenancy import TenancyConfig
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request

WORKLOADS = {"pricing-desk": (12, 24, 8),     # requests, prompt, new
             "batch-report": (6, 48, 16),
             "dashboard": (18, 12, 4)}


def submit_all(sched, cfg, seed=7):
    rng = np.random.default_rng(seed)
    for tenant, (n, plen, new) in WORKLOADS.items():
        for _ in range(n):
            sched.submit(Request(tenant,
                                 rng.integers(1, cfg.vocab_size,
                                              plen).astype(np.int32),
                                 max_new_tokens=new))


def report(sched, responses, label):
    print(f"\n=== {label}: served {len(responses)} requests across "
          f"{len(WORKLOADS)} tenants ===")
    print(f"{'tenant':>14} {'reqs':>5} {'tokens':>7} {'busy ms':>8} "
          f"{'share':>6}")
    for t, rep in sorted(sched.utilization_report().items()):
        print(f"{t:>14} {rep['requests']:>5.0f} {rep['tokens']:>7.0f} "
              f"{rep['busy_s'] * 1e3:>8.0f} {rep['busy_share'] * 100:>5.1f}%")
    lat = np.asarray([r.latency_s for r in responses])
    print(f"latency p50 {np.percentile(lat, 50) * 1e3:.0f} ms, "
          f"p99 {np.percentile(lat, 99) * 1e3:.0f} ms")
    ov = timeline_overlaps(sched.timeline)
    print(f"overlap pairs (staging k+1 inside decode k): {sum(ov)}/{len(ov)}")


def main():
    cfg = get_config("h2o-danube-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params, temperature=0.8)

    # slot-based: tenant batches staged under the running decode
    sched = MultiTenantScheduler(engine, max_batch=4,
                                 tenancy=TenancyConfig(1, 3))
    submit_all(sched, cfg)
    report(sched, sched.drain(), "slot-based (overlapped)")

    # continuous batching: paged KV-cache + persistent slot table
    sched = MultiTenantScheduler(
        engine, tenancy=TenancyConfig(1, 3), mode="continuous",
        continuous=dict(capacity=6, page_size=16, inner_steps=4,
                        max_prompt_len=64))
    submit_all(sched, cfg)
    report(sched, sched.drain(), "continuous (paged KV-cache)")
    eng = sched.continuous_engine
    print(f"micro-rounds={eng.rounds} x {eng.inner_steps} steps, "
          f"slot occupancy={eng.occupancy()*100:.1f}%, "
          f"pages reused={eng.kv.pages_reused}/{eng.kv.pages_allocated}")

    # prefix sharing: every tenant's queries repeat a 32-token system
    # prompt, and half of each tenant's requests are exact repeats
    # (dashboard refreshes) — the content-shared pool maps the common
    # blocks onto existing pages and skips repeat prefills entirely.
    # h2o-danube's sliding window would salt its chain keys with the
    # window phase, so only the byte-identical refreshes would share;
    # this section uses a full-attention arch so the shared system
    # prompt itself also maps onto common pages.
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    rng = np.random.default_rng(11)
    system_prompt = rng.integers(1, cfg.vocab_size, 32).astype(np.int32)
    originals, refreshes = [], []
    for t in range(3):
        for q in range(3):
            user = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
            prompt = np.concatenate([system_prompt, user])
            originals.append(Request(f"tenant-{t}", prompt,
                                     max_new_tokens=6))
            refreshes.append(Request(f"tenant-{t}", prompt.copy(),
                                     max_new_tokens=6))
    reqs = originals + refreshes     # refreshes arrive after their original
    print("\n=== prefix sharing: shared system prompt + repeated queries "
          "===")
    for sharing in (False, True):
        sched = MultiTenantScheduler(
            engine, tenancy=TenancyConfig(1, 3), mode="continuous",
            continuous=dict(capacity=6, page_size=16, inner_steps=4,
                            max_prompt_len=64, prefix_sharing=sharing))
        for r in reqs:
            sched.submit(r)
        sched.drain()
        eng = sched.continuous_engine
        print(f"sharing={'on ' if sharing else 'off'}: "
              f"pages allocated={eng.kv.pages_allocated:3d} "
              f"(shared mappings={eng.kv.pages_shared}, "
              f"cow forks={eng.kv.cow_forks}) "
              f"prefill calls={eng.prefill_calls} "
              f"skipped={eng.prefill_skips}")

    # paged-attention backends: the fused pallas kernels read pages in
    # place (no dense per-row KV gather) and must reproduce the jnp
    # backend's greedy tokens exactly — see the docstring section for when
    # each wins
    print("\n=== paged-attention backend: jnp gather vs fused pallas ===")
    from repro.serving.continuous import ContinuousBatchingEngine
    rng = np.random.default_rng(13)
    reqs = [Request(f"tenant-{i % 3}",
                    rng.integers(1, cfg.vocab_size,
                                 8 + 8 * (i % 2)).astype(np.int32),
                    max_new_tokens=4) for i in range(6)]
    tokens = {}
    for backend in ("jnp", "pallas"):
        eng = ContinuousBatchingEngine(engine, capacity=3, page_size=8,
                                       inner_steps=4, max_prompt_len=32,
                                       backend=backend)
        tokens[backend] = {id(r): t for r, t in eng.run_all(reqs)}
        blocks = eng.kv.max_blocks
        print(f"backend={backend:6s}: rounds={eng.rounds} "
              f"(dense window={'-' if backend == 'pallas' else f'{blocks} blocks/row/step'}; "
              f"pages streamed in place={'yes' if backend == 'pallas' else 'no'})")
    agree = all(np.array_equal(tokens["jnp"][id(r)], tokens["pallas"][id(r)])
                for r in reqs)
    print(f"tokens identical across backends: {agree}")

    # oversubscribed: 2 slots, long tier-1 reports in flight, a tier-0
    # dashboard query arriving mid-decode — preemption swaps a victim's
    # pages to the host tier, serves the query, restores token-exactly
    print("\n=== overload: priority preemption + load shedding ===")
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=16)
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng,
                                 preemption=True, max_backlog=4)
    rng = np.random.default_rng(17)
    reports = [Request(f"report-{i}",
                       rng.integers(1, cfg.vocab_size, 12).astype(np.int32),
                       max_new_tokens=40, priority=1) for i in range(2)]
    for r in reports:
        sched.submit(r)
    sched.step()                       # reports admitted, decode in flight
    dash = Request("dashboard",
                   rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                   max_new_tokens=4, priority=0)
    sched.submit(dash)                 # tier 0 against a full slot table
    backlog = [Request(f"backlog-{i}",
                       rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                       max_new_tokens=4, priority=1) for i in range(6)]
    for r in backlog:                  # 6 queued > max_backlog=4: 2 shed
        sched.submit(r)
    responses = {r.tenant: r for r in sched.drain()}
    shed = sum(int(s["shed"]) for s in sched.stats.values())
    print(f"preemptions={ceng.preemptions} restores={ceng.restores} "
          f"shed={shed}")
    for name in ("dashboard", *(r.tenant for r in reports)):
        resp = responses[name]
        print(f"  {name:>11}: {resp.outcome:9s} ttft={resp.ttft_s:.3f}s "
              f"swapped_out={resp.preemptions}x")
    n_rej = sum(r.outcome == 'rejected' for r in responses.values())
    print(f"  backlog: {sum(r.outcome == 'completed' for r in responses.values()) - 3} completed, "
          f"{n_rej} explicitly rejected (shed)")
    # the preempted report's tokens are bitwise an uninterrupted run's
    victim, = [r for r in reports if responses[r.tenant].preemptions]
    (
        _, want
    ), = ceng.run_all([Request("oracle", victim.prompt.copy(), 40)])
    exact = np.array_equal(want, responses[victim.tenant].tokens)
    print(f"  preempted row token-exact vs uninterrupted run: {exact}")


if __name__ == "__main__":
    main()
