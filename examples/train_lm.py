"""End-to-end LM training driver (~100M-param model, a few hundred steps).

    PYTHONPATH=src python examples/train_lm.py --steps 300

Uses the full production substrate on CPU: tenant microbatch accumulation,
prefetch feed (staging overlap), checkpoint/restart, straggler detection.
A ~100M-param qwen3-family config trains on the synthetic copy-structure
stream; loss should fall from ~10.4 to well under 7.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import DataConfig, PrefetchFeed
from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import null_sharder
from repro.models import params as pp
from repro.models.model import build_model
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import build_train_step, init_train_state


def hundred_m_config():
    base = get_config("qwen3-32b")
    return dataclasses.replace(
        base, name="qwen3-100m", num_layers=16, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        fsdp=False, microbatches=2, remat="none",
        param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    print(f"{cfg.name}: {pp.count_params(params):,} params")
    opt = make_optimizer(cfg)
    state = init_train_state(bundle, opt, params)
    step = jax.jit(build_train_step(
        bundle, sh, opt, lr_fn=lambda s: jnp.float32(3e-4) *
        jnp.minimum(1.0, s.astype(jnp.float32) / 50.0)), donate_argnums=(0,))

    start = ckpt.latest_step(args.ckpt_dir)
    if start:
        state = ckpt.restore(args.ckpt_dir, start, state)
        print(f"resumed from step {start}")
    start = start or 0

    dc = DataConfig(args.batch, args.seq, cfg.vocab_size)
    feed = PrefetchFeed(dc, cfg, start_step=start)
    losses, t0 = [], time.perf_counter()
    for i in range(start, args.steps):
        state, metrics = step(state, next(feed))
        losses.append(float(metrics["loss"]))
        if (i + 1) % 20 == 0:
            tps = args.batch * args.seq * 20 / (time.perf_counter() - t0)
            t0 = time.perf_counter()
            print(f"step {i + 1:4d}  loss {losses[-1]:.4f}  "
                  f"{tps / 1e3:.1f}k tok/s")
        if (i + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, i + 1, state)
    feed.close()
    assert np.isfinite(losses).all()
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
