"""Mesh-sharded serving: parity, conservation, and staging-lane contracts.

The tentpole contracts of the mesh-sharded `ContinuousBatchingEngine`:

* **1×1 bitwise identity** — an engine built on a 1×1 mesh emits the same
  tokens AND the same final logits bits as the meshless single-device path;
* **1×8 greedy token-exactness** — KV pools and the decode partition along
  KV heads over 8 devices; tokens match the single-device run through
  admission (batched + prefix-shared), free-list eviction, CoW forks, and
  preempt/restore through the per-slice staging lanes;
* **host-global accounting survives sharding** — page tables, free list,
  trie, refcounts and the two-tier conservation audit
  (``assert_conserved(host_pages=...)``) are unchanged by the mesh;
* **compile-count contracts** — one decode trace per (capacity, tier) and
  one restore trace, identical to the single-device engine;
* the fused pallas kernels run per-shard under ``shard_map`` and stay
  bitwise with their unsharded invocations.

8 host devices need XLA_FLAGS before jax initialisation, which this test
process has already done — so everything mesh-wide runs in a subprocess,
like tests/test_pipeline.py.  Head counts: ``reduced()`` clamps KV heads to
2, which a 8-way "model" axis cannot divide, so the children re-widen to
16 query / 8 KV heads.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.distributed.sharding import (DEFAULT_RULES, SERVING_RULES,
                                        parse_mesh, serving_sharder)


def _run_child(script: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    # append (not prepend): the last repetition of a flag wins, and earlier
    # suite imports may have left a device-count in XLA_FLAGS
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          timeout=600)


# ---------------------------------------------------------------------------
# in-process: mesh parsing + serving rules (no multi-device requirement)
# ---------------------------------------------------------------------------
def test_parse_mesh_specs():
    assert parse_mesh(None) is None
    assert parse_mesh("") is None
    m = parse_mesh("1x1")
    assert m.axis_names == ("data", "model") and m.shape["model"] == 1
    assert parse_mesh("1").shape == {"data": 1, "model": 1}
    with pytest.raises(ValueError):
        parse_mesh(f"1x{len(jax.devices()) + 1}")


def test_serving_rules_shard_only_heads():
    """The serving sharder must never partition a contraction axis: only
    head-like axes shard, so cross-shard merges are all-gathers (bitwise),
    never a psum whose float reassociation breaks token-exactness."""
    assert set(SERVING_RULES) == {"heads", "kv"}
    sh = serving_sharder(parse_mesh("1x1"))
    # replicated logical names fall through to None even when they exist
    # in the training rules
    for name in ("ff", "vocab", "expert", "inner", "seq", "batch"):
        assert DEFAULT_RULES[name] is not None  # guard: rule exists upstream
        assert sh._axes_for(name, 64) is None
    assert sh.extent("kv", 8) == 1              # 1x1: everything degenerate


SHARDED_ENGINE_SCRIPT = textwrap.dedent("""
    import dataclasses
    import numpy as np
    import jax

    from repro.configs import get_config
    from repro.distributed.sharding import parse_mesh, serving_sharder
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import MultiTenantScheduler, Request

    assert len(jax.devices()) == 8, jax.devices()
    # reduced() clamps to 2 KV heads; re-widen so 8 ways divide the pools
    cfg = dataclasses.replace(get_config("internlm2-1.8b").reduced(),
                              num_heads=16, num_kv_heads=8)
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))

    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    reqs = []
    for i in range(8):
        tail = rng.integers(1, cfg.vocab_size,
                            8 + 4 * (i % 3)).astype(np.int32)
        # half the mix shares a system prefix -> trie hits + CoW forks
        prompt = np.concatenate([shared, tail]) if i % 2 == 0 else tail
        reqs.append(Request(f"t{i % 3}", prompt, 6 + i, seed=i))

    def clone(rs):
        return [Request(r.tenant, r.prompt.copy(), r.max_new_tokens,
                        seed=r.seed, priority=r.priority) for r in rs]

    def build(sh, backend="jnp"):
        eng = ServingEngine(cfg, params, sh=sh, kernel_backend=backend)
        # tight pool: forces free-list eviction of registered cache pages
        return ContinuousBatchingEngine(eng, capacity=4, page_size=8,
                                        num_pages=40, inner_steps=2,
                                        max_prompt_len=32)

    def run(ceng):
        out = ceng.run_all(clone(reqs))
        host = ceng.swap_store.pages() if ceng.swap_store else None
        ceng.kv.assert_conserved(host_pages=host)
        return [t for _, t in out]

    base_eng = build(None)
    base = run(base_eng)
    assert base_eng.kv.cow_forks + base_eng.kv.pristine_forks > 0
    assert base_eng.kv.pages_reused > 0  # the tight pool really recycled

    # ---- 1x1 mesh: bitwise identity with the meshless path ----
    one = build(serving_sharder(parse_mesh("1x1")))
    toks1 = run(one)
    for a, b in zip(base, toks1):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(base_eng.state["logits"]),
                                  np.asarray(one.state["logits"]))

    # ---- 1x8 mesh: greedy token-exact, pools really sharded ----
    for backend in ("jnp", "pallas"):
        m8 = build(serving_sharder(parse_mesh("1x8")), backend=backend)
        name = m8.kv.attn_subs[0]
        pool = m8.state["caches"][name]["k"]
        assert len(pool.sharding.device_set) == 8, pool.sharding
        shard_shapes = {s.data.shape for s in pool.addressable_shards}
        assert shard_shapes == {pool.shape[:3] + (1, pool.shape[4])}, \
            shard_shapes                       # 8 KV heads / 8 devices
        toks8 = run(m8)
        for a, b in zip(base, toks8):
            np.testing.assert_array_equal(a, b)
        # compile-count contract: one decode trace per (capacity, tier)
        assert m8.decode_traces == base_eng.decode_traces
        assert m8.admit_traces == base_eng.admit_traces
    print("MESH_PARITY_OK")

    # ---- preempt/restore across the mesh staging lanes ----
    def swap_cycle(sh):
        eng = ServingEngine(cfg, params, sh=sh)
        sched = MultiTenantScheduler(
            eng, mode="continuous", preemption=True,
            continuous=dict(capacity=2, page_size=8, num_pages=14,
                            inner_steps=2, max_prompt_len=16))
        prompts = [rng2.integers(1, cfg.vocab_size,
                                 8 + 8 * (i % 2)).astype(np.int32)
                   for i in range(3)]
        for i in range(2):
            sched.submit(Request(f"lo{i}", prompts[i], 30, priority=1,
                                 seed=i))
        sched.step()
        sched.submit(Request("hi", prompts[2], 4, priority=0))
        res = sched.drain()
        ceng = sched.continuous_engine
        ceng.kv.assert_conserved(host_pages=ceng.swap_store.pages())
        assert ceng.preemptions > 0 and ceng.restores > 0
        assert ceng.restore_traces == 1
        assert all(r.outcome == "completed" for r in res)
        return {(r.tenant, tuple(r.tokens.tolist())) for r in res}, ceng

    rng2 = np.random.default_rng(1); ref, _ = swap_cycle(None)
    rng2 = np.random.default_rng(1)
    got, ceng8 = swap_cycle(serving_sharder(parse_mesh("1x8")))
    assert ref == got, (sorted(ref - got), sorted(got - ref))
    # swap-ins really rode the per-slice lanes: one sequential engine per
    # mesh device, each with staged transfers in its log
    lanes = ceng8.swap_store.lanes
    assert lanes is not None and lanes.n_lanes == 8
    assert all(len(e.log) > 0 for e in lanes.engines.values())
    print("MESH_SWAP_OK")
""")


def test_mesh_sharded_engine_subprocess():
    """1×1 bitwise + 1×8 token-exact (both backends, incl. eviction/CoW and
    preempt-restore through the staging lanes) with conservation audited on
    the sharded pool."""
    proc = _run_child(SHARDED_ENGINE_SCRIPT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_PARITY_OK" in proc.stdout
    assert "MESH_SWAP_OK" in proc.stdout


SHARDED_KERNEL_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.distributed.sharding import parse_mesh, serving_sharder
    from repro.kernels.paged_attention import (
        paged_attention_decode_pallas, paged_attention_decode_sharded,
        paged_prefill_scatter_pallas, paged_prefill_scatter_sharded)

    assert len(jax.devices()) == 8
    sh = serving_sharder(parse_mesh("1x8"))
    rng = np.random.default_rng(0)
    C, H, Hkv, D, NP, P, NB = 4, 16, 8, 16, 10, 4, 3
    q = jnp.asarray(rng.normal(size=(C, H, D)).astype(np.float32))
    kp = jnp.asarray(rng.normal(size=(NP, P, Hkv, D))).astype(jnp.bfloat16)
    vp = jnp.asarray(rng.normal(size=(NP, P, Hkv, D))).astype(jnp.bfloat16)
    pos_pool = jnp.asarray(rng.integers(0, 8, (NP, P)).astype(np.int32))
    pt = jnp.asarray(rng.integers(2, NP, (C, NB)).astype(np.int32))
    pos = jnp.asarray(rng.integers(4, 12, (C,)).astype(np.int32))

    ref = paged_attention_decode_pallas(q, kp, vp, pos_pool, pt, pos)
    out = jax.jit(lambda *a: paged_attention_decode_sharded(*a, sh))(
        q, kp, vp, pos_pool, pt, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    # MQA: pools replicated, q sharded on H.  Heads stay independent, but
    # the per-shard dot shapes (rep=H/8 vs rep=H) let XLA tile the in-dot
    # d-contraction differently -> f32-rounding-level agreement, not
    # bitwise (the engine contract for wide meshes is greedy token-exact)
    kp1, vp1 = kp[:, :, :1], vp[:, :, :1]
    ref1 = paged_attention_decode_pallas(q, kp1, vp1, pos_pool, pt, pos)
    out1 = jax.jit(lambda *a: paged_attention_decode_sharded(*a, sh))(
        q, kp1, vp1, pos_pool, pt, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(ref1),
                               rtol=0, atol=1e-5)

    # indivisible head counts fall back to fully replicated specs
    q3, kp3, vp3 = q[:, :12], kp[:, :, :3], vp[:, :, :3]
    ref3 = paged_attention_decode_pallas(q3, kp3, vp3, pos_pool, pt, pos)
    out3 = jax.jit(lambda *a: paged_attention_decode_sharded(*a, sh))(
        q3, kp3, vp3, pos_pool, pt, pos)
    np.testing.assert_array_equal(np.asarray(out3), np.asarray(ref3))

    S, nb = 2, 3
    pool = jnp.zeros((S, NP, P, Hkv, D), jnp.bfloat16)
    pages = jnp.asarray([3, 5, 7], jnp.int32)
    vals = jnp.asarray(rng.normal(size=(S, nb, P, Hkv, D)).astype(np.float32))
    ref_sc = paged_prefill_scatter_pallas(pool, pages, vals)
    out_sc = jax.jit(
        lambda *a: paged_prefill_scatter_sharded(*a, sh),
        donate_argnums=(0,))(pool, pages, vals)
    np.testing.assert_array_equal(np.asarray(out_sc), np.asarray(ref_sc))
    print("MESH_KERNELS_OK")
""")


def test_mesh_sharded_kernels_subprocess():
    """shard_map-wrapped pallas kernels are bitwise with their unsharded
    invocations across the GQA / MQA / replicated-fallback dispatch cases."""
    proc = _run_child(SHARDED_KERNEL_SCRIPT)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_KERNELS_OK" in proc.stdout
