"""Dispatch/await serving engine: token-exactness + real decode-under-staging
overlap.

The headline harness for the split serving path: ``ServingEngine.dispatch``
enqueues prefill + a single on-device ``lax.scan`` decode loop (sampling
folded into the scanned step) and returns a handle; ``await_result``
materialises tokens.  These tests lock in

* token-exact equivalence with the host-blocking ``generate`` loop, for
  greedy and temperature sampling with fixed seeds, across small configs of
  the three model families (decoder-only, SSM, encoder-decoder — the same
  reduced configs ``test_archs_smoke.py`` exercises);
* scheduler-level equivalence: blocking and overlapped schedules return
  identical tokens for an identical request mix;
* the overlap contract itself, in a subprocess mirroring
  ``tests/test_pipeline.py`` (``XLA_FLAGS=--xla_force_host_platform_
  device_count=8`` must precede jax init): the overlapped schedule shows
  >=1 (staging, decode) timeline pair satisfying the falsifiable
  ``timeline_overlaps`` predicate plus monotone per-slot windows, while the
  blocking schedule structurally shows zero such pairs.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import PendingGeneration, ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request

# one small config per model family, drawn from the smoke-test pool
EQUIV_ARCHS = ["internlm2-1.8b", "mamba2-2.7b", "whisper-base"]


def _make_engine(arch: str, temperature: float = 0.0) -> ServingEngine:
    cfg = get_config(arch).reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params, temperature=temperature)


def _inputs(engine: ServingEngine, rng, B=2, S=16):
    cfg = engine.cfg
    prompts = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    extra = None
    if cfg.enc_dec:
        extra = {"frames": rng.normal(
            size=(B, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)}
    return prompts, extra


@pytest.mark.parametrize("arch", EQUIV_ARCHS)
@pytest.mark.parametrize("temperature", [0.0, 0.9])
def test_dispatch_await_token_exact(arch, temperature, rng):
    """The scanned decode loop must reproduce the host loop token-for-token
    (same PRNG key schedule: PRNGKey(seed), then fold_in(key, step))."""
    engine = _make_engine(arch, temperature=temperature)
    prompts, extra = _inputs(engine, rng)
    for seed in (0, 7):
        blocking = engine.generate(prompts, max_new_tokens=6,
                                   extra_inputs=extra, seed=seed)
        handle = engine.dispatch(prompts, max_new_tokens=6,
                                 extra_inputs=extra, seed=seed)
        split = engine.await_result(handle)
        np.testing.assert_array_equal(blocking.tokens, split.tokens)
        assert split.tokens.shape == (2, 6)
        assert split.steps == 6
        assert split.prefill_s >= 0 and split.decode_s >= 0


def test_temperature_seeds_vary_tokens(rng):
    """Sanity for the temperature path: different seeds must differ, so the
    equality above is not vacuous."""
    engine = _make_engine("internlm2-1.8b", temperature=1.0)
    prompts, _ = _inputs(engine, rng, B=4)
    a = engine.await_result(engine.dispatch(prompts, 8, seed=0))
    b = engine.await_result(engine.dispatch(prompts, 8, seed=1))
    assert not np.array_equal(a.tokens, b.tokens)


def test_pending_generation_handle(rng):
    engine = _make_engine("internlm2-1.8b")
    prompts, _ = _inputs(engine, rng)
    handle = engine.dispatch(prompts, max_new_tokens=4)
    assert isinstance(handle, PendingGeneration)
    assert handle.t_dispatched >= handle.t_start
    first = engine.await_result(handle)
    assert handle.ready()                  # settled after a blocking await
    # awaiting the same handle again is idempotent on the token values
    np.testing.assert_array_equal(first.tokens,
                                  engine.await_result(handle).tokens)


def test_scheduler_blocking_vs_overlapped_token_identical(rng):
    """Same request mix through both schedules -> identical (tenant, tokens)
    response sequences (greedy, fixed engine)."""
    engine = _make_engine("internlm2-1.8b")
    cfg = engine.cfg
    mix = [(f"tenant-{i % 3}",
            rng.integers(1, cfg.vocab_size, 8 + (i % 2) * 4).astype(np.int32))
           for i in range(9)]

    def run(overlapped):
        sched = MultiTenantScheduler(engine, max_batch=2,
                                     overlapped=overlapped)
        for tenant, prompt in mix:
            sched.submit(Request(tenant, prompt, max_new_tokens=3))
        return sched, sched.drain()

    _, blocking = run(False)
    sched, overlapped = run(True)
    assert len(blocking) == len(overlapped) == 9
    for rb, ro in zip(blocking, overlapped):
        assert rb.tenant == ro.tenant
        np.testing.assert_array_equal(rb.tokens, ro.tokens)
    # overlapped run kept full per-slot accounting
    rep = sched.utilization_report()
    assert set(rep) == {"tenant-0", "tenant-1", "tenant-2"}
    assert sum(r["requests"] for r in rep.values()) == 9


def test_overlapped_timeline_windows_are_monotone(rng):
    engine = _make_engine("internlm2-1.8b")
    cfg = engine.cfg
    sched = MultiTenantScheduler(engine, max_batch=2, overlapped=True)
    for i in range(6):
        sched.submit(Request(f"t{i % 2}",
                             rng.integers(1, cfg.vocab_size,
                                          8).astype(np.int32),
                             max_new_tokens=2))
    sched.drain()
    tl = sched.timeline
    assert len(tl) == 4                    # 3 reqs/tenant at max_batch=2
    for e in tl:
        assert e.transfer_start <= e.transfer_end <= e.compute_start \
            <= e.compute_end, vars(e)
    # staged strictly in launch order
    for a, b in zip(tl, tl[1:]):
        assert b.transfer_start >= a.transfer_start


def test_blocking_schedule_structurally_shows_zero_overlap(rng):
    """The A/B baseline cannot satisfy the overlap predicate: each slot's
    assembly happens only after the previous generate() returned."""
    from repro.core.pipeline import timeline_overlaps
    engine = _make_engine("internlm2-1.8b")
    cfg = engine.cfg
    sched = MultiTenantScheduler(engine, max_batch=2, overlapped=False)
    for i in range(6):
        sched.submit(Request(f"t{i % 2}",
                             rng.integers(1, cfg.vocab_size,
                                          8).astype(np.int32),
                             max_new_tokens=2))
    sched.drain()
    ov = timeline_overlaps(sched.timeline)
    assert sum(ov) == 0, ov


SERVING_OVERLAP_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax

    from repro.configs import get_config
    from repro.core.pipeline import timeline_overlaps
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import MultiTenantScheduler, Request

    assert len(jax.devices()) == 8, jax.devices()
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
               for _ in range(9)]

    def run(overlapped, steps=32):
        sched = MultiTenantScheduler(engine, max_batch=3,
                                     overlapped=overlapped)
        for i, p in enumerate(prompts):
            sched.submit(Request(f"t{i % 3}", p, max_new_tokens=steps))
        return sched, sched.drain()

    run(False); run(True)            # warm: compile both decode paths
    sched, resp = run(True)
    sched_b, resp_b = run(False)
    assert len(resp) == len(resp_b) == 9

    # token-exact across the two schedules (greedy, same seed)
    for a, b in zip(resp, resp_b):
        assert a.tenant == b.tenant
        np.testing.assert_array_equal(a.tokens, b.tokens)

    # overlapped schedule: >=1 (staging, decode) pair where slot k+1's
    # assembly+staging began inside slot k's dispatch->ready decode window
    # (32 scanned decode steps far outlast one batch assembly+enqueue),
    # plus monotone per-slot windows stamped at device readiness
    tl = sched.timeline
    assert len(tl) == 3, tl
    for e in tl:
        assert e.transfer_start <= e.transfer_end <= e.compute_start \\
            <= e.compute_end, vars(e)
    for a, b in zip(tl, tl[1:]):
        assert b.transfer_start >= a.transfer_start
    ov = timeline_overlaps(tl)
    assert sum(ov) >= 1, ov

    # blocking schedule: structurally zero overlapped pairs
    ovb = timeline_overlaps(sched_b.timeline)
    assert sum(ovb) == 0, ovb
    print("SERVING_OVERLAP_OK")
""")


def test_serving_overlap_subprocess():
    """Overlap contract under 8 forced host devices, mirroring
    test_pipeline.py (the XLA flag must precede jax initialisation)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", SERVING_OVERLAP_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SERVING_OVERLAP_OK" in proc.stdout
