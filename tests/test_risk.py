"""Risk application: engine numerics, tenancy equivalence, metrics."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.risk_app import RiskAppConfig
from repro.core.tenancy import TenancyConfig
from repro.kernels.ref import aggregate_loss_ref
from repro.risk import metrics
from repro.risk.analysis import AggregateRiskAnalysis
from repro.risk.tables import generate, paper_scale_nbytes


@pytest.fixture(scope="module")
def cfg():
    return RiskAppConfig().reduced()


@pytest.fixture(scope="module")
def tables(cfg):
    return generate(cfg, seed=0)


def _ref_ylt(tables):
    return np.asarray(aggregate_loss_ref(
        jnp.asarray(tables.yet), jnp.asarray(tables.elt_losses),
        jnp.asarray(tables.occ_ret), jnp.asarray(tables.occ_lim),
        jnp.asarray(tables.agg_ret), jnp.asarray(tables.agg_lim)))


def test_single_run_matches_reference(cfg, tables):
    ara = AggregateRiskAnalysis(cfg)
    np.testing.assert_allclose(ara.run_single(tables), _ref_ylt(tables),
                               rtol=1e-6)


@pytest.mark.parametrize("tenants,mode", [(1, "sequential"),
                                          (2, "sequential"),
                                          (4, "sequential"),
                                          (2, "concurrent")])
def test_tenant_chunked_equals_single(cfg, tables, tenants, mode):
    """Multi-tenancy is a pure scheduling change — results are identical."""
    ara = AggregateRiskAnalysis(
        cfg, TenancyConfig(1, tenants, mode))
    rep = ara.run_tenant_chunked(tables)
    np.testing.assert_allclose(rep.ylt, _ref_ylt(tables), rtol=1e-6)
    assert rep.wall_s > 0
    assert len(rep.per_tenant_s) == tenants


def test_straggler_reorder_preserves_results(cfg, tables):
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 4))
    hist = {0: 5.0, 1: 1.0, 2: 3.0, 3: 0.5}
    rep = ara.run_tenant_chunked(tables, straggler_hist=hist)
    np.testing.assert_allclose(rep.ylt, _ref_ylt(tables), rtol=1e-6)


def test_generator_determinism(cfg):
    a, b = generate(cfg, seed=7), generate(cfg, seed=7)
    np.testing.assert_array_equal(a.yet, b.yet)
    np.testing.assert_array_equal(a.elt_losses, b.elt_losses)
    c = generate(cfg, seed=8)
    assert not np.array_equal(a.yet, c.yet)


def test_generator_structure(cfg, tables):
    assert tables.elt_losses[0].max() == 0.0       # pad row zero
    assert tables.yet.min() >= 0
    assert tables.yet.max() <= cfg.event_catalog
    assert (tables.occ_lim > 0).all()


def test_paper_scale_footprints():
    # paper: YET 4 GB, ELTs 120 MB, PF ~4 MB
    sizes = paper_scale_nbytes(RiskAppConfig())
    assert 3900 < sizes["yet_mb"] < 4100
    assert 100 < sizes["elt_mb"] < 140


def test_metrics_properties(tables, cfg):
    ara = AggregateRiskAnalysis(cfg)
    ylt = jnp.asarray(ara.run_single(tables))
    p = metrics.pml(ylt)
    vals = [float(p[r]) for r in (10, 50, 100, 250, 500, 1000)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))   # monotone in period
    assert float(metrics.tvar(ylt)) >= float(metrics.var(ylt))
    assert float(metrics.expected_loss(ylt)) <= float(tables.agg_lim)
    assert (np.asarray(ylt) >= 0).all()
    assert (np.asarray(ylt) <= tables.agg_lim + 1e-3).all()


def test_aggregate_terms_bound_losses(cfg, tables):
    """Every YLT entry respects min(max(l-AggR,0),AggL) bounds."""
    y = _ref_ylt(tables)
    assert y.min() >= 0.0
    assert y.max() <= tables.agg_lim + 1e-3


def test_sharded_step_single_device(cfg, tables):
    import jax
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    ara = AggregateRiskAnalysis(cfg)
    step = ara.make_sharded_step(mesh, chunk=16)
    ylt = step(jnp.asarray(tables.yet), jnp.asarray(tables.elt_losses),
               jnp.asarray(tables.occ_ret), jnp.asarray(tables.occ_lim),
               jnp.asarray(tables.agg_ret), jnp.asarray(tables.agg_lim))
    np.testing.assert_allclose(np.asarray(ylt), _ref_ylt(tables), rtol=1e-6)
