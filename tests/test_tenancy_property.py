"""Property-based tests (hypothesis) for the tenancy/transfer invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st  # noqa: E402

from repro.core import perfmodel as pm
from repro.core.tenancy import TenancyConfig, VirtualDevicePool
from repro.core.transfer import reorder_for_stragglers
from repro.training.grad_compression import (compress_with_feedback,
                                             dequantize_int8, quantize_int8)


@given(st.integers(1, 16), st.integers(1, 8), st.integers(0, 5000))
def test_plan_partitions_exactly(n_pdev, tenants, items):
    pool = VirtualDevicePool(TenancyConfig(n_pdev, tenants))
    tasks = pool.plan(items)
    assert len(tasks) == n_pdev * tenants
    covered = sorted((t.start, t.stop) for t in tasks)
    pos = 0
    for a, b in covered:
        assert a == pos and b >= a
        pos = b
    assert pos == items
    # balanced within 1
    sizes = [t.size for t in tasks]
    assert max(sizes) - min(sizes) <= 1


@given(st.integers(1, 16), st.integers(1, 8))
def test_plan_is_slot_major(n_pdev, tenants):
    pool = VirtualDevicePool(TenancyConfig(n_pdev, tenants))
    tasks = pool.plan(n_pdev * tenants * 3)
    slots = [t.slot for t in tasks]
    assert slots == sorted(slots)  # all slot-0 tenants staged first
    for t in tasks:
        assert pool.vdev_to_pdev(t.vdev) == (t.pdev, t.slot)


@given(st.integers(1, 12), st.integers(1, 12))
def test_memory_model_monotone_in_tenants(n_pdev, tenants):
    m = pm.PerfModelInputs(net=pm.FDR)
    a = pm.memory_per_pdev_mb(n_pdev, tenants, m)
    b = pm.memory_per_pdev_mb(n_pdev, tenants + 1, m)
    assert b > a  # more tenants per pdev always needs more memory


@given(st.integers(2, 12), st.integers(1, 6))
def test_exec_time_monotone_in_pdevs(n_pdev, tenants):
    # with more pdevs at fixed tenancy, compute falls; transfer overhead grows
    m = pm.PerfModelInputs(net=pm.FDR)
    t = pm.exec_time_multitenancy(n_pdev, tenants, m)
    assert t >= pm.t_computation(n_pdev, m)
    assert t >= pm.t_transfer(n_pdev * tenants, m) / tenants


@given(st.lists(st.floats(-1e3, 1e3), min_size=1, max_size=300),
       st.sampled_from([16, 64, 256]))
def test_quantize_roundtrip_error_bound(vals, block):
    x = np.asarray(vals, np.float32)
    q, s = quantize_int8(x, block)
    y = np.asarray(dequantize_int8(q, s, x.shape))
    # error per element bounded by half a quantisation step of its block
    flat = np.pad(x, (0, (-x.size) % block)).reshape(-1, block)
    steps = np.abs(flat).max(1) / 127.0
    bound = np.repeat(np.maximum(steps, 1e-12), block)[:x.size] * 0.51
    assert np.all(np.abs(x - y) <= bound + 1e-6)


@given(st.integers(0, 2**31 - 1))
def test_error_feedback_reduces_bias(seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=64).astype(np.float32)
    resid = np.zeros_like(g)
    total_sent = np.zeros_like(g)
    for _ in range(8):
        q, s, resid = compress_with_feedback(g, resid)
        total_sent += np.asarray(dequantize_int8(q, s, g.shape))
    # sum of dequantised messages ~ 8*g up to one residual's worth of error
    err = np.abs(total_sent - 8 * g)
    step = np.abs(g).max() / 127.0 + 1e-9
    assert err.max() <= 8 * step


@given(st.integers(1, 8), st.integers(1, 4))
def test_straggler_reorder_is_permutation(n_pdev, tenants):
    pool = VirtualDevicePool(TenancyConfig(n_pdev, tenants))
    tasks = pool.plan(100)
    hist = {t.vdev: float(t.vdev % 3) for t in tasks}
    re = reorder_for_stragglers(tasks, hist)
    assert sorted(t.vdev for t in re) == sorted(t.vdev for t in tasks)
    # slowest first
    assert hist[re[0].vdev] == max(hist.values())
