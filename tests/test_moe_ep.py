"""shard_map expert-parallel MoE == scatter baseline (8-device subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import ArchConfig, MoEConfig
    from repro.distributed.sharding import Sharder
    from repro.models.moe import apply_moe_scatter, apply_moe_ep, init_moe
    from repro.models import params as pp

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = Sharder(mesh, fsdp=False, seq_shard=False)
    cfg = ArchConfig(name="t", family="moe", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
        moe_period=1,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16,
                      capacity_factor=8.0, group_size=64),
        param_dtype="float32", compute_dtype="float32")
    params, _ = pp.split(init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    with mesh:
        y1, l1 = jax.jit(lambda p, x: apply_moe_scatter(p, x, cfg, sh))(params, x)
        y2, l2 = jax.jit(lambda p, x: apply_moe_ep(p, x, cfg, sh))(params, x)

    def loss_sc(p, x):
        y, l = apply_moe_scatter(p, x, cfg, sh)
        return jnp.sum(y ** 2) + sum(l.values())
    def loss_ep(p, x):
        y, l = apply_moe_ep(p, x, cfg, sh)
        return jnp.sum(y ** 2) + sum(l.values())
    with mesh:
        g1 = jax.jit(jax.grad(loss_sc))(params, x)
        g2 = jax.jit(jax.grad(loss_ep))(params, x)
    out = {
        "fwd_err": float(jnp.max(jnp.abs(y1 - y2))),
        "aux_err": abs(float(l1["moe_aux"]) - float(l2["moe_aux"])),
        "grad_err": max(float(jnp.max(jnp.abs(g1[k] - g2[k]))) for k in g1),
    }
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def result():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_MOE_DISPATCH", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_ep_forward_matches_scatter(result):
    assert result["fwd_err"] < 5e-3


def test_ep_aux_matches(result):
    assert result["aux_err"] < 1e-6


def test_ep_grads_match(result):
    assert result["grad_err"] < 1e-3
