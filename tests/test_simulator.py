"""Discrete-event simulator vs the paper's Figs 8/10/11/12/13/14."""
import pytest

from repro.core.simulator import (SimInputs, concurrent_vs_sequential,
                                  effective_bandwidth, simulate,
                                  simulate_cells)
from repro.core.tenancy import TenancyConfig


def test_fig11b_timeline_88_cells():
    r = simulate_cells(SimInputs(TenancyConfig(4, 1, "sequential")))
    assert r.steps() == 88
    # "data transferred completely to all GPUs at time step 20"
    assert max(e.transfer_end for e in r.events) == pytest.approx(20 * 0.035)


def test_fig13a_timeline_80_cells():
    r = simulate_cells(SimInputs(TenancyConfig(4, 2, "sequential")))
    assert r.steps() == 80
    ends = sorted(e.transfer_end for e in r.events)
    # "after transferring data in the 12th time step" (first 4 tenants)
    assert ends[3] == pytest.approx(12 * 0.035)
    # "the input data arrives at time step 24" (all 8)
    assert ends[-1] == pytest.approx(24 * 0.035)


def test_fig13b_timeline_76_cells():
    r = simulate_cells(SimInputs(TenancyConfig(4, 4, "sequential")))
    assert r.steps() == 76


def test_multitenancy_monotone_improvement():
    # same hardware, increasing tenants => shorter makespan, less energy,
    # higher utilisation (paper Fig 13/14)
    res = [simulate_cells(SimInputs(TenancyConfig(4, t, "sequential")))
           for t in (1, 2, 4)]
    assert res[0].makespan > res[1].makespan > res[2].makespan
    assert res[0].energy_ws > res[1].energy_ws > res[2].energy_ws
    assert res[0].utilization < res[1].utilization < res[2].utilization


def test_energy_close_to_paper_measurements():
    # paper Fig 12/14 (measured): 1145 / 1094 / 1041 Ws; model within 5%
    want = {1: 1145.0, 2: 1094.0, 4: 1041.0}
    for t, w in want.items():
        r = simulate_cells(SimInputs(TenancyConfig(4, t, "sequential")))
        assert abs(r.energy_ws - w) / w < 0.05, (t, r.energy_ws)


def test_utilization_trend_matches_paper():
    # paper: 71.44% -> 79.65% -> 81.93% (measured); model monotone & in band
    for t, lo in ((1, 0.70), (2, 0.78), (4, 0.80)):
        r = simulate_cells(SimInputs(TenancyConfig(4, t, "sequential")))
        assert r.utilization > lo


def test_fig8_bandwidth_sharing():
    bw = 6000.0
    for n in (1, 2, 4, 8):
        assert effective_bandwidth(n, bw) == pytest.approx(bw / n)


def test_concurrent_equals_sequential_without_tenancy():
    # paper §V-D1: without same-GPU overlap, both modes end at the same time
    cv = concurrent_vs_sequential(4)
    assert cv["concurrent"].steps() == cv["sequential"].steps()
    # ... but sequential starts the first GPU's compute earlier
    c0 = min(e.compute_start for e in cv["sequential"].events)
    c1 = min(e.compute_start for e in cv["concurrent"].events)
    assert c0 < c1


def test_continuous_sim_close_to_cells():
    for t in (1, 2, 4):
        rc = simulate(SimInputs(TenancyConfig(4, t, "sequential")))
        rq = simulate_cells(SimInputs(TenancyConfig(4, t, "sequential")))
        assert abs(rc.makespan - rq.makespan) / rq.makespan < 0.06
