"""Observability plane: spans/metrics core, instrumentation contracts,
exporters, and the telemetry-driven capacity planner.

* telemetry core: span nesting/parent links from the per-thread stack,
  zero-length events, the bounded ring (drops counted, never grown),
  counters/gauges/histograms and their snapshots;
* overhead contract: a disabled plane allocates nothing on the decode
  micro-round path (``spans_opened`` and the counter table stay flat),
  and an enabled plane changes no compile counts and no tokens;
* the occupancy regression (PR 8): ``occupancy()`` is derived from the
  per-round collect log, so a dispatched-but-uncollected round no longer
  deflates it the way the old ``row_steps / (rounds * inner * capacity)``
  quotient did;
* exporters: Chrome-trace JSON round-trips with parent links intact
  (round.jit > round.dispatch > sched.step) and the Prometheus text
  exposition parses back to the counter table; a golden-file run pins
  the engine-level counter/span-name schema;
* fit + plan: `plan_from_telemetry` on a replayed deployment sweep picks
  the same (n_pdev, tenancy, transfer-mode) optimum as the static
  Table II planner, with fitted predictions agreeing with the simulator.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import energymodel as em
from repro.core import perfmodel as pm
from repro.core.pipeline import TenantTimeline
from repro.core.planner import plan, plan_from_telemetry
from repro.core.simulator import SimInputs, simulate
from repro.core.tenancy import TenancyConfig
from repro.models import params as pp
from repro.models.model import build_model
from repro.obs.export import (chrome_trace, parse_prometheus_text,
                              prometheus_text, stats_line,
                              write_chrome_trace)
from repro.obs.fit import (fit_perf_inputs, fit_power_params, PhaseSample,
                           replay_sim_run, samples_from_telemetry)
from repro.obs.telemetry import (NULL_SPAN, record_timeline, Telemetry,
                                 TELEMETRY)
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "obs_serving_counters.json")


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params)


def _mk_reqs(engine, rng, n, plen=12, steps=8, tenant="a", **kw):
    return [Request(tenant, rng.integers(1, engine.cfg.vocab_size,
                                         plen).astype(np.int32),
                    max_new_tokens=steps, **kw) for _ in range(n)]


def _drain_lockstep(ceng, reqs):
    """Admit/dispatch/collect in lockstep — deterministic by construction
    (no ``handle.ready()`` timing races)."""
    queue = list(reqs)
    done = []
    while queue or ceng.active_count():
        free = ceng.free_slot_count()
        if queue and free:
            batch, queue = queue[:free], queue[free:]
            flags = ceng.try_admit_batch(batch)
            assert all(flags)
        h = ceng.dispatch_round()
        done.extend(ceng.collect(h).finished)
    return done


# ---------------------------------------------------------------------
# telemetry core
# ---------------------------------------------------------------------
def test_span_nesting_and_parent_links():
    tel = Telemetry(enabled=True)
    with tel.span("sched.step", mode="continuous") as outer:
        with tel.span("round.dispatch") as inner:
            tel.event("kv.alloc", slot=3)
            inner.note(steps=4)
        outer.note(responses=2)
    spans = {s.name: s for s in tel.spans()}
    assert set(spans) == {"sched.step", "round.dispatch", "kv.alloc"}
    assert spans["sched.step"].parent_id is None
    assert spans["round.dispatch"].parent_id == spans["sched.step"].span_id
    assert spans["kv.alloc"].parent_id == spans["round.dispatch"].span_id
    assert spans["kv.alloc"].duration == 0.0
    assert spans["round.dispatch"].attrs == {"steps": 4}
    assert spans["sched.step"].attrs == {"mode": "continuous",
                                         "responses": 2}
    # children close inside their parent's window on the same clock
    assert (spans["sched.step"].t_start <= spans["round.dispatch"].t_start
            <= spans["round.dispatch"].t_end <= spans["sched.step"].t_end)
    assert tel.spans_opened == 3 and tel.spans_dropped == 0


def test_ring_buffer_drops_oldest_and_reset():
    tel = Telemetry(enabled=True, max_spans=4)
    for i in range(6):
        tel.event("kv.alloc", i=i)
    spans = tel.spans()
    assert len(spans) == 4
    assert [s.attrs["i"] for s in spans] == [2, 3, 4, 5]   # oldest dropped
    assert tel.spans_dropped == 2
    assert tel.spans_opened == 6                # opened counts the dropped
    tel.count("kv.pages_allocated", 3)
    tel.reset()
    assert tel.spans() == [] and tel.counter_snapshot() == {}
    assert tel.spans_dropped == 0 and tel.enabled


def test_disabled_plane_is_free():
    tel = Telemetry()          # disabled by default
    assert tel.span("sched.step") is NULL_SPAN        # shared singleton
    with tel.span("sched.step") as sp:
        sp.note(anything=1)
    tel.event("kv.alloc")
    tel.count("c"), tel.gauge("g", 1.0), tel.observe("h", 2.0)
    assert tel.record_span("round.device", 0.0, 1.0) is None
    assert tel.spans_opened == 0 and tel.spans() == []
    assert tel.metric_snapshot() == {"counters": {}, "gauges": {},
                                     "histograms": {}}


def test_metrics_and_snapshots():
    tel = Telemetry(enabled=True)
    tel.count("kv.pages_allocated", 4)
    tel.count("kv.pages_allocated")
    tel.gauge("kv.free_pages", 7)
    for v in (0.5, 2.0, 1.0):
        tel.observe("round.wall_s", v)
    snap = tel.metric_snapshot()
    assert snap["counters"] == {"kv.pages_allocated": 5}
    assert snap["gauges"] == {"kv.free_pages": 7}
    assert snap["histograms"]["round.wall_s"] == {
        "count": 3, "sum": 3.5, "min": 0.5, "max": 2.0}
    line = stats_line(tel, keys=("kv.pages_allocated", "missing"), step=9)
    assert line == "obs: kv.pages_allocated=5 missing=0 step=9"


def test_record_timeline_mirrors_entry_as_spans():
    tel = Telemetry(enabled=True)
    entry = TenantTimeline(vdev=1, pdev=0, slot=2, transfer_start=0.1,
                           transfer_end=0.3, compute_start=0.3,
                           compute_end=0.9)
    record_timeline(tel, entry, base=tel.t0, tenant="a", nv=4)
    tr, = tel.spans(name="timeline.transfer")
    cp, = tel.spans(name="timeline.compute")
    assert cp.parent_id == tr.span_id
    assert tr.attrs["nv"] == 4 and tr.attrs["slot"] == 2
    assert tr.duration == pytest.approx(0.2)
    assert cp.duration == pytest.approx(0.6)


# ---------------------------------------------------------------------
# satellite 1: occupancy derived from the round log
# ---------------------------------------------------------------------
def test_occupancy_not_deflated_by_inflight_round(engine, rng):
    """Old formula counted a dispatched round's capacity before its live
    steps landed; the round-log version only scores collected rounds.
    On a drained engine the two agree exactly."""
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=16)
    old = lambda: (ceng.row_steps
                   / (ceng.rounds * ceng.inner_steps * ceng.capacity))
    assert all(ceng.try_admit_batch(_mk_reqs(engine, rng, 2, steps=8)))
    h = ceng.dispatch_round()
    ceng.collect(h)
    assert ceng.occupancy() == pytest.approx(1.0)      # round 1: all live
    h = ceng.dispatch_round()                          # round 2 in flight
    assert old() == pytest.approx(0.5)           # the PR-3..7 deflation bug
    assert ceng.occupancy() == pytest.approx(1.0)      # unaffected
    ceng.collect(h)                                    # rows retire here
    assert ceng.active_count() == 0
    # drained: the old quotient and the round-log derivation agree
    assert ceng.occupancy() == pytest.approx(old()) == pytest.approx(1.0)


# ---------------------------------------------------------------------
# satellite 3: overhead contract
# ---------------------------------------------------------------------
def test_disabled_plane_allocates_nothing_on_decode_path(engine, rng):
    """Layers resolve ``telemetry=None`` to the global plane; with it
    disabled a full admit/decode/collect run must not open a single span
    or touch a counter (``spans_opened`` counts every allocation ever
    attempted, including ones a ring would drop)."""
    assert not TELEMETRY.enabled
    before = (TELEMETRY.spans_opened, TELEMETRY.counter_snapshot(),
              TELEMETRY.metric_snapshot())
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=16)
    done = _drain_lockstep(ceng, _mk_reqs(engine, rng, 3, steps=6))
    assert len(done) == 3
    assert (TELEMETRY.spans_opened, TELEMETRY.counter_snapshot(),
            TELEMETRY.metric_snapshot()) == before


def test_enabled_plane_changes_no_compile_counts(engine, rng):
    """The test_continuous compile-count contract, replayed with the
    plane on: trace-time counters fire exactly once per trace and the
    engine's trace counts are unchanged by instrumentation."""
    tel = Telemetry(enabled=True)
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=32,
                                    telemetry=tel)
    cfg = engine.cfg
    mk = lambda plen, steps: Request("a", rng.integers(
        1, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=steps)
    ceng.run_all([mk(6, 1), mk(8, 5), mk(7, 9)])
    ceng.run_all([mk(12, 2), mk(16, 7)])
    ceng.run_all([mk(5, 11), mk(14, 3)])
    # identical to the uninstrumented contract in test_continuous.py
    assert ceng.decode_traces == 1
    assert ceng.admit_traces == 2
    assert ceng.prefill_traces == 4
    assert ceng.prefill_calls == 5
    # and the plane's trace-time counters mirror them exactly
    c = tel.counter_snapshot()
    assert c["trace.decode"] == 1
    assert c["trace.admit"] == 2
    assert c["trace.prefill"] == 4
    assert c["admit.prefill_calls"] == 5


def test_tokens_identical_enabled_vs_disabled(engine, rng):
    """Instrumentation changed no numerics: the same request mix decodes
    to bitwise-identical tokens with the plane on and off."""
    prompts = [rng.integers(1, engine.cfg.vocab_size, n).astype(np.int32)
               for n in (12, 8, 15)]
    outs = []
    for tel in (Telemetry(), Telemetry(enabled=True)):
        ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                        inner_steps=4, max_prompt_len=16,
                                        telemetry=tel)
        reqs = [Request("a", p.copy(), max_new_tokens=7) for p in prompts]
        done = {id(r): t for (r, t, _c) in _drain_lockstep(ceng, reqs)}
        outs.append([done[id(r)] for r in reqs])
    for off, on in zip(*outs):
        np.testing.assert_array_equal(off, on)


# ---------------------------------------------------------------------
# scheduler-level run: layer coverage, preemption spans, heartbeat
# ---------------------------------------------------------------------
@pytest.fixture(scope="module")
def sched_run(engine):
    """One preempting 2-tenant scheduler run on an instance plane: tier-1
    rows fill both slots, a late tier-0 arrival forces swap-out/restore."""
    tel = Telemetry(enabled=True)
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    num_pages=24, inner_steps=4,
                                    max_prompt_len=16, telemetry=tel)
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng, preemption=True,
                                 telemetry=tel)
    rng = np.random.default_rng(0)
    for i in range(2):
        sched.submit(Request(f"t{i}", rng.integers(
            1, engine.cfg.vocab_size, 12).astype(np.int32),
            max_new_tokens=40, priority=1))
    sched.step()
    sched.submit(Request("hi", rng.integers(
        1, engine.cfg.vocab_size, 8).astype(np.int32),
        max_new_tokens=4, priority=0))
    responses = sched.drain()
    sched.close()
    return tel, sched, ceng, responses


def test_trace_covers_all_layers(sched_run):
    """The ISSUE acceptance: one serving run records spans from the
    scheduler, engine-round, KV-pool, swap and transfer layers."""
    tel, _sched, ceng, responses = sched_run
    assert ceng.preemptions >= 1 and ceng.restores >= 1
    assert {r.tenant: r.outcome for r in responses} == {
        "t0": "completed", "t1": "completed", "hi": "completed"}
    layers = {s.name.split(".", 1)[0] for s in tel.spans()}
    assert {"sched", "round", "admit", "kv", "swap", "transfer",
            "timeline", "admission"} <= layers
    for name in ("swap.out", "swap.restore", "swap.fetch",
                 "transfer.stage", "kv.alloc", "round.device"):
        assert tel.spans(name=name), f"no {name} spans recorded"
    c = tel.counter_snapshot()
    assert c["swap.preemptions"] == ceng.preemptions
    assert c["swap.restores"] == ceng.restores
    assert c["heartbeat.beats"] > 0


def test_chrome_trace_roundtrip_and_nesting(sched_run, tmp_path):
    """Chrome-trace JSON survives a dump/load round trip and the span
    tree reconstructs from args: round.jit > round.dispatch > sched.step."""
    tel, *_ = sched_run
    path = tmp_path / "trace.json"
    write_chrome_trace(tel, str(path))
    doc = json.loads(path.read_text())
    events = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(events) == len(tel.spans())
    assert doc["otherData"]["spans_opened"] == tel.spans_opened
    by_id = {e["args"]["span_id"]: e for e in events}
    chains = set()
    for e in events:
        if e["name"] != "round.jit":
            continue
        parent = by_id[e["args"]["parent_id"]]
        grand = by_id[parent["args"]["parent_id"]]
        chains.add((e["name"], parent["name"], grand["name"]))
        # a child's [ts, ts+dur) window lies inside its parent's
        assert parent["ts"] <= e["ts"]
        assert e["ts"] + e["dur"] <= parent["ts"] + parent["dur"] + 1e-3
    assert ("round.jit", "round.dispatch", "sched.step") in chains
    # counter snapshot rides the same timeline as "C" events
    cvals = {e["name"]: e["args"]["value"]
             for e in doc["traceEvents"] if e["ph"] == "C"}
    assert cvals["swap.preemptions"] == tel.counter_snapshot()[
        "swap.preemptions"]


def test_prometheus_roundtrip(sched_run):
    tel, *_ = sched_run
    parsed = parse_prometheus_text(prometheus_text(tel))
    snap = tel.metric_snapshot()
    for name, value in snap["counters"].items():
        key = "repro_" + name.replace(".", "_")
        assert parsed[key] == pytest.approx(value)
    for name, value in snap["gauges"].items():
        assert parsed["repro_" + name.replace(".", "_")] == pytest.approx(
            value)


def test_heartbeat_suspects_surface_as_gauges(engine, rng):
    """Satellite: a zero-timeout heartbeat marks every scheduler round
    suspect; the verdicts surface as the plane's counter + gauge and in
    the periodic stats line."""
    tel = Telemetry(enabled=True)
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=16,
                                    telemetry=tel)
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng,
                                 heartbeat_timeout_s=0.0, telemetry=tel)
    for req in _mk_reqs(engine, rng, 2, steps=6):
        sched.submit(req)
    sched.drain()
    sched.close()
    assert sched.heartbeat_suspects > 0
    c = tel.counter_snapshot()
    assert c["heartbeat.missed"] == sched.heartbeat_suspects
    assert tel.metric_snapshot()["gauges"]["heartbeat.suspects"] == \
        sched.heartbeat_suspects
    line = stats_line(tel, keys=("heartbeat.suspects",))
    assert f"heartbeat.suspects={sched.heartbeat_suspects}" in line


def test_heartbeat_verdicts_on_global_plane():
    """HeartbeatMonitor itself (no scheduler) mirrors verdicts onto the
    global plane when enabled — and stays silent when disabled."""
    from repro.distributed.fault import HeartbeatMonitor
    hb = HeartbeatMonitor(timeout_s=0.0)
    assert hb.suspect()                       # disabled global: no record
    assert not TELEMETRY.enabled
    assert "heartbeat.verdicts" not in TELEMETRY.counter_snapshot()
    TELEMETRY.enable()
    try:
        assert hb.suspect()
        assert TELEMETRY.counter_snapshot()["heartbeat.verdicts"] == 1
        assert TELEMETRY.spans(name="heartbeat.suspect")
    finally:
        TELEMETRY.disable()
        TELEMETRY.reset()


# ---------------------------------------------------------------------
# satellite 4: golden-file schema pin for a deterministic run
# ---------------------------------------------------------------------
def _state_kind_counters(arch):
    """One deterministic admit -> round -> preempt -> restore -> drain
    cycle on ``arch``, returning only its per-state-kind ``kv.cross.*`` /
    ``kv.ssm.*`` counters (the PR-9 paged-state-pool schema)."""
    tel = Telemetry(enabled=True)
    cfg = get_config(arch).reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    eng = ServingEngine(cfg, params)
    ceng = ContinuousBatchingEngine(eng, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=16,
                                    telemetry=tel)
    rng = np.random.default_rng(3)
    reqs = [Request(t, rng.integers(1, cfg.vocab_size, 12).astype(np.int32),
                    max_new_tokens=6) for t in ("a", "b")]
    assert all(ceng.try_admit_batch(reqs))
    ceng.collect(ceng.dispatch_round())
    ticket = ceng.preempt(0)
    assert ceng.try_restore(ticket)
    assert len(_drain_lockstep(ceng, [])) == 2
    return {k: float(v) for k, v in sorted(tel.counter_snapshot().items())
            if k.startswith(("kv.cross.", "kv.ssm."))}


def test_golden_counters_and_span_names(engine, rng):
    """Lockstep 2-tenant engine-level run (no ready()-timing races):
    the counter table and the span-name multiset are pinned by a golden
    file, so a renamed or silently-dropped metric fails loudly.  The
    ``state_kind_counters`` section pins the PR-9 per-kind schema — an
    enc-dec and a pure-SSM arch each through a full admit/preempt/restore
    cycle, keeping only their ``kv.cross.*`` / ``kv.ssm.*`` rows.
    Regenerate with REPRO_REGEN_GOLDEN=1 after an intentional change."""
    tel = Telemetry(enabled=True)
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=16,
                                    telemetry=tel)
    reqs = [Request(t, rng.integers(1, engine.cfg.vocab_size,
                                    12).astype(np.int32), max_new_tokens=6)
            for t in ("a", "b", "a", "b")]
    done = _drain_lockstep(ceng, reqs)
    assert len(done) == 4
    names: dict = {}
    for s in tel.spans():
        names[s.name] = names.get(s.name, 0) + 1
    got = {"counters": {k: float(v)
                        for k, v in sorted(tel.counter_snapshot().items())},
           "span_names": dict(sorted(names.items())),
           "state_kind_counters": {
               "whisper-base": _state_kind_counters("whisper-base"),
               "mamba2-2.7b": _state_kind_counters("mamba2-2.7b")}}
    if os.environ.get("REPRO_REGEN_GOLDEN"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
    with open(GOLDEN) as f:
        want = json.load(f)
    assert got == want


# ---------------------------------------------------------------------
# fit + plan acceptance
# ---------------------------------------------------------------------
def _fdr_sweep(tel, nvs=(1, 2, 4, 8, 16)):
    m = pm.PerfModelInputs(net=pm.FDR)
    for nv in nvs:
        si = SimInputs(TenancyConfig(1, nv, "sequential"), net=m.net,
                       compute_time_1pdev=m.compute_time_1pdev,
                       yet_mb=m.yet_mb, elt_mb=m.elt_mb, pf_mb=m.pf_mb,
                       power=em.K20)
        replay_sim_run(tel, si, pw=em.K20)
    return m


def test_plan_from_telemetry_matches_static_planner():
    """ISSUE acceptance: replay a deployment sweep, fit, re-plan — the
    telemetry plan picks the paper's FDR optimum (9x2, sequential) and
    the fitted model's predictions agree with the simulator."""
    tel = Telemetry(enabled=True)
    m = _fdr_sweep(tel)
    tp = plan_from_telemetry(tel)
    st = plan(m, "time")
    d = tp.deployment
    assert (d.n_pdev, d.tenants_per_pdev) == (st.n_pdev,
                                              st.tenants_per_pdev) == (9, 2)
    assert tp.transfer_mode == "sequential"          # the paper's winner
    # the replay is exactly model-generated, so the fit recovers the
    # Table II constants to fp precision and residuals are numerical dust
    assert tp.m.net.t_4gb == pytest.approx(pm.FDR.t_4gb, rel=1e-6)
    assert tp.m.compute_time_1pdev == pytest.approx(
        pm.COMPUTATION_TIME_1PDEV, rel=1e-6)
    assert tp.pw.p_busy == pytest.approx(em.K20.p_busy, rel=1e-6)
    assert tp.pw.p_idle_assigned == pytest.approx(em.K20.p_idle_assigned,
                                                  rel=1e-6)
    assert tp.transfer_rms_s < 1e-9 and tp.compute_rms_s < 1e-9
    # fitted model vs simulator at the chosen deployment: same makespan
    si = SimInputs(TenancyConfig(d.n_pdev, d.tenants_per_pdev,
                                 "sequential"), net=tp.m.net,
                   compute_time_1pdev=tp.m.compute_time_1pdev,
                   yet_mb=tp.m.yet_mb, elt_mb=tp.m.elt_mb,
                   pf_mb=tp.m.pf_mb, power=tp.pw)
    assert pm.exec_time_multitenancy(
        d.n_pdev, d.tenants_per_pdev, tp.m) == pytest.approx(
        simulate(si).makespan, rel=1e-6)


def test_samples_pair_transfer_with_compute():
    tel = Telemetry(enabled=True)
    _fdr_sweep(tel, nvs=(1, 4))
    samples = samples_from_telemetry(tel)
    assert len(samples) == 1 + 4            # one sample per tenant event
    assert {s.nv for s in samples} == {1, 4}
    for s in samples:
        assert s.transfer_s > 0 and s.compute_s > 0


def test_fit_error_paths():
    one_nv = [PhaseSample(2, 0.5, 1.0), PhaseSample(2, 0.5, 1.0)]
    with pytest.raises(ValueError, match="distinct"):
        fit_perf_inputs(one_nv)
    with pytest.raises(ValueError, match=">= 2"):
        fit_power_params([(0.5, 80.0)])
    with pytest.raises(ValueError, match="variation"):
        fit_power_params([(0.5, 80.0), (0.5, 80.0)])


# ---------------------------------------------------------------------
# launch driver end to end (the --trace-out acceptance)
# ---------------------------------------------------------------------
def test_serve_driver_writes_trace_and_metrics(tmp_path, capsys):
    """`launch.serve --trace-out/--metrics-out` on the preempting demo
    mix produces a loadable Chrome trace with spans from the scheduler,
    round, pool and swap layers plus a parsable Prometheus file."""
    from repro.launch import serve
    trace = tmp_path / "trace.json"
    prom = tmp_path / "metrics.prom"
    try:
        rc = serve.main(["--mode", "continuous", "--tenants", "2",
                         "--requests", "3", "--capacity", "2",
                         "--priority", "3", "--new-tokens", "24",
                         "--stats-every", "4",
                         "--trace-out", str(trace),
                         "--metrics-out", str(prom)])
    finally:
        TELEMETRY.disable()       # the driver enables the global plane
        TELEMETRY.reset()
    assert rc == 0
    out = capsys.readouterr().out
    assert "obs: " in out                       # periodic stats line fired
    assert "heartbeat.suspects=" in out
    doc = json.loads(trace.read_text())
    layers = {e["name"].split(".", 1)[0]
              for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"sched", "round", "admit", "kv", "swap", "transfer"} <= layers
    parsed = parse_prometheus_text(prom.read_text())
    assert parsed["repro_swap_preemptions"] >= 1
    assert parsed["repro_swap_restores"] >= 1
