"""PagedKVCache allocator: leak regression, conservation, sharing property.

Host-side allocator tests (no model, no jitted state): the allocator is the
single source of truth for page ownership, refcounts, the prefix trie and
the copy-on-write reserve, so its invariants are checked exhaustively here:

* the PR-3 alloc leak: re-allocating a slot that still owns pages used to
  silently drop the old list off both the free list and the owned map;
* conservation under unshared admit/retire fuzz — the literal PR-3 contract
  ``free_pages() + sum(owned) == num_pages - RESERVED``;
* a Hypothesis property suite over random interleavings of shared/unshared
  admission, decode writes (CoW forks / pristine preserves / in-place),
  retirement, preemption swap cycles (swap-out to the host tier,
  restore, terminal drop) and crash/recovery boundaries (every live slot
  snapshotted to the host tier, the pool rebuilt from scratch and its
  two-tier ledger re-seeded via ``adopt_swapped`` — the engine-checkpoint
  restore montage): pages are never leaked or double-freed, every
  page's refcount equals the number of page-table references to it, the
  trie stays consistent, the fork reserve never exceeds the available pool
  (so a mandatory copy-on-write fork can never fail), and the two-tier
  ledger balances after every operation — ``assert_conserved(host_pages=
  ...)`` checks the allocator's ``swapped_pages`` against the model's own
  host-record tally after each swap cycle.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.kvcache import PagedKVCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False

CFG = get_config("internlm2-1.8b").reduced()
PAGE = 4


def make_kv(num_pages=None, capacity=4, max_blocks=4):
    return PagedKVCache(CFG, capacity, PAGE, max_blocks, num_pages)


def usable(kv):
    return kv.num_pages - kv.RESERVED


def owned_total(kv):
    return sum(len(p) for p in kv._owned.values())


# ---------------------------------------------------------------------------
# PR-3 leak regression
# ---------------------------------------------------------------------------
def test_realloc_of_owned_slot_raises():
    """alloc() on a slot that still owns pages must refuse loudly: silently
    overwriting the owned list leaked the old pages (they were neither on
    the free list nor reachable through _owned)."""
    kv = make_kv()
    assert kv.alloc(0, 2) is not None
    with pytest.raises(ValueError, match="already owns"):
        kv.alloc(0, 1)
    # the refusing call must not have touched anything
    assert kv.free_pages() + owned_total(kv) == usable(kv)
    kv.free(0)
    assert kv.free_pages() == usable(kv)


def test_admit_retire_fuzz_conservation():
    """Unshared admit/retire cycles at random sizes: the PR-3 conservation
    contract holds after every operation (the leak would break it on the
    first re-allocation pattern that used to overwrite)."""
    kv = make_kv(num_pages=PagedKVCache.RESERVED + 10, capacity=6,
                 max_blocks=4)
    rng = np.random.default_rng(0)
    live = set()
    for _ in range(500):
        if live and rng.random() < 0.45:
            slot = int(rng.choice(sorted(live)))
            kv.free(slot)
            live.discard(slot)
        else:
            slot = int(rng.integers(0, 6))
            n = int(rng.integers(1, 5))
            if slot in live:
                with pytest.raises(ValueError, match="already owns"):
                    kv.alloc(slot, n)
            elif kv.alloc(slot, n) is not None:
                live.add(slot)
        assert kv.free_pages() + owned_total(kv) == usable(kv)
        kv.assert_conserved()
    for slot in sorted(live):
        kv.free(slot)
    assert kv.free_pages() == usable(kv)


# ---------------------------------------------------------------------------
# sharing property suite
# ---------------------------------------------------------------------------
# a small prompt pool with deliberately shared prefixes: prompts are padded
# to 2-4 blocks of PAGE tokens, several sharing their leading blocks
def _prompt_pool():
    base = np.arange(1, 1 + 4 * PAGE, dtype=np.int32)
    pool = []
    for nblk in (2, 3, 4):
        for variant in range(3):
            p = base[:nblk * PAGE].copy()
            if variant:      # diverge in the last block only
                p[-1] = 200 + variant
            pool.append(p)
    return pool


PROMPTS = _prompt_pool()


class _Model:
    """Host-side mirror of the engine's admission/write montage, driving a
    PagedKVCache exactly the way ContinuousBatchingEngine does."""

    def __init__(self, num_pages, capacity):
        self.kv = make_kv(num_pages=num_pages, capacity=capacity)
        self.capacity = capacity
        # slot -> (keys, set of not-yet-written will_write blocks)
        self.live = {}
        # host-tier swap records: private-block counts, mirroring what the
        # engine's preempt() parks in the HostSwapStore
        self.host = []

    def host_pages(self):
        return sum(self.host)

    def admit(self, slot, prompt, max_new, share):
        kv = self.kv
        if slot in self.live:
            return
        keys = kv.chain_keys(prompt) if share else []
        nb = prompt.size // PAGE
        ring = prompt.size
        shared = kv.lookup_chain(keys)[:nb]
        will_write = {((ring + t) % ring) // PAGE
                      for t in range(min(max_new, ring))}
        # mirror of the sharer-count admission criterion: the allocator
        # must admit iff the pool covers fresh pages + revivals + the
        # post-admission mandatory-fork reserve (pending writes landing on
        # multi-referenced pages) — nothing coarser
        fresh = nb - len(shared)
        revived = sum(kv.ref(p) == 0 for p in shared)
        shared_set = set(shared)
        reserve = sum(1 for s2, blks in self.live.items()
                      for b in blks
                      if kv.ref(kv._owned[s2][b])
                      + (kv._owned[s2][b] in shared_set) > 1)
        reserve += sum(1 for b in will_write
                       if b < len(shared) and kv.ref(shared[b]) + 1 > 1)
        fits = kv.available() - fresh - revived >= reserve
        pages = kv.alloc_shared(slot, shared, fresh, will_write)
        assert (pages is not None) == fits, (fits, fresh, revived, reserve)
        if pages is None:
            return
        if share:
            kv.register(slot, keys)
        self.live[slot] = set(will_write)

    def write(self, slot, preserve_mode):
        """First-write one pending block (a decode round reaching it).
        preserve_mode: 0 = never, 1 = reuse-aware (engine default),
        2 = always (PR-4 behaviour)."""
        pending = self.live.get(slot)
        if not pending:
            return
        blk = min(pending)
        kv = self.kv
        page = kv._owned[slot][blk]
        pre_ref, pre_hits = kv.ref(page), kv.hits(page)
        registered = page in kv._page_key
        had_free = bool(kv._free)
        fork = kv.note_write(slot, blk, preserve=preserve_mode > 0,
                             require_hit=preserve_mode == 1)
        pending.discard(blk)
        if pre_ref > 1:
            assert fork is not None                     # mandatory CoW
        elif (registered and had_free and preserve_mode == 2):
            assert fork is not None                     # preserve-always
        elif (registered and had_free and preserve_mode == 1
                and pre_hits > 0):
            assert fork is not None                     # reuse-aware hit
        else:
            assert fork is None                         # in-place write
        if fork is not None:
            src, dst = fork
            assert src != dst
            assert self.kv.ref(dst) == 1

    def retire(self, slot):
        if slot in self.live:
            self.kv.free(slot)
            del self.live[slot]

    def swap_out(self, slot):
        """Preempt a live slot: only its private suffix (ref-1, unshared,
        unregistered pages) moves to the host tier; shared/pristine pages
        go through the ordinary free() cache/refcount paths."""
        if slot not in self.live:
            return
        n = len(self.kv.private_blocks(slot))
        self.kv.swap_out(slot, n)
        del self.live[slot]
        self.host.append(n)

    def swap_back(self, restored):
        """Close one host record: restored (the engine re-admitted it via
        alloc_shared, exercised by the admit ops) or terminally dropped
        after a poisoned-read retry budget."""
        if self.host:
            self.kv.swap_in(self.host.pop(), restored=restored)

    def crash_restore(self):
        """Crash into a *fresh* pool (the recovery path's allocator
        montage): every live slot snapshots to the host tier exactly as
        an engine checkpoint does (the per-slot swap record — private
        suffix to the host ledger, shared/pristine pages through the
        ordinary refcount paths), then the pool is rebuilt from scratch
        with the same geometry and the carried host records re-seed its
        two-tier ledger via ``adopt_swapped`` — so conservation holds
        across the snapshot boundary from the first post-recovery op."""
        for slot in sorted(self.live):
            n = len(self.kv.private_blocks(slot))
            self.kv.swap_out(slot, n)
            self.host.append(n)
        self.live.clear()
        self.kv = make_kv(num_pages=self.kv.num_pages,
                          capacity=self.capacity)
        for n in self.host:
            self.kv.adopt_swapped(n)


def _walk(m: _Model, ops) -> None:
    """Drive a model through (op, slot, *params) tuples, auditing the
    allocator — both tiers — after every step, then drain and check the
    terminal state: every non-reserved page free or cached, zero
    outstanding reserve, empty host tier."""
    for op, slot, *params in ops:
        if op == "admit":
            prompt_idx, max_new, share = params
            m.admit(slot, PROMPTS[prompt_idx], max_new=max_new, share=share)
        elif op == "write":
            m.write(slot, preserve_mode=params[0])
        elif op == "swap":
            m.swap_out(slot)
        elif op == "swapback":
            m.swap_back(restored=params[0])
        elif op == "crash":
            m.crash_restore()
        else:
            m.retire(slot)
        m.kv.assert_conserved(host_pages=m.host_pages())
    for slot in sorted(m.live):
        m.retire(slot)
    while m.host:
        m.swap_back(restored=False)
    m.kv.assert_conserved(host_pages=0)
    kv = m.kv
    assert kv.free_pages() + kv.cached_pages() == usable(kv)
    assert kv.cow_reserve == 0
    assert kv.swapped_pages == 0


def test_sharing_allocator_fuzz():
    """Seeded-random interleavings of shared/unshared admission,
    pending-block writes (mandatory CoW forks, pristine preserves under
    all three policies, in-place), retirement and preemption swap cycles
    (out to the host tier, restored or dropped back): never leak, never
    double-free, refcounts always equal the page-table references, the
    sharer-count reserve always covered, admission decisions exactly
    matching the refined criterion (the _Model re-derives it
    independently) and the two-tier ledger balanced after every op."""
    rng = np.random.default_rng(7)
    ops_menu = ("admit", "write", "retire", "swap", "swapback", "crash")
    for _ in range(150):
        m = _Model(PagedKVCache.RESERVED + int(rng.integers(6, 21)),
                   capacity=int(rng.integers(2, 7)))
        ops = []
        for _ in range(int(rng.integers(5, 41))):
            op = ops_menu[int(rng.integers(0, len(ops_menu)))]
            slot = int(rng.integers(0, m.capacity))
            if op == "admit":
                ops.append((op, slot, int(rng.integers(0, len(PROMPTS))),
                            int(rng.integers(1, 3 * PAGE + 1)),
                            bool(rng.integers(0, 2))))
            elif op == "write":
                ops.append((op, slot, int(rng.integers(0, 3))))
            elif op == "swapback":
                ops.append((op, slot, bool(rng.integers(0, 2))))
            else:
                ops.append((op, slot))
        _walk(m, ops)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_sharing_allocator_property():
    """The same state machine under Hypothesis (shrinking finds minimal
    violating interleavings; runs in CI where hypothesis is installed)."""

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        m = _Model(PagedKVCache.RESERVED + data.draw(st.integers(6, 20)),
                   capacity=data.draw(st.integers(2, 6)))
        ops = []
        for _ in range(data.draw(st.integers(5, 40))):
            op = data.draw(st.sampled_from(
                ("admit", "write", "retire", "swap", "swapback",
                 "crash")))
            slot = data.draw(st.integers(0, m.capacity - 1))
            if op == "admit":
                ops.append((op, slot,
                            data.draw(st.integers(0, len(PROMPTS) - 1)),
                            data.draw(st.integers(1, 3 * PAGE)),
                            data.draw(st.booleans())))
            elif op == "write":
                ops.append((op, slot, data.draw(st.integers(0, 2))))
            elif op == "swapback":
                ops.append((op, slot, data.draw(st.booleans())))
            else:
                ops.append((op, slot))
        _walk(m, ops)

    run()


def test_refined_reserve_admits_exact_fit():
    """The PR-4 coarse reserve charged one page per to-be-written block, so
    a request whose fresh pages exactly fill the pool was rejected even
    though none of its writes could ever fork.  The sharer-count reserve
    admits it: exclusively owned pages carry no fork obligation."""
    kv = make_kv(num_pages=PagedKVCache.RESERVED + 2, capacity=2,
                 max_blocks=2)
    pages = kv.alloc_shared(0, [], 2, {0, 1})    # coarse: 2 + 2 > 2 usable
    assert pages is not None
    assert kv.cow_reserve == 0
    assert kv.free_pages() == 0
    # both writes resolve in place (unshared, unregistered): no forks
    assert kv.note_write(0, 0) is None
    assert kv.note_write(0, 1) is None
    kv.assert_conserved()
    kv.free(0)
    assert kv.free_pages() == 2


def test_reserve_tracks_sharer_counts():
    """Reserve follows actual refcounts: joining a chain charges headroom
    for every pending write the share makes mandatory (the sharer's own and
    other slots'), a third sharer the pool cannot indemnify is rejected,
    and a resolving fork releases exactly its obligations."""
    kv = make_kv(num_pages=PagedKVCache.RESERVED + 3, capacity=3,
                 max_blocks=1)
    prompt = PROMPTS[0][:PAGE]
    keys = kv.chain_keys(prompt)
    assert kv.alloc_shared(0, [], 1, {0}) is not None
    kv.register(0, keys)
    assert kv.cow_reserve == 0                   # sole owner: no obligation
    chain = kv.lookup_chain(keys)
    assert kv.alloc_shared(1, chain, 0, {0}) is not None
    # both slots now pend a write into the ref-2 page: 2 mandatory forks
    assert kv.cow_reserve == 2
    assert kv.available() == 2
    # a third sharer would need reserve 3 > 2 available: rejected, state
    # untouched (the coarse policy would also reject, but for the wrong
    # ledger — 0 fresh + 1 will_write vs 2 available passes it)
    assert kv.alloc_shared(2, kv.lookup_chain(keys), 0, {0}) is None
    assert kv.ref(chain[0]) == 2
    kv.assert_conserved()
    # slot 1 writes: mandatory fork consumes one reserved page and releases
    # both obligations (slot 0 is sole owner afterwards)
    fork = kv.note_write(1, 0)
    assert fork is not None and fork[0] == chain[0]
    assert kv.cow_reserve == 0
    kv.assert_conserved()
    kv.free(0)
    kv.free(1)
    kv.assert_conserved()


def test_pristine_preserve_is_reuse_aware():
    """A sole-owner write into a registered page copies the pristine page
    only once the chain has recorded a sharing hit; require_hit=False
    restores the PR-4 always-preserve policy."""
    kv = make_kv(num_pages=PagedKVCache.RESERVED + 6, capacity=3)
    prompt = PROMPTS[0][:2 * PAGE]
    keys = kv.chain_keys(prompt)
    kv.alloc_shared(0, [], 2, {0})
    kv.register(0, keys)
    assert kv.hits(kv.lookup_chain(keys)[0]) == 0
    # share-nothing: the write unregisters instead of copying
    assert kv.note_write(0, 0) is None
    assert kv.pristine_forks == 0
    assert len(kv.lookup_chain(keys)) == 0       # chain head gone
    kv.free(0)
    # re-admit and re-register, then record a hit via a sharer
    kv.alloc_shared(0, [], 2, {0})
    kv.register(0, keys)
    chain = kv.lookup_chain(keys)
    kv.alloc_shared(1, chain, 0, set())
    assert kv.hits(chain[0]) == 1
    kv.free(1)                                   # hit persists past retire
    fork = kv.note_write(0, 0)                   # now worth preserving
    assert fork is not None and kv.pristine_forks == 1
    assert kv.lookup_chain(keys) == chain        # pristine copy cached
    kv.free(0)
    kv.assert_conserved()
    # the "always" policy preserves without evidence
    kv2 = make_kv(num_pages=PagedKVCache.RESERVED + 6, capacity=3)
    kv2.alloc_shared(0, [], 2, {0})
    kv2.register(0, kv2.chain_keys(prompt))
    assert kv2.note_write(0, 0, require_hit=False) is not None
    assert kv2.pristine_forks == 1


def test_shared_admission_and_cow_fork_lifecycle():
    """Deterministic walk through the sharing lifecycle: share, fork on
    write, pristine retention, revival from cache, eviction."""
    kv = make_kv(num_pages=PagedKVCache.RESERVED + 8, capacity=4)
    prompt = PROMPTS[0][:2 * PAGE]
    keys = kv.chain_keys(prompt)
    # original admission registers its blocks
    pages0 = kv.alloc_shared(0, [], 2, {0})
    kv.register(0, keys)
    assert kv.lookup_chain(keys) == list(pages0)
    # second request shares the full chain (refcounts 2)
    pages1 = kv.alloc_shared(1, kv.lookup_chain(keys), 0, {0})
    assert list(pages1) == list(pages0)
    assert kv.ref(pages0[0]) == 2
    assert kv.pages_shared == 2
    # slot 1 writes block 0: mandatory fork, slot 0 untouched
    fork = kv.note_write(1, 0)
    assert fork is not None and fork[0] == pages0[0]
    assert kv.ref(pages0[0]) == 1 and kv.ref(fork[1]) == 1
    assert kv.cow_forks == 1
    # slot 0 writes block 0: sole owner of a registered page -> preserve
    fork0 = kv.note_write(0, 0)
    assert fork0 is not None and kv.pristine_forks == 1
    assert kv.ref(pages0[0]) == 0 and kv.cached_pages() == 1
    # the pristine chain is still shareable after both owners retire
    kv.free(0)
    kv.free(1)
    assert kv.lookup_chain(keys) == list(pages0)
    revived = kv.alloc_shared(2, kv.lookup_chain(keys), 0, set())
    assert list(revived) == list(pages0)
    assert kv.ref(pages0[0]) == 1
    kv.free(2)
    kv.assert_conserved()
    # pool pressure evicts cached pristine pages (leaf-most first)
    taken = [kv._take_page() for _ in range(kv.free_pages())]
    assert kv.cached_pages() == 2
    extra = kv._take_page()          # must come from the cached set
    assert kv.cached_pages() == 1
    assert len(kv.lookup_chain(keys)) == 1      # chain truncated, not torn
    kv._free.extend(taken + [extra])
