"""Sharder logical-rule resolution + an 8-device pjit integration test run in
a subprocess (this process keeps its single CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.sharding import Sharder


def test_null_sharder_is_identity():
    import jax.numpy as jnp
    sh = Sharder(None)
    x = jnp.ones((4, 4))
    assert sh.constrain(x, ("batch", None)) is x


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import Sharder
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.models import params as pp
    from repro.training.optimizer import make_optimizer
    from repro.training.train_loop import build_train_step, init_train_state

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = Sharder(mesh, fsdp=True, seq_shard=False)

    out = {}
    # rule resolution: divisible dims shard, indivisible replicate
    out["heads_div"] = str(sh.spec(("fsdp", "heads"), (256, 64)))
    out["heads_indiv"] = str(sh.spec((None, "heads"), (256, 6)))
    out["kvseq_fallback"] = str(sh.spec(("batch", "kvseq"), (1, 64)))
    out["kvseq_normal"] = str(sh.spec(("batch", "kvseq"), (8, 64)))
    out["used_once"] = str(sh.spec(("heads", "ff"), (64, 64)))

    # end-to-end: reduced arch trains on the 2x4 mesh with sharded params
    cfg = get_config("internlm2-1.8b").reduced()
    bundle = build_model(cfg)
    boxed = bundle.init(jax.random.PRNGKey(0))
    params, axes = pp.split(boxed)
    from repro.distributed.sharding import param_shardings
    shards = param_shardings(sh, axes, jax.eval_shape(lambda: params))
    params = jax.tree.map(
        lambda v, s: jax.device_put(v, s) if s is not None else v,
        params, shards)
    opt = make_optimizer(cfg)
    state = init_train_state(bundle, opt, params)
    step = jax.jit(build_train_step(bundle, sh, opt))
    import numpy as np
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(1, 200, (8, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(1, 200, (8, 32)), jnp.int32)}
    with mesh:
        state, metrics = step(state, batch)
    out["loss"] = float(metrics["loss"])
    out["finite"] = bool(jnp.isfinite(metrics["loss"]))
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_result():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_spec_resolution_on_mesh(subproc_result):
    o = subproc_result
    assert o["heads_div"] == "PartitionSpec('data', 'model')"
    assert o["heads_indiv"] == "PartitionSpec(None, None)"
    # batch=1 frees data; kvseq takes model (+data fallback set)
    assert "model" in o["kvseq_fallback"]
    assert o["kvseq_normal"].startswith("PartitionSpec('data',")
    # an axis is used at most once per spec
    assert o["used_once"] == "PartitionSpec('model', None)"


def test_sharded_train_step_runs(subproc_result):
    assert subproc_result["finite"]
    assert subproc_result["loss"] > 0
