"""Per-architecture smoke tests: REDUCED same-family configs run one train
step + prefill + decode on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import null_sharder
from repro.models import params as pp
from repro.models.model import build_model
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import build_train_step, init_train_state


def _batch(cfg, rng, B=2, S=32, labels=True):
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)}
    if labels:
        batch["labels"] = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, 1024)), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    opt = make_optimizer(cfg)
    state = init_train_state(bundle, opt, params)
    step = jax.jit(build_train_step(bundle, sh, opt))
    state, metrics = step(state, _batch(cfg, rng))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    assert loss > 0
    assert int(state["step"]) == 1
    # params actually changed
    before = pp.count_params(params)
    after = pp.count_params(state["params"])
    assert before == after


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S, labels=False)
    logits, caches, idx = bundle.prefill_fn(params, batch, sh)
    from repro.models.layers import pad_vocab
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches2 = bundle.decode_fn(params, tok, caches, idx, sh)
    assert logits2.shape == logits.shape
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "h2o-danube-1.8b",
                                  "mamba2-2.7b", "jamba-1.5-large-398b",
                                  "whisper-base", "olmoe-1b-7b"])
def test_decode_consistent_with_full_forward(arch, rng):
    """Decoding token S with the prefill cache == full forward over S+1."""
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S + 1)), jnp.int32)
    extra = _batch(cfg, rng, B, S, labels=False)
    batch = dict(extra, tokens=toks[:, :S])
    full = dict(extra, tokens=toks)
    _, caches, idx = bundle.prefill_fn(params, batch, sh)
    ld, _ = bundle.decode_fn(params, toks[:, S:S + 1], caches, idx, sh)
    lf, _, _ = bundle.prefill_fn(params, full, sh)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf),
                               rtol=2e-2, atol=2e-2)


def test_block_schedules():
    jamba = get_config("jamba-1.5-large-398b")
    sched = jamba.block_schedule()
    assert len(sched) == 72
    attn_layers = [i for i, (m, _) in enumerate(sched) if m == "attn"]
    assert len(attn_layers) == 9          # 1:7 interleave
    assert all(i % 8 == 4 for i in attn_layers)
    moe_layers = [i for i, (_, m) in enumerate(sched) if m == "moe"]
    assert len(moe_layers) == 36          # every other layer
    assert jamba.stage_period == 8

    mamba = get_config("mamba2-2.7b")
    assert all(m == "mamba" for m, _ in mamba.block_schedule())
    assert all(p == "none" for _, p in mamba.block_schedule())

    llama4 = get_config("llama4-maverick-400b-a17b")
    assert all(s == ("attn", "moe") for s in llama4.block_schedule())


def _serving_pair(cfg, capacity=2):
    """One (blocking engine, continuous engine) pair on the reduced cfg."""
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine

    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    ceng = ContinuousBatchingEngine(engine, capacity=capacity, page_size=8,
                                    inner_steps=3, max_prompt_len=16)
    return engine, ceng


def _blocking_oracle(engine, ceng, req):
    """Blocking generate under the continuous path's conventions: prompt
    left-padded to its admission bucket, same resolved per-request extras."""
    from repro.serving.engine import resolve_extra_inputs

    b = ceng.bucket_len(req.prompt.size)
    padded = np.zeros((1, b), np.int32)
    padded[0, b - req.prompt.size:] = req.prompt
    extra = {k: np.asarray(v)[None] for k, v in
             resolve_extra_inputs(engine.cfg, req).items()}
    return engine.generate(padded, max_new_tokens=req.max_new_tokens,
                           extra_inputs=extra or None,
                           seed=req.seed).tokens[0]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_continuous_serving(arch, rng):
    """Every config serves mode="continuous" through the paged-state pool
    (PR 9).  Three ragged requests over two slots force slot eviction and
    refill mid-drain; non-MoE archs must be token-exact against the
    blocking oracle (MoE capacity routing couples batch rows, so those
    assert completion + finiteness instead, per ``supported_modes``), and
    the per-kind page/record ledger must balance at drain."""
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.multitenant import Request

    cfg = get_config(arch).reduced()
    modes = ContinuousBatchingEngine.supported_modes(cfg)
    assert modes["continuous"]["supported"]
    engine, ceng = _serving_pair(cfg)
    reqs = []
    for i, n in enumerate((5, 9, 13)):
        extra = None
        if cfg.num_patches:
            # distinct per-request images: rows must never share pages
            extra = {"patch_embeds": rng.normal(
                size=(cfg.num_patches, 1024)).astype(np.float32)}
        reqs.append(Request(f"t{i}", rng.integers(
            1, cfg.vocab_size, n).astype(np.int32), max_new_tokens=6,
            extra_inputs=extra))
    done = {req.tenant: toks for req, toks in ceng.run_all(list(reqs))}
    assert not ceng.rejected
    for req in reqs:
        toks = done[req.tenant]
        assert toks.size == req.max_new_tokens
        assert np.isfinite(toks).all(), arch
        if modes["continuous"]["exactness"] == "bitwise":
            np.testing.assert_array_equal(
                _blocking_oracle(engine, ceng, req), toks, err_msg=arch)
    ceng.kv.assert_conserved(
        host_pages={k.name: 0 for k in ceng.kv.state_kinds})


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "jamba-1.5-large-398b"])
def test_smoke_ssm_hybrid_preempt_restore(arch, rng):
    """SSM and hybrid rows are ordinary preemption victims (PR 9): their
    slot state checkpoints to fixed-width host records on swap-out and
    scatters back on restore.  A tier-0 arrival against a full slot table
    must preempt, every request must complete to full length, and mamba2
    (non-MoE) must resume token-exactly vs the blocking oracle."""
    from repro.serving.multitenant import MultiTenantScheduler, Request

    cfg = get_config(arch).reduced()
    engine, ceng = _serving_pair(cfg)
    assert ceng.can_preempt
    assert "ssm" in [k.name for k in ceng.state_kinds]
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng, preemption=True)
    los = [Request(f"lo{i}", rng.integers(1, cfg.vocab_size,
                                          9).astype(np.int32),
                   max_new_tokens=12, priority=1) for i in range(2)]
    hi = Request("hi", rng.integers(1, cfg.vocab_size, 6).astype(np.int32),
                 max_new_tokens=3, priority=0)
    for r in los:
        sched.submit(r)
    sched.step()
    sched.submit(hi)
    out = {r.tenant: r for r in sched.drain()}
    assert ceng.preemptions > 0 and ceng.restores > 0
    assert len(ceng.swap_store) == 0
    for req in [*los, hi]:
        resp = out[req.tenant]
        assert resp.outcome == "completed", arch
        assert resp.tokens.size == req.max_new_tokens
        assert np.isfinite(resp.tokens).all(), arch
        if arch == "mamba2-2.7b":
            np.testing.assert_array_equal(
                _blocking_oracle(engine, ceng, req), resp.tokens)
    ceng.kv.assert_conserved(host_pages=ceng.swap_store.pages_by_kind())


def test_smoke_ssm_checkpoint_roundtrip_bitwise(rng):
    """The checkpoint/restore hooks themselves: gathering a slot's row out
    of an SSM state pytree and scattering it back is bitwise lossless and
    leaves every other slot untouched."""
    from repro.models import ssm as ssm_mod

    state = {"conv": jnp.asarray(rng.normal(size=(3, 4, 5, 7)), jnp.float32),
             "ssm": {"h": jnp.asarray(rng.normal(size=(3, 4, 2, 8)),
                                      jnp.float32)}}
    rec = ssm_mod.checkpoint_slot_state(state, 2)
    clobbered = jax.tree.map(lambda l: l.at[:, 2].set(0.0), state)
    restored = ssm_mod.restore_slot_state(clobbered, 2, rec)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_smoke_sliding_window_prefix_sharing(rng):
    """Sliding-window archs re-enter the prefix trie via window-phase chain
    keys (PR 9): a byte-identical refresh admitted while the original's
    ring is still pristine shares its pages and skips prefill entirely,
    the original's first ring write CoW-forks the shared pages, and both
    rows stay token-exact vs blocking.  (Every ring block is decode-
    written, so the pool must hold fork headroom — hence the explicit
    ``num_pages`` — and retired SWA rings leave nothing pristine to share,
    unlike full-attention prompts.)"""
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import Request

    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window is not None
    modes = ContinuousBatchingEngine.supported_modes(cfg)
    assert modes["continuous"]["window_phase_keys"]
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=4,
                                    num_pages=16, inner_steps=3,
                                    max_prompt_len=16)
    prompt = rng.integers(1, cfg.vocab_size, 13).astype(np.int32)
    reqs = [Request(f"s{i}", prompt.copy(), max_new_tokens=6)
            for i in range(2)]
    assert ceng.try_admit_batch([reqs[0]]) == [True]
    assert ceng.try_admit_batch([reqs[1]]) == [True]   # the refresh
    assert ceng.kv.pages_shared > 0
    assert ceng.prefill_skips >= 1
    done = {}
    while ceng.active_count():
        for r, toks, _ in ceng.collect(ceng.dispatch_round()).finished:
            done[r.tenant] = toks
    assert ceng.kv.cow_forks > 0
    for req in reqs:
        np.testing.assert_array_equal(
            _blocking_oracle(engine, ceng, req), done[req.tenant])
    ceng.kv.assert_conserved(
        host_pages={k.name: 0 for k in ceng.kv.state_kinds})


def test_param_counts_plausible():
    # reduced configs stay tiny; full configs match the pool's labels
    import math
    cfg = get_config("internlm2-1.8b")
    bundle = build_model(cfg)
    sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    vals, _ = pp.split(sds)
    n = sum(math.prod(l.shape) for l in jax.tree.leaves(vals))
    assert 1.5e9 < n < 2.5e9, n


def test_full_config_param_counts():
    import math
    expect = {"qwen3-32b": (30e9, 36e9), "mistral-large-123b": (115e9, 130e9),
              "olmoe-1b-7b": (6e9, 8e9), "mamba2-2.7b": (2.4e9, 3.1e9),
              "jamba-1.5-large-398b": (370e9, 420e9)}
    for arch, (lo, hi) in expect.items():
        bundle = build_model(get_config(arch))
        vals, _ = pp.split(jax.eval_shape(bundle.init, jax.random.PRNGKey(0)))
        n = sum(math.prod(l.shape) for l in jax.tree.leaves(vals))
        assert lo < n < hi, (arch, n)
