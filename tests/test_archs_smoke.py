"""Per-architecture smoke tests: REDUCED same-family configs run one train
step + prefill + decode on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import null_sharder
from repro.models import params as pp
from repro.models.model import build_model
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import build_train_step, init_train_state


def _batch(cfg, rng, B=2, S=32, labels=True):
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)}
    if labels:
        batch["labels"] = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, 1024)), jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    opt = make_optimizer(cfg)
    state = init_train_state(bundle, opt, params)
    step = jax.jit(build_train_step(bundle, sh, opt))
    state, metrics = step(state, _batch(cfg, rng))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    assert loss > 0
    assert int(state["step"]) == 1
    # params actually changed
    before = pp.count_params(params)
    after = pp.count_params(state["params"])
    assert before == after


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S, labels=False)
    logits, caches, idx = bundle.prefill_fn(params, batch, sh)
    from repro.models.layers import pad_vocab
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches2 = bundle.decode_fn(params, tok, caches, idx, sh)
    assert logits2.shape == logits.shape
    assert np.isfinite(np.asarray(logits2)).all(), arch


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "h2o-danube-1.8b",
                                  "mamba2-2.7b", "jamba-1.5-large-398b",
                                  "whisper-base", "olmoe-1b-7b"])
def test_decode_consistent_with_full_forward(arch, rng):
    """Decoding token S with the prefill cache == full forward over S+1."""
    cfg = get_config(arch).reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    B, S = 2, 24
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S + 1)), jnp.int32)
    extra = _batch(cfg, rng, B, S, labels=False)
    batch = dict(extra, tokens=toks[:, :S])
    full = dict(extra, tokens=toks)
    _, caches, idx = bundle.prefill_fn(params, batch, sh)
    ld, _ = bundle.decode_fn(params, toks[:, S:S + 1], caches, idx, sh)
    lf, _, _ = bundle.prefill_fn(params, full, sh)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf),
                               rtol=2e-2, atol=2e-2)


def test_block_schedules():
    jamba = get_config("jamba-1.5-large-398b")
    sched = jamba.block_schedule()
    assert len(sched) == 72
    attn_layers = [i for i, (m, _) in enumerate(sched) if m == "attn"]
    assert len(attn_layers) == 9          # 1:7 interleave
    assert all(i % 8 == 4 for i in attn_layers)
    moe_layers = [i for i, (_, m) in enumerate(sched) if m == "moe"]
    assert len(moe_layers) == 36          # every other layer
    assert jamba.stage_period == 8

    mamba = get_config("mamba2-2.7b")
    assert all(m == "mamba" for m, _ in mamba.block_schedule())
    assert all(p == "none" for _, p in mamba.block_schedule())

    llama4 = get_config("llama4-maverick-400b-a17b")
    assert all(s == ("attn", "moe") for s in llama4.block_schedule())


def test_param_counts_plausible():
    # reduced configs stay tiny; full configs match the pool's labels
    import math
    cfg = get_config("internlm2-1.8b")
    bundle = build_model(cfg)
    sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    vals, _ = pp.split(sds)
    n = sum(math.prod(l.shape) for l in jax.tree.leaves(vals))
    assert 1.5e9 < n < 2.5e9, n


def test_full_config_param_counts():
    import math
    expect = {"qwen3-32b": (30e9, 36e9), "mistral-large-123b": (115e9, 130e9),
              "olmoe-1b-7b": (6e9, 8e9), "mamba2-2.7b": (2.4e9, 3.1e9),
              "jamba-1.5-large-398b": (370e9, 420e9)}
    for arch, (lo, hi) in expect.items():
        bundle = build_model(get_config(arch))
        vals, _ = pp.split(jax.eval_shape(bundle.init, jax.random.PRNGKey(0)))
        n = sum(math.prod(l.shape) for l in jax.tree.leaves(vals))
        assert lo < n < hi, (arch, n)
