#!/usr/bin/env bash
# Tier-1 test entrypoint: one command for local runs and CI.
#
#     tests/run_tier1.sh                 # whole suite
#     tests/run_tier1.sh tests/test_serving_overlap.py -k subprocess
#
# Sets PYTHONPATH=src and forces 8 host devices (the same XLA flag the
# subprocess overlap tests in test_pipeline.py / test_serving_overlap.py
# append for their children — it must precede jax initialisation, hence an
# env var here rather than a fixture).  Extra args pass through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# append: the last repetition of the flag wins if the caller already set one
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8"
exec python -m pytest -x -q "$@"
