"""Overload survival: priority admission, preemption/swap, faults, shedding.

The PR-6 robustness contracts on top of the continuous-batching stack:

* **token-exact preemption** — a request swapped out to the host tier and
  restored later decodes bitwise identically to an uninterrupted run:
  greedy and seeded sampling, pure attention and sliding-window attention,
  including a victim holding trie-shared (CoW) prefix pages;
* **every state kind swaps (PR 9)** — SSM/hybrid and encoder-decoder rows
  are ordinary preemption victims: slot-table SSM state checkpoints as
  fixed-width host records and cross-attention pages snapshot like
  attention blocks, so an SSM victim's restored decode is token-exact too
  (the per-kind two-tier ledger audits all of it);
* **every request terminates** — a 2x-oversubscribed burst, load shedding
  past ``max_backlog``, and injected faults (dropped rounds, stalled
  admissions, poisoned swap reads) all end in exactly one explicit
  terminal outcome per request — completed, rejected or failed — never an
  exception out of ``drain()`` and never a hang;
* **two-tier conservation** — ``assert_conserved(host_pages=...)`` holds
  at every drain, including after terminal drops of poisoned records;
* the trace harness (``benchmarks/overload.py``) is deterministic and
  drives the scheduler to full termination.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.fault import FaultPlane
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request


def _make_engine(arch: str) -> ServingEngine:
    cfg = get_config(arch).reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params)


@pytest.fixture(scope="module")
def engine():
    return _make_engine("internlm2-1.8b")


@pytest.fixture(scope="module")
def pceng(engine):
    # capacity 2 with ample pages: the *slot table* is the contended
    # resource, so a tier-0 arrival against a full table exercises the
    # slot-exhaustion preemption path (not ordinary page-pressure waits)
    return ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    num_pages=24, inner_steps=4,
                                    max_prompt_len=16)


def _oracle(engine, ceng, req):
    b = ceng.bucket_len(req.prompt.size)
    padded = np.zeros((1, b), np.int32)
    padded[0, b - req.prompt.size:] = req.prompt
    return engine.generate(padded, max_new_tokens=req.max_new_tokens,
                           seed=req.seed).tokens[0]


def _sched(engine, ceng, **kw):
    kw.setdefault("preemption", True)
    return MultiTenantScheduler(engine, mode="continuous",
                                continuous_engine=ceng, **kw)


def _clone(req: Request) -> Request:
    return Request(req.tenant, req.prompt.copy(), req.max_new_tokens,
                   temperature=req.temperature, top_k=req.top_k,
                   seed=req.seed, priority=req.priority)


def _preempt_mix(engine, ceng, reqs_lo, req_hi, **sched_kw):
    """Fill every slot with long tier-1 rows, dispatch a round, then land a
    tier-0 arrival against the full slot table.  Asserts a preemption and a
    restore actually happened plus two-tier conservation at drain; returns
    responses keyed by tenant."""
    sched = _sched(engine, ceng, **sched_kw)
    pre0, res0 = ceng.preemptions, ceng.restores
    for r in reqs_lo:
        sched.submit(r)
    sched.step()
    sched.submit(req_hi)
    out = sched.drain()
    assert ceng.preemptions > pre0
    assert ceng.restores > res0
    assert len(ceng.swap_store) == 0
    ceng.kv.assert_conserved(host_pages=ceng.swap_store.pages())
    assert len(out) == len(reqs_lo) + 1
    return sched, {r.tenant: r for r in out}


def test_preempt_restore_token_exact_greedy(engine, pceng, rng):
    """The tentpole exactness contract: the swapped-out victim's restored
    decode is bitwise identical to blocking generate on the same prompt —
    indistinguishable from never having been preempted."""
    cfg = engine.cfg
    los = [Request(f"lo{i}", rng.integers(1, cfg.vocab_size,
                                          12).astype(np.int32),
                   max_new_tokens=40, priority=1) for i in range(2)]
    hi = Request("hi", rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=4, priority=0)
    sched, by_tenant = _preempt_mix(engine, pceng, los, hi)
    for req in [*los, hi]:
        resp = by_tenant[req.tenant]
        assert resp.outcome == "completed"
        assert resp.ttft_s is not None and resp.ttft_s >= 0.0
        np.testing.assert_array_equal(_oracle(engine, pceng, req),
                                      resp.tokens)
    # the victim's Response records its swap count; somebody was swapped
    assert sum(r.preemptions for r in by_tenant.values()) >= 1
    assert sum(s["preempted"] for s in sched.stats.values()) >= 1
    # fixed-width snapshots: the restore jit traces once, ever
    assert pceng.restore_traces == 1


def test_preempt_restore_token_exact_seeded_sampling(engine, pceng, rng):
    """Seeded temperature sampling across a swap cycle: the PRNG schedule
    is fold_in(key, lstep) per emitted token and lstep is restored bitwise,
    so the sampled continuation must match an uninterrupted run of the
    same request on the same engine."""
    cfg = engine.cfg
    los = [Request(f"slo{i}", rng.integers(1, cfg.vocab_size,
                                           12).astype(np.int32),
                   max_new_tokens=36, priority=1, temperature=1.1,
                   top_k=20, seed=5 + i) for i in range(2)]
    hi = Request("shi", rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=4, priority=0, temperature=0.9, seed=11)
    # uninterrupted reference first (one request at a time: no contention,
    # no preemption possible), on the same engine + jit caches
    want = {r.tenant: t for c in [*los, hi]
            for r, t in pceng.run_all([_clone(c)])}
    _, by_tenant = _preempt_mix(engine, pceng, los, hi)
    for req in [*los, hi]:
        resp = by_tenant[req.tenant]
        assert resp.outcome == "completed"
        np.testing.assert_array_equal(want[req.tenant], resp.tokens)


def test_preempt_victim_with_shared_prefix_token_exact(engine, pceng, rng):
    """Preempting a row whose prompt blocks are trie-shared with a live
    neighbour: only the private suffix moves to the host tier (the shared
    pages stay device-resident under the other reader), and both rows —
    victim and survivor — stay token-exact."""
    cfg = engine.cfg
    sys_prompt = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)
    mk = lambda t: Request(t, np.concatenate(
        [sys_prompt, rng.integers(1, cfg.vocab_size, 4).astype(np.int32)]),
        max_new_tokens=40, priority=1)
    los = [mk("cow0"), mk("cow1")]
    hi = Request("cowhi", rng.integers(1, cfg.vocab_size,
                                       8).astype(np.int32),
                 max_new_tokens=4, priority=0)
    shared0 = pceng.kv.pages_shared
    _, by_tenant = _preempt_mix(engine, pceng, los, hi)
    assert pceng.kv.pages_shared > shared0        # the prefix actually shared
    for req in [*los, hi]:
        resp = by_tenant[req.tenant]
        assert resp.outcome == "completed"
        np.testing.assert_array_equal(_oracle(engine, pceng, req),
                                      resp.tokens)


def test_sliding_window_preempt_restore_token_exact(rng):
    """Sliding-window attention family: the decode ring wraps inside the
    window, so the swap snapshot must carry ring-wrapped block contents and
    positions exactly.  Same contract, different cache geometry."""
    engine = _make_engine("h2o-danube-1.8b")
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    num_pages=24, inner_steps=4,
                                    max_prompt_len=16)
    assert ceng.can_preempt
    cfg = engine.cfg
    los = [Request(f"wlo{i}", rng.integers(1, cfg.vocab_size,
                                           12).astype(np.int32),
                   max_new_tokens=28, priority=1) for i in range(2)]
    hi = Request("whi", rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=3, priority=0)
    _, by_tenant = _preempt_mix(engine, ceng, los, hi)
    for req in [*los, hi]:
        resp = by_tenant[req.tenant]
        assert resp.outcome == "completed"
        np.testing.assert_array_equal(_oracle(engine, ceng, req),
                                      resp.tokens)


def test_ssm_preempt_restore_token_exact(rng):
    """Pure-SSM family (PR 9): slot-table SSM state checkpoints as fixed-
    width host records on swap-out and scatters back bitwise on restore, so
    an SSM victim's resumed decode is token-exact — a priority arrival
    evicts a row instead of waiting, exactly like the attention families."""
    engine = _make_engine("mamba2-2.7b")
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=3, max_prompt_len=16)
    assert ceng.can_preempt
    assert [k.name for k in ceng.state_kinds] == ["ssm"]
    cfg = engine.cfg
    los = [Request(f"mlo{i}", rng.integers(1, cfg.vocab_size,
                                           9).astype(np.int32),
                   max_new_tokens=12, priority=1) for i in range(2)]
    hi = Request("mhi", rng.integers(1, cfg.vocab_size, 6).astype(np.int32),
                 max_new_tokens=3, priority=0)
    sched, by_tenant = _preempt_mix(engine, ceng, los, hi)
    assert sum(s["preempted"] for s in sched.stats.values()) >= 1
    for req in [*los, hi]:
        resp = by_tenant[req.tenant]
        assert resp.outcome == "completed"
        np.testing.assert_array_equal(_oracle(engine, ceng, req),
                                      resp.tokens)
    ceng.kv.assert_conserved(host_pages=ceng.swap_store.pages_by_kind())


def test_burst_2x_oversubscribed_terminates(engine, pceng, rng):
    """The pool-exhaustion regression: a burst demanding ~2x the page pool
    (and 4x the slot table) drains without an exception, every request in
    exactly one terminal state and the two-tier ledger balanced."""
    cfg = engine.cfg
    reqs = [Request(f"b{i}", rng.integers(1, cfg.vocab_size,
                                          12).astype(np.int32),
                    max_new_tokens=10, priority=0 if i % 4 == 3 else 1)
            for i in range(24)]
    # 2x oversubscribed by pages (24 rings x 2 blocks vs a 24-page pool),
    # 12x by slots
    demand = sum(pceng.kv.blocks_for(pceng._ring_len(
        pceng.bucket_len(r.prompt.size))) for r in reqs)
    assert demand >= 2 * pceng.kv.num_pages
    sched = _sched(engine, pceng)
    for r in reqs:
        sched.submit(r)
    out = sched.drain()
    assert len(out) == len(reqs)
    assert {r.outcome for r in out} <= {"completed", "rejected", "failed"}
    assert all(r.outcome == "completed" for r in out)   # pool cycles fine
    assert sum(r.tokens.size for r in out) == \
        sum(r.max_new_tokens for r in reqs)
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())


def test_load_shed_past_max_backlog(engine, pceng, rng):
    """Backlog beyond the SLO bound sheds the lowest-priority queued work
    with an explicit REJECTED outcome (never silently dropped), keeping
    tier-0 requests; shed counts land in per-tenant stats."""
    cfg = engine.cfg
    reqs = [Request(f"s{i}", rng.integers(1, cfg.vocab_size,
                                          8).astype(np.int32),
                    max_new_tokens=4, priority=0 if i == 2 else 1)
            for i in range(6)]
    sched = _sched(engine, pceng, max_backlog=2)
    for r in reqs:
        sched.submit(r)
    out = sched.drain()
    assert len(out) == 6
    shed = sum(s["shed"] for s in sched.stats.values())
    assert shed == 4
    by_tenant = {r.tenant: r for r in out}
    assert by_tenant["s2"].outcome == "completed"      # tier 0 never shed
    assert sum(r.outcome == "rejected" for r in out) == 4
    for resp in out:
        if resp.outcome == "rejected":
            assert resp.tokens.size == 0
            assert resp.priority == 1


def test_fault_injection_survives_to_completion(engine, pceng, rng):
    """Dropped rounds and stalled admissions below the failure limits are
    retried transparently: every request still completes token-exactly and
    the survived-fault count matches the injector's ledger."""
    cfg = engine.cfg
    plane = FaultPlane(drop_round_every=4, stall_admission_every=3)
    pceng.fault_plane = plane
    try:
        sched = _sched(engine, pceng)
        reqs = [Request(f"f{i}", rng.integers(1, cfg.vocab_size,
                                              10).astype(np.int32),
                        max_new_tokens=9, priority=i % 2)
                for i in range(4)]
        for r in reqs:
            sched.submit(r)
        out = sched.drain()
    finally:
        pceng.fault_plane = None
    assert len(out) == 4
    assert all(r.outcome == "completed" for r in out)
    assert plane.total_injected() > 0
    assert sched.faults_survived == plane.total_injected()
    by_tenant = {r.tenant: r for r in out}
    for req in reqs:
        np.testing.assert_array_equal(_oracle(engine, pceng, req),
                                      by_tenant[req.tenant].tokens)
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())


def test_poisoned_swap_read_fails_terminally(engine, pceng, rng):
    """A swap record whose every read is poisoned exhausts the bounded
    retry budget and fails *that request only* — explicit FAILED outcome,
    host record dropped, everyone else completes, ledger balanced."""
    cfg = engine.cfg
    plane = FaultPlane(poison_swap_every=1)       # every fetch poisoned
    pceng.swap_store.fault_plane = plane
    drops0 = pceng.kv.swap_drops
    try:
        los = [Request(f"p{i}", rng.integers(1, cfg.vocab_size,
                                             12).astype(np.int32),
                       max_new_tokens=28, priority=1) for i in range(2)]
        hi = Request("phi", rng.integers(1, cfg.vocab_size,
                                         8).astype(np.int32),
                     max_new_tokens=4, priority=0)
        sched = _sched(engine, pceng)
        for r in los:
            sched.submit(r)
        sched.step()
        sched.submit(hi)
        out = sched.drain()
    finally:
        pceng.swap_store.fault_plane = None
    assert len(out) == 3
    outcomes = sorted(r.outcome for r in out)
    assert outcomes == ["completed", "completed", "failed"]
    failed, = [r for r in out if r.outcome == "failed"]
    assert failed.tokens.size == 0
    assert failed.preemptions >= 1
    assert pceng.kv.swap_drops > drops0
    assert len(pceng.swap_store) == 0
    assert sched.faults_survived > 0
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())


def test_heartbeat_suspects_counted(engine, pceng, rng):
    """A zero-timeout heartbeat flags every scheduler step: the monitor is
    actually wired into the continuous round loop (suspects counted), and
    progress continues regardless — suspicion is observability, not a
    kill switch."""
    sched = _sched(engine, pceng, heartbeat_timeout_s=0.0)
    sched.submit(Request("h", rng.integers(1, engine.cfg.vocab_size,
                                           8).astype(np.int32),
                         max_new_tokens=4))
    out = sched.drain()
    assert [r.outcome for r in out] == ["completed"]
    assert sched.heartbeat_suspects > 0
    assert sched.heartbeat.missed == sched.heartbeat_suspects


def test_harness_trace_deterministic_and_drives(engine, pceng):
    """benchmarks/overload.py: identical seeds give identical traces, and
    the closed-loop driver runs a mixed-priority trace to full termination
    through the real scheduler."""
    from benchmarks.overload import drive, make_trace

    a = make_trace(6, seed=3, mean_gap_s=0.01, vocab=engine.cfg.vocab_size,
                   hi_every=3, lo_steps=(6, 12))
    b = make_trace(6, seed=3, mean_gap_s=0.01, vocab=engine.cfg.vocab_size,
                   hi_every=3, lo_steps=(6, 12))
    assert len(a) == 6
    for sa, sb in zip(a, b):
        assert sa["arrival"] == sb["arrival"]
        assert sa["priority"] == sb["priority"]
        np.testing.assert_array_equal(sa["prompt"], sb["prompt"])
    assert {s["priority"] for s in a} == {0, 1}
    assert all(s["prompt"].size <= 16 for s in a)

    sched = _sched(engine, pceng, max_backlog=12)
    out = drive(sched, a, open_loop=False)
    assert len(out) == 6
    assert {r.outcome for r in out} <= {"completed", "rejected", "failed"}
    assert sched.pending() == 0
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())


def test_deadline_miss_shed_at_pick(engine, pceng, rng):
    """A queued request whose absolute deadline already passed is shed
    terminally at pick time (REJECTED, counted in the shed stat) instead
    of burning slots and pages on work that can no longer meet its SLO;
    fresh work behind it is untouched."""
    import time

    cfg = engine.cfg
    sched = _sched(engine, pceng)
    late = Request("late", rng.integers(1, cfg.vocab_size,
                                        8).astype(np.int32),
                   max_new_tokens=4, deadline_s=time.perf_counter() - 1.0)
    ok = Request("ok", rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                 max_new_tokens=4)
    sched.submit(late)
    sched.submit(ok)
    by_tenant = {r.tenant: r for r in sched.drain()}
    assert by_tenant["late"].outcome == "rejected"
    assert by_tenant["late"].tokens.size == 0
    assert sched.stats["late"]["shed"] == 1
    assert by_tenant["ok"].outcome == "completed"
    np.testing.assert_array_equal(_oracle(engine, pceng, ok),
                                  by_tenant["ok"].tokens)
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())


def test_live_priorities_accessor(engine, pceng, rng):
    """``live_priorities()`` reports the priority of every occupied slot —
    the public surface ``_preemption_pressure`` consults instead of
    reaching into the engine's private slot table."""
    cfg = engine.cfg
    assert pceng.live_priorities() == []
    sched = _sched(engine, pceng)
    sched.submit(Request("a", rng.integers(1, cfg.vocab_size,
                                           8).astype(np.int32),
                         max_new_tokens=12, priority=1))
    sched.submit(Request("b", rng.integers(1, cfg.vocab_size,
                                           8).astype(np.int32),
                         max_new_tokens=12, priority=0))
    sched.step()
    assert sorted(pceng.live_priorities()) == [0, 1]
    out = sched.drain()
    assert {r.outcome for r in out} == {"completed"}
    assert pceng.live_priorities() == []
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())


def test_restore_prefetch_window(engine, pceng):
    """``_drain_restores`` prefetches a bounded *window* of the restore
    queue (``restore_prefetch``), not just its head, so later restores
    overlap their host->device staging with the in-flight round."""
    from repro.serving.swap import SwapRecord

    store = pceng.swap_store

    def fake_record():
        return SwapRecord(
            req=None, priority=1, target=0, temp=0.0, top_k=0, bucket=8,
            ring=0, tokens=[], chain_keys=[], written=set(), pos=0,
            remaining=0, lstep=0, key=np.zeros(2, np.uint32),
            logits=np.zeros(4, np.float32),
            host_kv={"sub": {"k": np.zeros((1, 1, 1, 1, 1), np.float32),
                             "v": np.zeros((1, 1, 1, 1, 1), np.float32)}},
            host_pos=np.zeros((1, 1), np.int32), n_private=0)

    tickets = [store.put(fake_record()) for _ in range(3)]
    try:
        sched = _sched(engine, pceng, restore_prefetch=2)
        # park every ticket behind a far-future backoff so the drain only
        # requeues (no try_restore) and then stages its prefetch window
        sched._restore_q = list(tickets)
        sched._ticket_backoff = {t: 10 ** 9 for t in tickets}
        assert sched._drain_restores(False) == 0
        assert sorted(sched._restore_q) == tickets
        assert len(store._staged) == 2      # was 1 before the window fix
    finally:
        for t in tickets:
            store.pop(t)
    assert len(store) == 0
    pceng.kv.assert_conserved(host_pages=pceng.swap_store.pages())
