"""Unit tests for the dry-run accounting tools (HLO collective parser,
extrapolation) — no device work."""
import pytest

from repro.launch import dryrun


SAMPLE_HLO = """
HloModule jit_step
  %x = f32[16,4096]{1,0} parameter(0)
  %ag = f32[256,4096]{1,0} all-gather(f32[16,4096]{1,0} %x), replica_groups={}
  %ar = f32[16,4096]{1,0} all-reduce(%x), to_apply=%add
  %tup = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(%a, %b), to_apply=%add
  %a2a = f32[16,64]{1,0} all-to-all(%x), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %ags = f32[32,32]{1,0} all-gather-start(f32[16,32]{1,0} %z)
  %agd = f32[32,32]{1,0} all-gather-done(%ags)
  %fusion.1 = f32[99,99]{1,0} fusion(%all-reduce.7, %c), kind=kLoop
  %gte = f32[1,1]{0,1} get-tuple-element(%all-reduce.8), index=0
"""


def test_parser_counts_only_defining_instructions():
    c = dryrun.parse_collectives(SAMPLE_HLO)
    assert c["all-gather"]["count"] == 2          # %ag and %ags (-start)
    assert c["all-reduce"]["count"] == 2          # %ar and %tup (not -done/uses)
    assert c["all-to-all"]["count"] == 1
    assert c["collective-permute"]["count"] == 1


def test_parser_payloads():
    c = dryrun.parse_collectives(SAMPLE_HLO)
    assert c["all-gather"]["bytes"] == 256 * 4096 * 4 + 32 * 32 * 4
    # ring all-reduce counted at 2x payload; tuple payloads summed
    assert c["all-reduce"]["bytes"] == 2 * (16 * 4096 * 4) + 2 * (2 * 8 * 128 * 4)
    assert c["all-to-all"]["bytes"] == 16 * 64 * 4
    assert c["total_bytes"] == sum(
        v["bytes"] for k, v in c.items() if isinstance(v, dict))


def test_extrapolation_linear():
    mk = lambda f, ag: {"flops": f, "bytes_accessed": 10 * f,
                        "transcendentals": 0.0,
                        "collectives": {k: {"count": 1 if k == "all-gather" else 0,
                                            "bytes": ag if k == "all-gather" else 0}
                                        for k in dryrun.COLL_KINDS}}
    v1, v2 = mk(100.0, 50), mk(160.0, 80)
    ex = dryrun._extrapolate(v1, v2, 10)
    assert ex["flops"] == pytest.approx(100 + 60 * 9)
    assert ex["collectives"]["all-gather"]["bytes"] == 50 + 30 * 9
    assert ex["collectives"]["total_bytes"] == 50 + 30 * 9


def test_shape_bytes():
    assert dryrun._shape_bytes("bf16", "4,8") == 64
    assert dryrun._shape_bytes("f32", "") == 4     # scalar
    assert dryrun._shape_bytes("nosuch", "4") == 0
