import numpy as np
import pytest

try:
    from hypothesis import settings
    settings.register_profile("repro", deadline=None, max_examples=25)
    settings.load_profile("repro")
except ImportError:
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)
