"""Serving engine + multi-tenant scheduler."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params)


def test_greedy_generation_deterministic(engine, rng):
    prompts = rng.integers(1, 200, (2, 16)).astype(np.int32)
    a = engine.generate(prompts, max_new_tokens=4)
    b = engine.generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.shape == (2, 4)
    assert a.tokens_per_s > 0


def test_temperature_sampling_varies(engine, rng):
    engine.temperature = 1.0
    prompts = rng.integers(1, 200, (4, 16)).astype(np.int32)
    a = engine.generate(prompts, max_new_tokens=8, seed=0)
    b = engine.generate(prompts, max_new_tokens=8, seed=1)
    engine.temperature = 0.0
    assert not np.array_equal(a.tokens, b.tokens)


def test_multitenant_round_robin(engine, rng):
    sched = MultiTenantScheduler(engine, max_batch=2)
    for i in range(6):
        sched.submit(Request(f"tenant-{i % 2}",
                             rng.integers(1, 200, 8).astype(np.int32),
                             max_new_tokens=2))
    responses = sched.drain()
    assert len(responses) == 6
    rep = sched.utilization_report()
    assert set(rep) == {"tenant-0", "tenant-1"}
    assert rep["tenant-0"]["requests"] == 3
    # fair round-robin: batches alternate tenants
    shares = [r["busy_share"] for r in rep.values()]
    assert abs(sum(shares) - 1.0) < 1e-6


def test_multitenant_batching_caps(engine, rng):
    sched = MultiTenantScheduler(engine, max_batch=2)
    for _ in range(5):
        sched.submit(Request("t", rng.integers(1, 200, 8).astype(np.int32),
                             max_new_tokens=1))
    r1 = sched.step()
    assert len(r1) == 2 and all(x.batch_size == 2 for x in r1)
    sched.drain()
    assert sched.pending() == 0


def test_idle_step_returns_none(engine):
    sched = MultiTenantScheduler(engine)
    assert sched.step() is None


class _DelayedTokens:
    """Fake device output: block_until_ready sleeps out the remaining
    'decode' time (jax.block_until_ready duck-types on the method)."""

    def __init__(self, arr, delay_s):
        self.arr = arr
        self._ready_at = __import__("time").perf_counter() + delay_s

    def block_until_ready(self):
        import time as _t
        rem = self._ready_at - _t.perf_counter()
        if rem > 0:
            _t.sleep(rem)
        return self


class _FakeEngine:
    """Deterministic stand-in: per-tenant latency keyed by first token.
    Supports both the blocking (generate) and split (dispatch/await)
    engine protocols so either schedule can run against it."""

    def __init__(self, delays):
        self.delays = delays             # first-token-value -> seconds

    def _delay(self, prompts):
        return self.delays.get(int(prompts[0, -1]), 0.0)

    def generate(self, prompts, steps, **kw):
        import time as _t
        from repro.serving.engine import GenerationResult
        d = self._delay(prompts)
        _t.sleep(d)
        toks = np.zeros((prompts.shape[0], steps), np.int32)
        return GenerationResult(toks, 0.0, d, steps)

    def dispatch(self, prompts, steps, **kw):
        import time as _t
        from repro.serving.engine import PendingGeneration
        d = self._delay(prompts)
        t0 = _t.perf_counter()
        toks = _DelayedTokens(np.zeros((prompts.shape[0], steps), np.int32),
                              d)
        return PendingGeneration(toks, np.zeros((prompts.shape[0], 1)),
                                 steps, t0, _t.perf_counter())

    def await_result(self, handle):
        import time as _t
        from repro.serving.engine import GenerationResult
        t0 = _t.perf_counter()
        handle.tokens.block_until_ready()
        return GenerationResult(handle.tokens.arr, 0.0,
                                _t.perf_counter() - t0, handle.steps)


@pytest.mark.parametrize("overlapped", [False, True])
def test_straggler_priority_serves_rounds_without_starvation(overlapped):
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.02, 2: 0.0})
    sched = MultiTenantScheduler(eng, max_batch=1, straggler_priority=True,
                                 overlapped=overlapped)
    for _ in range(3):
        sched.submit(Request("slow", np.array([1], np.int32), 1))
        sched.submit(Request("fast", np.array([2], np.int32), 1))
    served = []
    while sched.pending():
        r = sched.step()
        if r:
            served.extend(x.tenant for x in r)
    sched.close()
    # every tenant served each round: no starvation of the fast tenant
    assert served.count("fast") == 3 and served.count("slow") == 3
    if not overlapped:
        # blocking: round 2's pick already sees round 1's latencies, so the
        # slow tenant goes first.  (Overlapped staging picks one batch ahead
        # of completion, so its round 2 order still reflects cold history.)
        assert served[2] == "slow" and served[3] == "fast"


def test_straggler_detector_keyed_by_stable_slot():
    """Regression: detector keys must be the scheduler's stable tenant
    slots, not hash(tenant) % 2**31 — python string hashes are salted per
    process and can collide across tenants, silently merging two tenants'
    EWMA histories."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.01, 2: 0.0})
    sched = MultiTenantScheduler(eng, max_batch=1, overlapped=False)
    for _ in range(2):
        sched.submit(Request("tenant-a", np.array([1], np.int32), 1))
        sched.submit(Request("tenant-b", np.array([2], np.int32), 1))
    sched.drain()
    # two tenants -> two distinct, stable keys: their submission slots
    assert set(sched.detector.mean) == {0, 1}
    assert sched._slot_of == {"tenant-a": 0, "tenant-b": 1}
    # slot 0 (the slow tenant) accumulated the larger EWMA
    assert sched.detector.mean[0] > sched.detector.mean[1]


def test_pending_counts_staged_ahead_batches():
    """pending() must count requests held in staged-ahead state (assembled
    but unserved, and dispatched but unawaited), or drain() would exit with
    work in flight."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({})
    sched = MultiTenantScheduler(eng, max_batch=2, overlapped=True)
    for _ in range(2):
        sched.submit(Request("a", np.array([1], np.int32), 1))
        sched.submit(Request("b", np.array([2], np.int32), 1))
    assert sched.pending() == 4
    r = sched.step()                       # serves a; b left dispatched
    assert len(r) == 2
    assert sched.pending() == 2            # b's reqs: queues empty, inflight
    assert len(sched.queues["b"]) == 0
    r = sched.step()
    assert len(r) == 2 and sched.pending() == 0
    sched.close()
    # blocking path: the pre-assembled (not yet served) batch counts too
    sched = MultiTenantScheduler(eng, max_batch=2, overlapped=False)
    for _ in range(2):
        sched.submit(Request("a", np.array([1], np.int32), 1))
        sched.submit(Request("b", np.array([2], np.int32), 1))
    sched.step()                           # serves a, stages b ahead
    assert sched._prepared is not None
    assert sched.pending() == 2


def test_overlapped_busy_excludes_queue_wait():
    """A slot dispatched under the previous slot's long decode must not be
    billed for that queue wait: its compute window opens at device
    occupancy (previous slot's compute_end), so busy_s/EWMA stay honest
    and per-slot windows never double-count device time."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.08, 2: 0.0})
    sched = MultiTenantScheduler(eng, max_batch=1, overlapped=True)
    sched.submit(Request("slow", np.array([1], np.int32), 1))
    sched.submit(Request("fast", np.array([2], np.int32), 1))
    sched.drain()
    slow, fast = sched.timeline
    assert slow.compute_s >= 0.05
    # fast was enqueued behind slow's 80ms decode; its own decode is ~0ms
    assert fast.compute_s < 0.05, vars(fast)
    assert fast.compute_start >= slow.compute_end - 1e-6
    assert sched.stats["fast"]["busy_s"] < 0.05


def test_depth_n_dispatch_queue():
    """stage_depth generalises the single staged-ahead batch to a depth-N
    queue of dispatched slots: with depth 3 the scheduler keeps up to 1+3
    batches in flight, pending() counts them all, and responses still come
    back in dispatch order."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({})
    sched = MultiTenantScheduler(eng, max_batch=1, overlapped=True,
                                 stage_depth=3)
    for i in range(5):
        sched.submit(Request("t", np.array([i], np.int32), 1))
    r = sched.step()                       # fills to 4 inflight, awaits 1
    assert len(r) == 1
    assert len(sched._inflight) == 3       # depth-3 staged ahead
    assert sched.pending() == 4            # 3 inflight + 1 queued
    served = len(r)
    while sched.pending():
        r = sched.step()
        served += len(r or [])
    sched.close()
    assert served == 5


def test_ewma_harvest_closes_one_batch_lag():
    """Regression for the PR 2 deferred item: when slot k's completion has
    already landed, its latency must be stamped *before* the pick for slot
    k+1.  Tenant b's slow round-1 batch completes while the host idles
    between steps; the round-2 pick must therefore see b's fresh EWMA and
    serve b (the straggler) first — without the harvest the pick ran on
    b's cold 0.0 history and picked a."""
    import time as _t
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.02, 2: 0.06})
    sched = MultiTenantScheduler(eng, max_batch=1, straggler_priority=True,
                                 overlapped=True)
    for _ in range(2):
        sched.submit(Request("a", np.array([1], np.int32), 1))
        sched.submit(Request("b", np.array([2], np.int32), 1))
    served = [x.tenant for x in sched.step()]        # serves a; b in flight
    _t.sleep(0.2)                  # b's 60ms decode lands, waiter stamps it
    while sched.pending():
        r = sched.step()
        if r:
            served.extend(x.tenant for x in r)
    sched.close()
    # round 2 starts with the harvested straggler b, not a
    assert served == ["a", "b", "b", "a"], served
    # harvest + await never double-account
    rep = sched.utilization_report()
    assert rep["a"]["requests"] == 2 and rep["b"]["requests"] == 2


def _drain_order(sched):
    served = []
    while sched.pending():
        r = sched.step()
        if r:
            served.extend(x.tenant for x in r)
    sched.close()
    return served


def _assert_round_invariant(served, tenants, rounds):
    """Every backlogged tenant is served exactly once per round: the pick
    sequence chunks into permutations of the full tenant set."""
    assert len(served) == len(tenants) * rounds
    for r in range(rounds):
        chunk = served[r * len(tenants):(r + 1) * len(tenants)]
        assert sorted(chunk) == sorted(tenants), (r, served)


@pytest.mark.parametrize("n_tenants,rounds,ewma", [
    (2, 3, [5.0, 0.0]),
    (3, 2, [0.0, 9.0, 9.0]),       # ties + zero history
    (4, 2, [1.0, 1.0, 1.0, 1.0]),  # fully degenerate EWMA
])
def test_straggler_round_invariant_deterministic(n_tenants, rounds, ewma):
    """Deterministic cases of the fairness property (always runs, with or
    without hypothesis installed)."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    sched = MultiTenantScheduler(_FakeEngine({}), max_batch=1,
                                 straggler_priority=True, overlapped=False)
    tenants = [f"t{i}" for i in range(n_tenants)]
    for _ in range(rounds):
        for t in tenants:
            sched.submit(Request(t, np.array([0], np.int32), 1))
    sched._recent.update(dict(zip(tenants, ewma)))
    _assert_round_invariant(_drain_order(sched), tenants, rounds)


def test_straggler_round_invariant_property():
    """Hypothesis property: the round invariant holds for arbitrary EWMA
    seedings and tenant counts, in both schedules."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st
    from repro.serving.multitenant import MultiTenantScheduler, Request

    @given(st.integers(2, 5), st.integers(1, 3),
           st.lists(st.floats(0.0, 10.0, allow_nan=False),
                    min_size=5, max_size=5),
           st.booleans())
    def prop(n_tenants, rounds, ewma, overlapped):
        sched = MultiTenantScheduler(_FakeEngine({}), max_batch=1,
                                     straggler_priority=True,
                                     overlapped=overlapped)
        tenants = [f"t{i}" for i in range(n_tenants)]
        for _ in range(rounds):
            for t in tenants:
                sched.submit(Request(t, np.array([0], np.int32), 1))
        sched._recent.update(dict(zip(tenants, ewma)))
        _assert_round_invariant(_drain_order(sched), tenants, rounds)

    prop()


def test_serving_timeline_windows_are_honest():
    """Blocking schedule: compute window = the generate call only; the
    staged-ahead assembly of the next slot must not inflate the previous
    slot's compute_end."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.01, 2: 0.01})
    sched = MultiTenantScheduler(eng, max_batch=1, overlapped=False)
    for _ in range(2):
        sched.submit(Request("a", np.array([1], np.int32), 1))
        sched.submit(Request("b", np.array([2], np.int32), 1))
    while sched.pending():
        sched.step()
    tl = sched.timeline
    assert len(tl) == 4
    for e in tl:
        assert e.transfer_start <= e.transfer_end <= e.compute_start \
            <= e.compute_end
    # serial engine: next slot's assembly happens after this compute ends
    for a, b in zip(tl, tl[1:]):
        assert b.transfer_start >= a.compute_end - 1e-6
