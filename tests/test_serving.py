"""Serving engine + multi-tenant scheduler."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params)


def test_greedy_generation_deterministic(engine, rng):
    prompts = rng.integers(1, 200, (2, 16)).astype(np.int32)
    a = engine.generate(prompts, max_new_tokens=4)
    b = engine.generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.shape == (2, 4)
    assert a.tokens_per_s > 0


def test_temperature_sampling_varies(engine, rng):
    engine.temperature = 1.0
    prompts = rng.integers(1, 200, (4, 16)).astype(np.int32)
    a = engine.generate(prompts, max_new_tokens=8, seed=0)
    b = engine.generate(prompts, max_new_tokens=8, seed=1)
    engine.temperature = 0.0
    assert not np.array_equal(a.tokens, b.tokens)


def test_multitenant_round_robin(engine, rng):
    sched = MultiTenantScheduler(engine, max_batch=2)
    for i in range(6):
        sched.submit(Request(f"tenant-{i % 2}",
                             rng.integers(1, 200, 8).astype(np.int32),
                             max_new_tokens=2))
    responses = sched.drain()
    assert len(responses) == 6
    rep = sched.utilization_report()
    assert set(rep) == {"tenant-0", "tenant-1"}
    assert rep["tenant-0"]["requests"] == 3
    # fair round-robin: batches alternate tenants
    shares = [r["busy_share"] for r in rep.values()]
    assert abs(sum(shares) - 1.0) < 1e-6


def test_multitenant_batching_caps(engine, rng):
    sched = MultiTenantScheduler(engine, max_batch=2)
    for _ in range(5):
        sched.submit(Request("t", rng.integers(1, 200, 8).astype(np.int32),
                             max_new_tokens=1))
    r1 = sched.step()
    assert len(r1) == 2 and all(x.batch_size == 2 for x in r1)
    sched.drain()
    assert sched.pending() == 0


def test_idle_step_returns_none(engine):
    sched = MultiTenantScheduler(engine)
    assert sched.step() is None


class _FakeEngine:
    """Deterministic stand-in: per-tenant latency keyed by first token."""

    def __init__(self, delays):
        self.delays = delays             # first-token-value -> seconds

    def generate(self, prompts, steps, **kw):
        import time as _t
        from repro.serving.engine import GenerationResult
        d = self.delays.get(int(prompts[0, -1]), 0.0)
        _t.sleep(d)
        toks = np.zeros((prompts.shape[0], steps), np.int32)
        return GenerationResult(toks, 0.0, d, steps)


def test_straggler_priority_serves_rounds_without_starvation():
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.02, 2: 0.0})
    sched = MultiTenantScheduler(eng, max_batch=1, straggler_priority=True)
    for _ in range(3):
        sched.submit(Request("slow", np.array([1], np.int32), 1))
        sched.submit(Request("fast", np.array([2], np.int32), 1))
    served = []
    while sched.pending():
        r = sched.step()
        if r:
            served.extend(x.tenant for x in r)
    # every tenant served each round: no starvation of the fast tenant
    assert served.count("fast") == 3 and served.count("slow") == 3
    # within a round (after one step of history) the slow tenant goes first
    assert served[2] == "slow" and served[3] == "fast"


def test_serving_timeline_windows_are_honest():
    """compute window = the generate call only; the staged-ahead assembly of
    the next slot must not inflate the previous slot's compute_end."""
    from repro.serving.multitenant import MultiTenantScheduler, Request
    eng = _FakeEngine({1: 0.01, 2: 0.01})
    sched = MultiTenantScheduler(eng, max_batch=1)
    for _ in range(2):
        sched.submit(Request("a", np.array([1], np.int32), 1))
        sched.submit(Request("b", np.array([2], np.int32), 1))
    while sched.pending():
        sched.step()
    tl = sched.timeline
    assert len(tl) == 4
    for e in tl:
        assert e.transfer_start <= e.transfer_end <= e.compute_start \
            <= e.compute_end
    # serial engine: next slot's assembly happens after this compute ends
    for a, b in zip(tl, tl[1:]):
        assert b.transfer_start >= a.compute_end - 1e-6
