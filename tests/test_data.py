"""Synthetic token pipeline: determinism, restart consistency, prefetch."""
import numpy as np

from repro.configs import get_config
from repro.data.tokens import DataConfig, PrefetchFeed, synth_batch


def test_determinism_in_seed_and_step():
    dc = DataConfig(4, 32, 1000, seed=3)
    a = synth_batch(dc, 7)
    b = synth_batch(dc, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(dc, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    d = synth_batch(DataConfig(4, 32, 1000, seed=4), 7)
    assert not np.array_equal(a["tokens"], d["tokens"])


def test_labels_are_shifted_tokens():
    dc = DataConfig(2, 16, 500)
    b = synth_batch(dc, 0)
    assert b["tokens"].shape == (2, 16)
    assert b["labels"].shape == (2, 16)
    assert (b["tokens"] > 0).all() and (b["tokens"] < 500).all()


def test_modality_extras():
    cfg = get_config("llava-next-mistral-7b").reduced()
    b = synth_batch(DataConfig(2, 16, cfg.vocab_size), 0, cfg)
    assert b["patch_embeds"].shape == (2, cfg.num_patches, 1024)
    cfg2 = get_config("whisper-base").reduced()
    b2 = synth_batch(DataConfig(2, 16, cfg2.vocab_size), 0, cfg2)
    assert b2["frames"].shape == (2, cfg2.encoder_seq_len, cfg2.d_model)


def test_prefetch_matches_sync_and_restart():
    dc = DataConfig(2, 16, 300, seed=1)
    feed = PrefetchFeed(dc, depth=2)
    got = [np.asarray(next(feed)["tokens"]) for _ in range(4)]
    feed.close()
    want = [synth_batch(dc, s)["tokens"] for s in range(4)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    # restart from step 2 reproduces the tail (checkpoint-consistent feed)
    feed2 = PrefetchFeed(dc, start_step=2)
    g2 = np.asarray(next(feed2)["tokens"])
    feed2.close()
    np.testing.assert_array_equal(g2, want[2])
