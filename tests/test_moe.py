"""MoE capacity-dispatch semantics vs an explicit per-token reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchConfig, MoEConfig
from repro.distributed.sharding import null_sharder
from repro.models.moe import apply_moe, init_moe
from repro.models import params as pp


def _cfg(E=4, k=2, cf=8.0, shared=0, gs=64):
    return ArchConfig(
        name="moe-test", family="moe", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
        moe_period=1,
        moe=MoEConfig(num_experts=E, top_k=k, d_ff_expert=16,
                      num_shared_experts=shared, capacity_factor=cf,
                      group_size=gs),
        param_dtype="float32", compute_dtype="float32")


def _dense_reference(params, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    mc = cfg.moe
    B, S, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, mc.top_k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x)
    for e in range(mc.num_experts):
        g = jax.nn.silu(x @ params["w_gate"][e])
        u = x @ params["w_up"][e]
        y_e = (g * u) @ params["w_down"][e]
        w_e = jnp.sum(jnp.where(ids == e, gates, 0.0), axis=-1)
        out = out + y_e * w_e[..., None]
    return out


@pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 4)])
def test_moe_matches_dense_reference_with_ample_capacity(E, k):
    cfg = _cfg(E=E, k=k, cf=float(E))  # capacity >= all tokens: no drops
    params, _ = pp.split(init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, losses = apply_moe(params, x, cfg, null_sharder())
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(losses["moe_aux"]) > 0


def test_capacity_drops_reduce_output_norm():
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    big = _cfg(cf=8.0)
    tiny = dataclasses.replace(big, moe=dataclasses.replace(
        big.moe, capacity_factor=0.25))
    params, _ = pp.split(init_moe(jax.random.PRNGKey(0), big))
    y_big, _ = apply_moe(params, x, big, null_sharder())
    y_tiny, _ = apply_moe(params, x, tiny, null_sharder())
    # dropped tokens contribute zero -> smaller aggregate norm
    assert float(jnp.sum(y_tiny ** 2)) < float(jnp.sum(y_big ** 2))


def test_shared_expert_adds_dense_path():
    cfg = _cfg(shared=1)
    params, _ = pp.split(init_moe(jax.random.PRNGKey(0), cfg))
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    y, _ = apply_moe(params, x, cfg, null_sharder())
    assert np.isfinite(np.asarray(y)).all()


def test_moe_grads_flow():
    cfg = _cfg()
    params, _ = pp.split(init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))

    def loss(p):
        y, l = apply_moe(p, x, cfg, null_sharder())
        return jnp.sum(y ** 2) + sum(l.values())

    g = jax.grad(loss)(params)
    gnorms = {jax.tree_util.keystr(kp): float(jnp.linalg.norm(v.reshape(-1)))
              for kp, v in jax.tree_util.tree_flatten_with_path(g)[0]}
    assert all(np.isfinite(list(gnorms.values())))
    assert gnorms["['router']"] > 0          # router learns
    assert gnorms["['w_down']"] > 0


def test_group_size_invariance():
    """Different routing-group sizes only change drop boundaries; with ample
    capacity results are identical."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    a = _cfg(cf=8.0, gs=16)
    b = _cfg(cf=8.0, gs=64)
    params, _ = pp.split(init_moe(jax.random.PRNGKey(0), a))
    ya, _ = apply_moe(params, x, a, null_sharder())
    yb, _ = apply_moe(params, x, b, null_sharder())
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb),
                               rtol=2e-4, atol=2e-4)
