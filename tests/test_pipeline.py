"""Overlapped execution pipeline: equivalence, caching, overlap contract.

Covers the simulator-vs-executable overlap contract documented in
repro.core.pipeline: the overlapped run_tenant_chunked must be bit-identical
to run_single across tenancy configs, must not retrace or re-upload resident
tables on repeated runs, and its timeline must show tenant k+1's transfer
starting before tenant k's compute ends.  The multi-device case runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag
must precede jax initialisation, which this process has already done).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.risk_app import RiskAppConfig
from repro.core.pipeline import PipelineExecutor
from repro.core.tenancy import TenancyConfig, VirtualDevicePool
from repro.risk.analysis import AggregateRiskAnalysis
from repro.risk.tables import generate


@pytest.fixture(scope="module")
def cfg():
    return RiskAppConfig().reduced()


@pytest.fixture(scope="module")
def tables(cfg):
    return generate(cfg, seed=0)


@pytest.mark.parametrize("tenants,mode", [(1, "sequential"),
                                          (2, "sequential"),
                                          (4, "sequential"),
                                          (1, "concurrent"),
                                          (2, "concurrent"),
                                          (4, "concurrent")])
def test_overlapped_bit_identical_to_single(cfg, tables, tenants, mode):
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, tenants, mode))
    single = ara.run_single(tables)
    rep = ara.run_tenant_chunked(tables)
    np.testing.assert_array_equal(rep.ylt, single)
    assert len(rep.per_tenant_s) == tenants
    assert rep.timeline is not None and len(rep.timeline) == tenants


def test_overlapped_matches_blocking(cfg, tables):
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 4))
    a = ara.run_tenant_chunked(tables, overlapped=True)
    b = ara.run_tenant_chunked(tables, overlapped=False)
    np.testing.assert_array_equal(a.ylt, b.ylt)


def test_ragged_trials_bit_identical(cfg):
    """67 trials over 4 vdevs: uniform padding must not perturb results."""
    t67 = generate(dataclasses.replace(cfg, num_trials=67), seed=3)
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 4))
    np.testing.assert_array_equal(ara.run_tenant_chunked(t67).ylt,
                                  ara.run_single(t67))


def test_no_retrace_across_runs_and_ragged_remainders(cfg, tables):
    """Uniform padding -> one chunk shape -> exactly one trace, even with a
    ragged remainder, and re-runs hit the jit cache."""
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 4))
    t0 = ara.trace_count
    ara.run_tenant_chunked(tables)
    assert ara.trace_count == t0 + 1       # one compile for all 4 tenants
    ara.run_tenant_chunked(tables)
    t67 = generate(dataclasses.replace(cfg, num_trials=67), seed=1)
    # 67 = 4x16+3: unpadded this would need two traces (17- and 16-row)
    ara.run_tenant_chunked(t67)
    ara.run_tenant_chunked(t67)
    assert ara.trace_count == t0 + 2       # only the new 17-row shape


def test_resident_tables_uploaded_once(cfg, tables):
    """Second run must not re-stage the un-splittable ELT/term tables."""
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 2))
    ara.run_tenant_chunked(tables)
    uploads = ara.table_uploads
    ara.run_tenant_chunked(tables)
    assert ara.table_uploads == uploads    # cache hit, no second upload
    # perturbing only the layer aggregate terms (what-if pricing) keeps
    # table identity, so still no upload
    t2 = dataclasses.replace(tables, agg_ret=tables.agg_ret * 1.5)
    ara.run_tenant_chunked(t2)
    assert ara.table_uploads == uploads
    # genuinely new tables do upload
    ara.run_tenant_chunked(generate(cfg, seed=9))
    assert ara.table_uploads > uploads


def test_resident_cache_detects_inplace_mutation(cfg, tables):
    """Fingerprint revalidation of the id()-keyed cache: whole-table and
    term mutations re-upload instead of serving stale device copies.  (The
    documented contract still forbids in-place mutation — a *sparse* ELT
    edit can slip past the sampled fingerprint; these are the tripwire
    cases it must catch.)"""
    t = generate(cfg, seed=11)
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 2))
    before = ara.run_tenant_chunked(t).ylt.copy()
    uploads = ara.table_uploads
    t.elt_losses *= 2.0                    # same array object, new content
    after = ara.run_tenant_chunked(t).ylt
    assert ara.table_uploads > uploads     # stale entry evicted + re-staged
    np.testing.assert_array_equal(after, ara.run_single(t))
    assert not np.array_equal(before, after)
    # single-element edit of the (small, fully-fingerprinted) term arrays
    uploads = ara.table_uploads
    t.occ_ret[0] *= 0.5
    np.testing.assert_array_equal(ara.run_tenant_chunked(t).ylt,
                                  ara.run_single(t))
    assert ara.table_uploads > uploads


def test_sequential_timeline_overlaps(cfg):
    """transfer(k+1) starts inside compute(k)'s window — the paper's
    overlap, with the falsifiable predicate from core.pipeline.  Uses a
    workload big enough that each tenant's compute outlasts one staging
    step (the predicate is honest: it would fail on a blocking schedule)."""
    big = dataclasses.replace(cfg, num_trials=32768, events_per_trial=128,
                              chunk_events=128)
    tb = generate(big, seed=0)
    ara = AggregateRiskAnalysis(big, TenancyConfig(1, 4, "sequential"))
    ara.run_tenant_chunked(tb)                      # warm: exclude compile
    rep = ara.run_tenant_chunked(tb)
    tl = rep.timeline
    assert len(tl) == 4
    # majority of pairs overlapped: a blocking schedule scores 0 (its
    # transfers all precede its computes), while noise on a shared host can
    # legitimately drain isolated pairs early
    from repro.core.pipeline import timeline_overlaps
    ov = timeline_overlaps(tl)
    assert sum(ov) > len(ov) // 2, ov
    for e in tl:
        assert e.transfer_start <= e.transfer_end <= e.compute_start \
            <= e.compute_end


def test_straggler_reorder_with_pipeline(cfg, tables):
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, 4))
    hist = {0: 5.0, 1: 1.0, 2: 3.0, 3: 0.5}
    rep = ara.run_tenant_chunked(tables, straggler_hist=hist)
    np.testing.assert_array_equal(rep.ylt, ara.run_single(tables))
    # slowest previous tenant is staged (and therefore timed) first
    assert rep.timeline[0].vdev == 0


def test_executor_generic_payload():
    """The executor is workload-agnostic: any stage_fn/compute_fn pair."""
    import jax.numpy as jnp
    pool = VirtualDevicePool(TenancyConfig(1, 3, "sequential"))
    tasks = pool.plan(30, uniform=True)
    data = np.arange(30, dtype=np.float32)
    ex = PipelineExecutor(pool)
    rep = ex.run(tasks,
                 lambda t: data[t.start:t.stop],
                 lambda t, x: jnp.asarray(x) * 2.0)
    assert rep.mode == "sequential"
    out = np.concatenate([np.asarray(rep.results[t.vdev]) for t in tasks])
    np.testing.assert_array_equal(out, data * 2.0)
    assert rep.wall_s > 0 and len(rep.timeline) == 3


def test_executor_propagates_waiter_errors():
    """A device error surfacing in the waiter thread must re-raise on the
    main thread, not silently yield a partial result dict."""
    class Boom:
        def block_until_ready(self):
            raise RuntimeError("device boom")

    pool = VirtualDevicePool(TenancyConfig(1, 2, "sequential"))
    tasks = pool.plan(4, uniform=True)
    ex = PipelineExecutor(pool)
    with pytest.raises(RuntimeError, match="device boom"):
        ex.run(tasks, lambda t: np.float32([1.0]), lambda t, x: Boom())


def test_executor_reaps_waiter_on_stage_error():
    """stage_fn raising mid-loop must not leak a blocked waiter thread."""
    import threading

    def bad_stage(t):
        raise ValueError("bad stage")

    pool = VirtualDevicePool(TenancyConfig(1, 2, "sequential"))
    ex = PipelineExecutor(pool)
    with pytest.raises(ValueError, match="bad stage"):
        ex.run(pool.plan(4, uniform=True), bad_stage, lambda t, x: x)
    assert not any(th.name == "pipeline-waiter" and th.is_alive()
                   for th in threading.enumerate())


def test_uniform_plan_shapes():
    pool = VirtualDevicePool(TenancyConfig(2, 2))
    tasks = pool.plan(67, uniform=True)
    assert all(t.padded_size == 17 for t in tasks)
    assert sum(t.size for t in tasks) == 67
    assert {t.size + t.pad for t in tasks} == {17}
    # non-uniform plan keeps the legacy contract
    legacy = pool.plan(67)
    assert all(t.padded_size is None and t.pad == 0 for t in legacy)


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import dataclasses
    import numpy as np
    from repro.configs.risk_app import RiskAppConfig
    from repro.core.tenancy import TenancyConfig
    from repro.risk.analysis import AggregateRiskAnalysis
    from repro.risk.tables import generate
    import jax

    devs = jax.devices()
    assert len(devs) == 8, devs
    cfg = dataclasses.replace(RiskAppConfig().reduced(), num_trials=4096)
    tables = generate(cfg, seed=0)
    for tenants, mode in [(1, "sequential"), (2, "sequential"),
                          (2, "concurrent")]:
        ara = AggregateRiskAnalysis(cfg, TenancyConfig(8, tenants, mode),
                                    devices=devs)
        rep = ara.run_tenant_chunked(tables)
        np.testing.assert_array_equal(rep.ylt, ara.run_single(tables))
        assert len(rep.per_tenant_s) == 8 * tenants
        # chunks really live on their pdev
        placed = {t.vdev: t.pdev for t in ara.pool.plan(tables.num_trials)}
        assert len(set(placed.values())) == 8
    # overlap contract on real multi-device: warm, then check the timeline
    # (transfer k+1 inside compute k's window — needs compute that outlasts
    # one staging step, hence the bigger workload).  A blocking schedule
    # scores 0/15 pairs (its transfers all precede its computes), so a
    # majority of overlapped pairs distinguishes the schedules even on a
    # noisy shared-CPU host where individual pairs can legitimately drain
    # early under contention.
    from repro.core.pipeline import timeline_overlaps
    big = dataclasses.replace(RiskAppConfig().reduced(), num_trials=65536,
                              events_per_trial=64, chunk_events=64)
    tbig = generate(big, seed=0)
    ara = AggregateRiskAnalysis(big, TenancyConfig(8, 2, "sequential"),
                                devices=devs)
    ara.run_tenant_chunked(tbig)
    ov = timeline_overlaps(ara.run_tenant_chunked(tbig).timeline)
    assert sum(ov) > len(ov) // 2, ov
    print("MULTI_DEVICE_OK")
""")


def test_multi_device_pipeline_subprocess(cfg):
    """8 host devices need XLA_FLAGS before jax init -> subprocess."""
    env = dict(os.environ)
    # append (not prepend): the last repetition of a flag wins, and earlier
    # suite imports (launch.dryrun) may have left a device-count in XLA_FLAGS
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTI_DEVICE_OK" in proc.stdout
