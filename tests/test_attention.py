"""Blockwise flash attention vs naive oracle: fwd + grad, causal/window/GQA,
plus the unrolled cost-analysis variant."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention_core import blockwise_attention, naive_attention


def _qkv(B, Sq, Skv, Hq, Hkv, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Sq, Hq, D)),
            jax.random.normal(ks[1], (B, Skv, Hkv, D)),
            jax.random.normal(ks[2], (B, Skv, Hkv, D)))


CASES = [
    # B, S, Hq, Hkv, D, causal, window, bq, bk
    (2, 64, 4, 2, 16, True, None, 16, 32),
    (2, 64, 4, 4, 16, False, None, 32, 16),
    (1, 128, 8, 2, 8, True, 32, 32, 32),
    (2, 96, 6, 3, 16, True, 48, 32, 48),   # non-pow2 heads/seq
    (1, 64, 2, 1, 32, False, 16, 64, 64),  # single block (no loop)
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,bq,bk", CASES)
def test_forward_matches_naive(B, S, Hq, Hkv, D, causal, window, bq, bk):
    q, k, v = _qkv(B, S, S, Hq, Hkv, D)
    o1 = naive_attention(q, k, v, causal=causal, window=window)
    o2 = blockwise_attention(q, k, v, causal=causal, window=window,
                             block_q=bq, block_kv=bk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_grads_match_naive(causal, window):
    q, k, v = _qkv(2, 64, 64, 4, 2, 16)

    def f_ref(q, k, v):
        return jnp.sum(naive_attention(q, k, v, causal=causal,
                                       window=window) ** 2)

    def f_blk(q, k, v):
        return jnp.sum(blockwise_attention(q, k, v, causal=causal,
                                           window=window, block_q=16,
                                           block_kv=32) ** 2)

    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_blk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_unrolled_variant_matches(monkeypatch):
    monkeypatch.setenv("REPRO_UNROLL", "1")
    q, k, v = _qkv(1, 64, 64, 4, 2, 16)
    o2 = blockwise_attention(q, k, v, causal=True, window=None)
    g2 = jax.grad(lambda q, k, v: jnp.sum(
        blockwise_attention(q, k, v, causal=True) ** 2),
        argnums=(0, 1, 2))(q, k, v)
    monkeypatch.setenv("REPRO_UNROLL", "0")
    o1 = naive_attention(q, k, v, causal=True)
    g1 = jax.grad(lambda q, k, v: jnp.sum(
        naive_attention(q, k, v, causal=True) ** 2),
        argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_decode_with_kv_positions():
    # ring-cache decode: permuted kv with explicit positions == ordered cache
    q, k, v = _qkv(1, 1, 32, 4, 2, 16)
    perm = np.random.default_rng(0).permutation(32)
    kp = k[:, perm]
    vp = v[:, perm]
    pos = jnp.asarray(perm)
    o1 = naive_attention(q, k, v, causal=True, q_offset=31)
    o2 = naive_attention(q, kp, vp, causal=True, q_offset=31,
                         kv_positions=pos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_bf16_path():
    q, k, v = _qkv(1, 64, 64, 4, 2, 16)
    o1 = blockwise_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), causal=True)
    o2 = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1, np.float32), np.asarray(o2),
                               rtol=5e-2, atol=5e-2)
