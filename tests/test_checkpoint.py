"""Checkpoint save/restore, atomicity, GC, and failure recovery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import (HeartbeatMonitor, StragglerDetector,
                                     run_with_recovery)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 10, t)
    assert ckpt.latest_step(tmp_path) == 10
    r = ckpt.restore(tmp_path, 10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    t = _tree()
    th = ckpt.save_async(tmp_path, 5, t)
    th.join()
    assert ckpt.latest_step(tmp_path) == 5


def test_gc_keeps_last(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, t, keep_last=2)
    assert ckpt.available_steps(tmp_path) == [4, 5]


def test_partial_checkpoint_ignored(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # fake a partial write: directory without .done marker
    (tmp_path / "step_2").mkdir()
    (tmp_path / "step_2" / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    bad = {"a": jnp.zeros((5, 8)), "nested": {"b": jnp.zeros(10, jnp.int32),
                                              "c": jnp.float32(0)},
           "step": jnp.int32(0)}
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, 1, bad)


def test_run_with_recovery_restarts(tmp_path):
    """Inject a failure at step 7; the loop restores step 5 and completes."""
    crashed = {"done": False}

    def step_fn(state, i):
        if i == 7 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected device loss")
        return {"x": state["x"] + 1.0}

    state = {"x": jnp.float32(0)}
    rep = run_with_recovery(step_fn, state, num_steps=10,
                            ckpt_dir=tmp_path, save_every=5, max_failures=2)
    assert rep.steps_done == 10
    assert rep.failures == 1
    assert rep.restarts == [7]
    final = ckpt.restore(tmp_path, 10, state)
    assert float(final["x"]) == 10.0


def test_recovery_gives_up(tmp_path):
    def step_fn(state, i):
        if i >= 3:
            raise RuntimeError("permafail")
        return state

    ckpt.save(tmp_path, 3, {"x": jnp.float32(0)})
    with pytest.raises(RuntimeError):
        run_with_recovery(step_fn, {"x": jnp.float32(0)}, 10, tmp_path,
                          save_every=100, max_failures=2)


def test_heartbeat():
    hb = HeartbeatMonitor(timeout_s=0.0)
    assert hb.suspect()
    hb2 = HeartbeatMonitor(timeout_s=1e6)
    assert not hb2.suspect()


def test_straggler_detector_flags_slow_tenant():
    det = StragglerDetector(alpha=0.5, z_threshold=1.5)
    flagged = []
    for _ in range(10):
        flagged = det.update({0: 1.0, 1: 1.0, 2: 1.0, 3: 10.0})
    assert flagged == [3]
    pri = det.staging_priority()
    assert pri[3] > pri[0]


def test_elastic_restore_smaller_mesh(tmp_path):
    """Save from a '4-device' mesh layout, restore onto 1 device (pod loss):
    restore() reshards via device_put with new shardings (None here)."""
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(tmp_path, 1, t)
    r = ckpt.restore(tmp_path, 1, t, shardings=None)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
