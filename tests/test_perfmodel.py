"""Paper-claims validation: Eqs 4-10 + Table II reproduce the paper's own
reported numbers (§V-F, Figs 17-22)."""
import pytest

from repro.core import energymodel as em
from repro.core import perfmodel as pm
from repro.core.planner import evaluate, full_surface, plan


def test_table2_single_device_times():
    # Fig 6 / Table I: 1 local GPU ~ 9.55 s compute; rCUDA FDR 4GB = 0.67 s
    m = pm.PerfModelInputs(net=pm.FDR)
    assert pm.t_computation(1, m) == pytest.approx(9.55)
    assert pm.FDR.t_4gb == pytest.approx(0.67)
    assert pm.QDR.t_4gb == pytest.approx(1.171)


def test_perfect_compute_scalability():
    m = pm.PerfModelInputs(net=pm.FDR)
    for n in (1, 2, 4, 8, 16):
        assert pm.t_computation(n, m) == pytest.approx(9.55 / n)


def test_transfer_overhead_grows_with_devices():
    # paper §V-C: rCUDA transfer time *increases* with #GPUs
    m = pm.PerfModelInputs(net=pm.FDR)
    ts = [pm.t_transfer(n, m) for n in (1, 2, 4, 8, 16)]
    assert all(b > a for a, b in zip(ts, ts[1:]))


def test_memory_cap_reproduces_paper():
    # paper §V-F1: 4 tenants on one K20 consume 4484 MB; >4 exhausts it
    m = pm.PerfModelInputs(net=pm.FDR)
    assert pm.memory_per_pdev_mb(1, 4, m) == pytest.approx(4484.0)
    assert pm.feasible(1, 4, m)
    assert not pm.feasible(1, 5, m)


def test_optimal_deployments_match_paper():
    # paper §V-F1: optimum = 7 pGPU x 2 vGPU (QDR), 9 pGPU x 2 vGPU (FDR)
    for net, want in ((pm.QDR, (7, 2)), (pm.FDR, (9, 2))):
        m = pm.PerfModelInputs(net=net)
        best = plan(m, "time")
        assert (best.n_pdev, best.tenants_per_pdev) == want, net.name


def test_energy_optimal_matches_paper():
    # paper §V-F2: energy-efficient deployment = 4 vGPUs on 1 pGPU, both nets
    for net in (pm.QDR, pm.FDR):
        best = plan(pm.PerfModelInputs(net=net), "energy")
        assert (best.n_pdev, best.tenants_per_pdev) == (1, 4), net.name


def test_multitenancy_beats_single_tenancy():
    # the paper's hypothesis: same hardware, lower time with tenants
    m = pm.PerfModelInputs(net=pm.FDR)
    for p in (4, 8):
        t1 = pm.exec_time_multitenancy(p, 1, m)
        t2 = pm.exec_time_multitenancy(p, 2, m)
        assert t2 < t1


def test_under_two_seconds_fdr():
    # paper abstract: "executed under two seconds ... on the same hardware"
    m = pm.PerfModelInputs(net=pm.FDR)
    assert plan(m, "time").exec_time_s < 2.0


def test_eq9_is_max_of_eq7_eq8():
    m = pm.PerfModelInputs(net=pm.FDR)
    for p in (1, 4, 9):
        for v in (1, 2, 4):
            nv = p * v
            e7 = pm.t_transfer(nv, m) / v + v * pm.t_computation(nv, m)
            e8 = pm.t_transfer(nv, m) + pm.t_computation(nv, m)
            assert pm.exec_time_multitenancy(p, v, m) == pytest.approx(
                max(e7, e8))


def test_energy_eq10():
    m = pm.PerfModelInputs(net=pm.FDR)
    t = pm.exec_time_multitenancy(4, 2, m)
    tc = pm.t_computation(4, m)
    want = 4 * (tc * 102.0 + (t - tc) * 47.0)
    assert em.total_energy(4, 2, m) == pytest.approx(want)


def test_planner_objectives_and_budget():
    m = pm.PerfModelInputs(net=pm.FDR)
    t = plan(m, "time")
    e = plan(m, "energy")
    d = plan(m, "edp")
    assert e.energy_ws <= t.energy_ws
    assert t.exec_time_s <= e.exec_time_s
    assert t.exec_time_s <= d.exec_time_s <= e.exec_time_s + 1e-9
    b = plan(m, "time", budget_pdev=3)
    assert b.n_pdev <= 3


def test_surface_covers_figures_space():
    m = pm.PerfModelInputs(net=pm.FDR)
    surf = full_surface(m, max_pdev=16, max_tenants=12)
    assert (16, 1) in surf and (1, 4) in surf
    assert (1, 5) not in surf  # infeasible by memory


def test_v5e_profile_scales():
    m = pm.PerfModelInputs(net=pm.V5E, compute_time_1pdev=0.4)
    best = plan(m, "time")
    assert best.exec_time_s < 0.4
