"""Staging engine: modes, ordering, logs (single-device host)."""
import numpy as np
import pytest

from repro.core.tenancy import TenancyConfig, VirtualDevicePool
from repro.core.transfer import StagingEngine


@pytest.fixture
def pool():
    return VirtualDevicePool(TenancyConfig(1, 4, "sequential"))


def _chunks(tasks, rng):
    data = {t.vdev: rng.normal(size=(t.size, 8)).astype(np.float32)
            for t in tasks}
    return data


def test_sequential_staging_order_and_log(pool, rng):
    tasks = pool.plan(64)
    data = _chunks(tasks, rng)
    eng = StagingEngine(pool)
    staged = eng.stage(tasks, lambda t: {"x": data[t.vdev]})
    assert [c.task.vdev for c in staged] == [t.vdev for t in tasks]
    # sequential: every chunk has a ready timestamp, monotonically increasing
    times = [c.ready_s for c in staged]
    assert all(t is not None for t in times)
    assert times == sorted(times)
    assert all(e["mode"] == "sequential" for e in eng.log)
    # data round-trips
    np.testing.assert_array_equal(np.asarray(staged[0].arrays["x"]),
                                  data[staged[0].task.vdev])


def test_concurrent_staging(pool, rng):
    tasks = pool.plan(64)
    data = _chunks(tasks, rng)
    eng = StagingEngine(pool, mode="concurrent")
    staged = eng.stage(tasks, lambda t: {"x": data[t.vdev]}, block=True)
    assert len(staged) == 4
    assert all(c.ready_s is not None for c in staged)


def test_stage_covers_all_items(pool, rng):
    tasks = pool.plan(37)  # ragged split
    data = _chunks(tasks, rng)
    eng = StagingEngine(pool)
    staged = eng.stage(tasks, lambda t: {"x": data[t.vdev]})
    total = sum(c.arrays["x"].shape[0] for c in staged)
    assert total == 37
