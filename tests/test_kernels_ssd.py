"""SSD Pallas kernel vs oracles: chunked == recurrent == pallas, + grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.ref import (ssd_chunked_ref, ssd_decode_step_ref,
                               ssd_recurrent_ref)
from repro.kernels.ssd_scan import ssd_chunked_pallas


def _case(b, L, H, P, N, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    a = A[None, None, :] * dt
    B = jax.random.normal(ks[3], (b, L, H, N))
    C = jax.random.normal(ks[4], (b, L, H, N))
    h0 = jax.random.normal(ks[5], (b, H, P, N))
    return x, dt, a, B, C, h0


SWEEP = [(2, 64, 4, 8, 16, 16), (1, 128, 2, 16, 32, 32),
         (2, 32, 8, 4, 8, 8), (1, 256, 1, 64, 128, 64)]


@pytest.mark.parametrize("b,L,H,P,N,chunk", SWEEP)
@pytest.mark.parametrize("with_h0", [False, True])
def test_pallas_matches_ref(b, L, H, P, N, chunk, with_h0):
    x, dt, a, B, C, h0 = _case(b, L, H, P, N)
    init = h0 if with_h0 else None
    y1, s1 = ssd_chunked_pallas(x, dt, a, B, C, chunk=chunk,
                                initial_state=init)
    y2, s2 = ssd_chunked_ref(x, dt, a, B, C, chunk=chunk, initial_state=init)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_matches_recurrence(chunk):
    x, dt, a, B, C, h0 = _case(2, 64, 4, 8, 16)
    y1, s1 = ssd_recurrent_ref(x, dt, a, B, C, initial_state=h0)
    y2, s2 = ssd_chunked_ref(x, dt, a, B, C, chunk=chunk, initial_state=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_decode_step_matches_recurrence():
    x, dt, a, B, C, h0 = _case(2, 8, 4, 8, 16)
    y_seq, _ = ssd_recurrent_ref(x, dt, a, B, C, initial_state=h0)
    h = h0
    for t in range(8):
        y_t, h = ssd_decode_step_ref(h, x[:, t], dt[:, t], a[:, t],
                                     B[:, t], C[:, t])
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_seq[:, t]),
                                   rtol=1e-4, atol=1e-4)


def test_bf16_inputs():
    x, dt, a, B, C, _ = _case(1, 32, 2, 8, 16)
    y1, s1 = ssd_chunked_pallas(x.astype(jnp.bfloat16), dt, a,
                                B.astype(jnp.bfloat16),
                                C.astype(jnp.bfloat16), chunk=16)
    y2, s2 = ssd_chunked_ref(x.astype(jnp.bfloat16), dt, a,
                             B.astype(jnp.bfloat16),
                             C.astype(jnp.bfloat16), chunk=16)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-2, atol=2e-2)


def test_ops_pallas_path_differentiable():
    x, dt, a, B, C, _ = _case(1, 32, 2, 4, 8)
    kops.use_pallas(True)
    try:
        def loss(x, B, C):
            y, h = kops.ssd(x, dt, a, B, C, chunk=16)
            return jnp.sum(y ** 2) + jnp.sum(h ** 2)
        g1 = jax.grad(loss, argnums=(0, 1, 2))(x, B, C)
    finally:
        kops.use_pallas(False)

    def loss_ref(x, B, C):
        y, h = ssd_chunked_ref(x, dt, a, B, C, 16)
        return jnp.sum(y ** 2) + jnp.sum(h ** 2)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, B, C)
    for a_, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_ops_padding_path():
    # L not a multiple of chunk: ops.ssd pads state-neutrally
    x, dt, a, B, C, h0 = _case(1, 33, 2, 4, 8)
    y1, s1 = kops.ssd(x, dt, a, B, C, chunk=16, initial_state=h0)
    y2, s2 = ssd_recurrent_ref(x, dt, a, B, C, initial_state=h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
