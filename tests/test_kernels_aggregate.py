"""aggregate_loss Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.aggregate_loss import aggregate_loss_pallas
from repro.kernels.ref import aggregate_loss_chunked_ref, aggregate_loss_ref


def _case(rng, T, K, M, cat):
    ids = rng.integers(0, cat + 1, (T, K)).astype(np.int32)
    elt = np.abs(rng.normal(size=(cat + 1, M))).astype(np.float32)
    elt[0] = 0.0
    occ_r = (np.abs(rng.normal(size=M)) * 0.5).astype(np.float32)
    occ_l = (np.abs(rng.normal(size=M)) + 1.0).astype(np.float32)
    return (jnp.asarray(ids), jnp.asarray(elt), jnp.asarray(occ_r),
            jnp.asarray(occ_l), np.float32(K * 0.1), np.float32(K * 0.8))


SWEEP = [
    # T, K, M, cat, chunk, trial_block, rows_tile
    (64, 32, 3, 512, 16, 32, None),
    (128, 64, 5, 1000, 32, 64, 256),
    (32, 16, 1, 100, 8, 8, 64),
    (256, 128, 15, 4096, 128, 256, 512),
    (17, 24, 2, 50, 8, 16, None),      # odd trial count
    (48, 96, 7, 333, 48, 16, 100),     # non-pow2 catalog/tile
]


@pytest.mark.parametrize("variant", ["gather", "onehot"])
@pytest.mark.parametrize("T,K,M,cat,chunk,tb,rt", SWEEP)
def test_pallas_matches_oracle(rng, T, K, M, cat, chunk, tb, rt, variant):
    args = _case(rng, T, K, M, cat)
    got = aggregate_loss_pallas(*args, chunk=chunk, trial_block=tb,
                                rows_tile=rt, variant=variant)
    want = aggregate_loss_chunked_ref(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_variant_selection_via_ops(rng):
    """kernels.ops routes the configured variant to the Pallas kernel."""
    from repro.kernels import ops as kops
    args = _case(rng, 32, 16, 2, 128)
    want = np.asarray(aggregate_loss_chunked_ref(*args, chunk=8))
    prev_pallas, prev_variant = kops.pallas_enabled(), kops.aggregate_variant()
    kops.use_pallas(True)
    try:
        for variant in ("gather", "onehot"):
            kops.use_aggregate_variant(variant)
            assert kops.aggregate_variant() == variant
            got = np.asarray(kops.aggregate_loss(*args, chunk=8))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)
    finally:
        kops.use_pallas(prev_pallas)
        kops.use_aggregate_variant(prev_variant)


def test_chunked_ref_matches_unchunked(rng):
    args = _case(rng, 64, 64, 4, 256)
    a = aggregate_loss_ref(*args)
    for chunk in (8, 16, 32, 64):
        b = aggregate_loss_chunked_ref(*args, chunk=chunk)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_pad_event_contributes_zero(rng):
    ids = jnp.zeros((8, 16), jnp.int32)        # all pads
    elt = jnp.ones((100, 3), jnp.float32).at[0].set(0.0)
    z = aggregate_loss_pallas(ids, elt, jnp.zeros(3), jnp.full(3, 1e9),
                              np.float32(0), np.float32(1e9), chunk=16)
    np.testing.assert_allclose(np.asarray(z), 0.0)


def test_occurrence_and_aggregate_clipping(rng):
    # one trial, one event of loss 10; occ_ret 2, occ_lim 5 -> event loss 5
    ids = jnp.asarray([[1]], jnp.int32)
    elt = jnp.zeros((3, 1), jnp.float32).at[1, 0].set(10.0)
    y = aggregate_loss_pallas(ids, elt, jnp.asarray([2.0]),
                              jnp.asarray([5.0]), np.float32(1.0),
                              np.float32(3.0), chunk=1)
    # aggregate: max(5-1,0)=4, capped at 3
    np.testing.assert_allclose(np.asarray(y), [3.0])


def test_int32_vs_int64_ids_and_f32(rng):
    args = list(_case(rng, 32, 32, 3, 128))
    got32 = aggregate_loss_pallas(*args, chunk=16)
    args[0] = args[0].astype(jnp.int32)
    got = aggregate_loss_pallas(*args, chunk=16)
    np.testing.assert_allclose(np.asarray(got32), np.asarray(got))
