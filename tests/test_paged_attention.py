"""Fused paged-attention kernels: interpret-mode parity + backend contracts.

Two layers of lock-in for ``kernels/paged_attention.py``:

* **kernel vs oracle parity** — the Pallas decode kernel against
  :func:`repro.kernels.ref.paged_attention_decode_ref` (the dense-gather
  math the jnp serving backend runs verbatim) across page sizes, GQA
  ratios, ragged per-row lengths, sliding windows and SENTINEL-padded
  tables, to float32-rounding tolerance (the online softmax reassociates
  the reduction, so bitwise equality is not expected — token-level
  equality is, and the end-to-end tests assert it); the prefill scatter
  kernel against :func:`repro.kernels.ref.paged_scatter_ref` *bit-exactly*
  (it performs no arithmetic beyond the storage cast).
* **backend contracts** — ``ContinuousBatchingEngine(backend="pallas")``
  decodes greedy token-exactly with the jnp backend and with blocking
  ``generate`` on attention, sliding-window and hybrid (jamba) archs,
  including after page eviction/reuse under pool pressure, across
  shared/CoW-forked pages and skip-prefill full-prefix hits, and keeps the
  compile-count contract: one decode-round trace per (capacity, sampling
  tier) no matter the request mix.

A physical-page permutation property (seeded fuzz + Hypothesis where
installed) pins down that the kernel's output depends on page *content*
reached through the table, never on physical page ids.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention import (paged_attention_decode_pallas,
                                           paged_prefill_scatter_pallas)
from repro.kernels.ref import paged_attention_decode_ref, paged_scatter_ref
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import POS_SENTINEL, PagedKVCache
from repro.serving.multitenant import Request

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI installs it
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# synthetic paged states
# ---------------------------------------------------------------------------
def _rand_paged_state(rng, *, C, NB, P, H, Hkv, D, n_extra_pages=3):
    """A plausible mid-decode paged state: per-row rings of ragged length
    laid out over distinct physical pages (SENTINEL-padded tables), the
    position plane holding the dense ring's positions, plus unreferenced
    distractor pages full of garbage."""
    NP_ = PagedKVCache.RESERVED + C * NB + n_extra_pages
    k_pool = rng.standard_normal((NP_, P, Hkv, D)).astype(np.float32)
    v_pool = rng.standard_normal((NP_, P, Hkv, D)).astype(np.float32)
    pos_pool = np.full((NP_, P), POS_SENTINEL, np.int32)
    page_table = np.full((C, NB), PagedKVCache.SENTINEL, np.int32)
    free = list(rng.permutation(np.arange(PagedKVCache.RESERVED, NP_)))
    positions = np.zeros((C,), np.int32)
    for c in range(C):
        nb_c = int(rng.integers(1, NB + 1))        # ragged ring lengths
        ring = nb_c * P
        pos = int(rng.integers(ring - P, 2 * ring + 3))  # may have wrapped
        positions[c] = pos
        pages = [free.pop() for _ in range(nb_c)]
        page_table[c, :nb_c] = pages
        for j in range(ring):                      # dense ring semantics:
            filled = j <= pos                      # slot j holds the latest
            wraps = (pos - j) // ring if filled else 0   # pos' = j (mod ring)
            pos_pool[pages[j // P], j % P] = (
                j + wraps * ring if filled else POS_SENTINEL)
    return (jnp.asarray(k_pool, jnp.bfloat16), jnp.asarray(v_pool,
                                                           jnp.bfloat16),
            jnp.asarray(pos_pool), jnp.asarray(page_table),
            jnp.asarray(positions),
            jnp.asarray(rng.standard_normal((C, H, D)).astype(np.float32)))


def _agree(a, b, rtol=3e-5, atol=3e-6):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol,
                               atol=atol)


# ---------------------------------------------------------------------------
# kernel vs oracle parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("P,NB,H,Hkv,window", [
    (4, 3, 4, 2, None),          # GQA 2:1, three ragged blocks
    (8, 2, 2, 2, None),          # MHA
    (4, 2, 4, 1, None),          # MQA
    (4, 4, 4, 2, 6),             # sliding window smaller than the ring
])
def test_decode_kernel_matches_oracle(P, NB, H, Hkv, window):
    rng = np.random.default_rng(hash((P, NB, H, Hkv, window or 0)) % 2**31)
    D = 8
    k_pool, v_pool, pos_pool, pt, pos, q = _rand_paged_state(
        rng, C=3, NB=NB, P=P, H=H, Hkv=Hkv, D=D)
    got = paged_attention_decode_pallas(q, k_pool, v_pool, pos_pool, pt,
                                        pos, window=window)
    want = paged_attention_decode_ref(q, k_pool, v_pool, pt, pos,
                                      pos_pool=pos_pool, window=window)
    assert got.shape == want.shape == (3, H, D)
    assert got.dtype == want.dtype == jnp.float32
    _agree(got, want)


def test_decode_kernel_all_masked_row_degenerates_like_softmax():
    """A row whose table is all SENTINEL (fresh slot / masked lane) must
    produce the same uniform-average degenerate output as the full softmax
    over an all-(-1e30) score row — no NaNs, no infs."""
    rng = np.random.default_rng(5)
    k_pool, v_pool, pos_pool, pt, pos, q = _rand_paged_state(
        rng, C=2, NB=2, P=4, H=2, Hkv=2, D=8)
    pt = pt.at[0].set(PagedKVCache.SENTINEL)       # row 0: nothing valid
    got = paged_attention_decode_pallas(q, k_pool, v_pool, pos_pool, pt, pos)
    want = paged_attention_decode_ref(q, k_pool, v_pool, pt, pos,
                                      pos_pool=pos_pool)
    assert np.isfinite(np.asarray(got)).all()
    _agree(got, want)


def test_decode_kernel_under_jit_and_vs_dense_window():
    """The kernel composes with jit (the round jit wraps it) and agrees
    with the oracle when every row shares one full-block ring — the densest
    case, where the dense gather wastes the least."""
    rng = np.random.default_rng(11)
    k_pool, v_pool, pos_pool, pt, pos, q = _rand_paged_state(
        rng, C=4, NB=3, P=4, H=4, Hkv=2, D=8)
    f = jax.jit(lambda *a: paged_attention_decode_pallas(*a))
    _agree(f(q, k_pool, v_pool, pos_pool, pt, pos),
           paged_attention_decode_ref(q, k_pool, v_pool, pt, pos,
                                      pos_pool=pos_pool))


def _permute_pages(perm, k_pool, v_pool, pos_pool, pt):
    """Relabel physical pages by ``perm`` (identity on reserved pages):
    pool rows move to their new ids and the table follows."""
    inv = np.argsort(perm)
    return (k_pool[inv], v_pool[inv], pos_pool[inv],
            jnp.asarray(perm)[pt])


def _page_permutation(rng_or_data, NP_, draw=None):
    ids = np.arange(NP_)
    body = ids[PagedKVCache.RESERVED:].copy()
    if draw is None:
        rng_or_data.shuffle(body)
    else:
        body = np.asarray(draw(st.permutations(list(body))))
    ids[PagedKVCache.RESERVED:] = body
    return ids


def test_page_permutation_invariance_fuzz():
    """Physical page ids are pure routing: relabelling every page (pool
    rows + table entries consistently) must leave the kernel output
    *bitwise* unchanged — the kernel may depend on page content and block
    order only."""
    rng = np.random.default_rng(17)
    for trial in range(6):
        k_pool, v_pool, pos_pool, pt, pos, q = _rand_paged_state(
            rng, C=3, NB=3, P=4, H=4, Hkv=2, D=8)
        base = np.asarray(paged_attention_decode_pallas(
            q, k_pool, v_pool, pos_pool, pt, pos))
        perm = _page_permutation(rng, k_pool.shape[0])
        kp, vp, pp_, ptp = _permute_pages(perm, k_pool, v_pool, pos_pool, pt)
        got = np.asarray(paged_attention_decode_pallas(
            q, kp, vp, pp_, ptp, pos))
        np.testing.assert_array_equal(base, got)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_page_permutation_invariance_property():
    """The same invariance under Hypothesis-shrunk permutations."""
    rng = np.random.default_rng(23)
    state = _rand_paged_state(rng, C=2, NB=2, P=4, H=2, Hkv=2, D=8)
    k_pool, v_pool, pos_pool, pt, pos, q = state
    base = np.asarray(paged_attention_decode_pallas(
        q, k_pool, v_pool, pos_pool, pt, pos))

    @settings(max_examples=10, deadline=None)
    @given(st.data())
    def run(data):
        perm = _page_permutation(None, k_pool.shape[0], draw=data.draw)
        kp, vp, pp_, ptp = _permute_pages(perm, k_pool, v_pool, pos_pool, pt)
        got = np.asarray(paged_attention_decode_pallas(
            q, kp, vp, pp_, ptp, pos))
        np.testing.assert_array_equal(base, got)

    run()


def test_prefill_scatter_kernel_bit_exact():
    """The scatter kernel is bit-exact with the jnp ``at[].set`` hop: the
    named pages carry exactly the cast values, every other page — live
    neighbours, SENTINEL, TRASH — is bit-untouched."""
    rng = np.random.default_rng(3)
    S, NP_, P, Hkv, D, nb = 2, 9, 4, 2, 8, 3
    pool = jnp.asarray(rng.standard_normal((S, NP_, P, Hkv, D)),
                       jnp.bfloat16)
    values = jnp.asarray(
        rng.standard_normal((S, nb, P, Hkv, D)).astype(np.float32))
    pages = jnp.asarray([4, 2, 7], jnp.int32)
    got = paged_prefill_scatter_pallas(pool, pages, values)
    want = paged_scatter_ref(pool, pages, values)
    assert got.dtype == pool.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
    # and under jit with donation, as the admission jit runs it
    f = jax.jit(paged_prefill_scatter_pallas, donate_argnums=(0,))
    got2 = f(want, pages, values)
    np.testing.assert_array_equal(np.asarray(got2, np.float32),
                                  np.asarray(got, np.float32))


# ---------------------------------------------------------------------------
# backend contracts (end-to-end through the continuous engine)
# ---------------------------------------------------------------------------
def _make_engine(arch):
    cfg = get_config(arch).reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params)


@pytest.fixture(scope="module")
def engine():
    return _make_engine("internlm2-1.8b")


def _oracle(engine, ceng, req):
    b = ceng.bucket_len(req.prompt.size)
    padded = np.zeros((1, b), np.int32)
    padded[0, b - req.prompt.size:] = req.prompt
    return engine.generate(padded, max_new_tokens=req.max_new_tokens,
                           seed=req.seed).tokens[0]


def test_pallas_backend_token_exact_with_eviction_and_reuse(engine):
    """backend="pallas" under pool pressure: page eviction and reuse, with
    every request token-exact against blocking generate — recycled pages
    must not leak stale KV through the fused read."""
    rng = np.random.default_rng(31)
    ceng = ContinuousBatchingEngine(engine, capacity=4, page_size=8,
                                    num_pages=2 + 4, inner_steps=2,
                                    max_prompt_len=16, prefix_sharing=False,
                                    backend="pallas")
    reqs = [Request("a", rng.integers(1, engine.cfg.vocab_size,
                                      12).astype(np.int32),
                    max_new_tokens=3) for _ in range(5)]
    done = ceng.run_all(reqs)
    assert len(done) == 5
    assert ceng.kv.pages_reused >= 6          # reuse was actually forced
    for req, tokens in done:
        np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)


def test_pallas_backend_token_exact_with_sharing_and_cow(engine):
    """backend="pallas" across the sharing lifecycle: shared prefix pages,
    CoW forks on first decode write, a skip-prefill full-prefix repeat, and
    a replay after churn evicted the cached chain — all token-exact with
    generate and bit-identical to the jnp backend."""
    cfg = engine.cfg
    rng = np.random.default_rng(37)
    sys_prompt = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    mk = lambda t: Request(f"t{t}", np.concatenate(
        [sys_prompt, rng.integers(1, cfg.vocab_size, 8).astype(np.int32)]),
        max_new_tokens=4)
    wave = [mk(t) for t in range(3)]
    repeat = Request("t0", wave[0].prompt.copy(), max_new_tokens=4)
    churn = [Request("x", rng.integers(1, cfg.vocab_size, 32).astype(
        np.int32), max_new_tokens=2) for _ in range(6)]
    wave2 = [mk(t) for t in range(3)]

    def run(backend):
        ceng = ContinuousBatchingEngine(engine, capacity=3, page_size=8,
                                        inner_steps=4, max_prompt_len=32,
                                        backend=backend)
        out = [t for _, t in ceng.run_all(wave)]
        out += [t for _, t in ceng.run_all([repeat])]
        ceng.run_all(churn)
        out += [t for _, t in ceng.run_all(wave2)]
        return ceng, out

    ceng_p, toks_p = run("pallas")
    assert ceng_p.kv.pages_shared > 0
    assert ceng_p.kv.cow_forks + ceng_p.kv.pristine_forks > 0
    assert ceng_p.prefill_skips >= 1          # the full-prefix repeat hit
    ceng_p.kv.assert_conserved()
    ceng_j, toks_j = run("jnp")
    assert len(toks_p) == len(toks_j) == 7
    for a, b in zip(toks_p, toks_j):
        np.testing.assert_array_equal(a, b)
    # spot-check the shared wave against the blocking engine too
    for req, tokens in zip(wave, toks_p[:3]):
        np.testing.assert_array_equal(_oracle(engine, ceng_p, req), tokens)


def test_pallas_backend_sliding_window_arch():
    """Sliding-window arch (ring wraps inside the bucket): the in-kernel
    window mask must match the gather path token-for-token."""
    engine = _make_engine("h2o-danube-1.8b")
    rng = np.random.default_rng(41)
    reqs = [Request("a", rng.integers(1, engine.cfg.vocab_size,
                                      6 + 4 * i).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    out = {}
    for backend in ("jnp", "pallas"):
        ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=4,
                                        inner_steps=3, max_prompt_len=16,
                                        backend=backend)
        # PR 9: SWA no longer disables sharing — chain keys carry the
        # window phase, so these distinct prompts simply never match
        assert ceng.prefix_sharing
        out[backend] = {id(r): t for r, t in ceng.run_all(reqs)}
    for r in reqs:
        np.testing.assert_array_equal(out["jnp"][id(r)],
                                      out["pallas"][id(r)])


def test_pallas_backend_hybrid_arch_matches_jnp():
    """Hybrid (jamba: mamba + attention + MoE) through both backends: only
    the attention pool read differs, so rows must match token-for-token
    (MoE couples rows, but identically in both engines)."""
    engine = _make_engine("jamba-1.5-large-398b")
    rng = np.random.default_rng(43)
    reqs = [Request("a", rng.integers(1, engine.cfg.vocab_size,
                                      5 + 3 * i).astype(np.int32),
                    max_new_tokens=3) for i in range(2)]
    out = {}
    for backend in ("jnp", "pallas"):
        ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                        inner_steps=3, max_prompt_len=16,
                                        backend=backend)
        out[backend] = {id(r): t for r, t in ceng.run_all(reqs)}
    for r in reqs:
        np.testing.assert_array_equal(out["jnp"][id(r)],
                                      out["pallas"][id(r)])


def test_pallas_backend_compile_count(engine):
    """The fused backend keeps the compile-count contract: one decode-round
    trace per (capacity, sampling tier) across ragged budget/bucket mixes,
    one admission trace per bucket, one prefill trace per (bucket, width
    tier) — the kernel's page streaming never retraces with the mix."""
    rng = np.random.default_rng(47)
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=32,
                                    backend="pallas")
    cfg = engine.cfg
    mk = lambda plen, steps: Request("a", rng.integers(
        1, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=steps)
    ceng.run_all([mk(6, 1), mk(8, 5), mk(7, 9)])
    assert ceng.decode_traces == 1
    assert ceng.admit_traces == 1
    assert ceng.prefill_traces == 2
    ceng.run_all([mk(12, 2), mk(16, 7)])
    assert ceng.decode_traces == 1            # same capacity, same tier
    assert ceng.admit_traces == 2
    ceng.run_all([mk(5, 11), mk(14, 3)])
    assert ceng.decode_traces == 1
    assert ceng.admit_traces == 2


def test_backend_validation(engine):
    with pytest.raises(ValueError, match="backend"):
        ContinuousBatchingEngine(engine, capacity=2, max_prompt_len=16,
                                 backend="cuda")
