"""Optimizers: numerics, state sharding axes, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.training.optimizer import adafactor, adamw, lr_schedule


def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("make_opt", [lambda: adamw(weight_decay=0.0),
                                      lambda: adafactor()])
def test_optimizers_converge_on_quadratic(make_opt):
    params, loss, target = _quad_problem()
    opt = make_opt()
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.float32(0.05))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.15)


def test_adamw_first_step_is_lr_sized():
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0, -1.0, 2.0, -3.0])}
    new_p, _ = opt.update(g, state, params, jnp.float32(0.1))
    # bias-corrected adam: first step ~= -lr * sign(g)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               -0.1 * np.sign(np.asarray(g["w"])), rtol=1e-3)


def test_adamw_weight_decay_decoupled():
    opt = adamw(weight_decay=0.5)
    params = {"w": jnp.ones(2)}
    state = opt.init(params)
    g = {"w": jnp.zeros(2)}
    new_p, _ = opt.update(g, state, params, jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(new_p["w"]), 1.0 - 0.1 * 0.5,
                               rtol=1e-5)


def test_adafactor_factored_state_shapes():
    opt = adafactor(min_dim_factored=4)
    params = {"big": jnp.zeros((8, 16)), "small": jnp.zeros(3),
              "stack": jnp.zeros((2, 8, 16))}
    st = opt.init(params)
    assert st["v"]["big"]["vr"].shape == (8,)
    assert st["v"]["big"]["vc"].shape == (16,)
    assert st["v"]["stack"]["vr"].shape == (2, 8)
    assert st["v"]["stack"]["vc"].shape == (2, 16)
    assert st["v"]["small"]["v"].shape == (3,)
    assert st["m"]["big"].dtype == jnp.bfloat16


def test_state_axes_mirror_param_axes():
    opt_a = adamw()
    p_axes = {"w": ("fsdp", "heads"), "b": (None,)}
    p_shapes = {"w": jax.ShapeDtypeStruct((256, 256), jnp.float32),
                "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    ax = opt_a.state_axes(p_axes, p_shapes)
    assert ax["m"] == p_axes and ax["v"] == p_axes

    opt_f = adafactor()
    axf = opt_f.state_axes(p_axes, p_shapes)
    assert axf["v"]["w"] == {"vr": ("fsdp",), "vc": ("heads",)}
    assert axf["v"]["b"] == {"v": (None,)}


def test_lr_schedule_shape():
    cfg = get_config("internlm2-1.8b")
    lr = lr_schedule(cfg, warmup=10, total=100)
    vals = [float(lr(jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert vals[0] == 0.0
    assert vals[1] < vals[2]
    assert vals[2] >= vals[3] >= vals[4] > 0.0


def test_state_dtype_is_fp32_for_bf16_params():
    opt = adamw()
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_p, _ = opt.update(g, st, params, jnp.float32(0.1))
    assert new_p["w"].dtype == jnp.bfloat16
