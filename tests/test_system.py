"""End-to-end behaviour tests for the paper's system.

The paper's claims, executed on the real (CPU-reduced) stack:
  1. multi-tenancy with sequential transfers returns identical risk numbers
     while the schedule model shows lower makespan/energy (Figs 11-14);
  2. the deployment planner picks the paper's optima (Figs 17-22);
  3. a small LM actually trains end-to-end through the same tenancy-aware
     substrate (microbatch accumulation, prefetch feed, checkpoint restart).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.risk_app import RiskAppConfig
from repro.core import perfmodel as pm
from repro.core.planner import plan
from repro.core.simulator import SimInputs, simulate_cells
from repro.core.tenancy import TenancyConfig
from repro.data.tokens import DataConfig, synth_batch
from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import null_sharder
from repro.models import params as pp
from repro.models.model import build_model
from repro.risk.analysis import AggregateRiskAnalysis
from repro.risk.tables import generate
from repro.training.optimizer import make_optimizer
from repro.training.train_loop import build_train_step, init_train_state


def test_paper_pipeline_end_to_end():
    """§IV+V: generate tables -> multi-tenant analysis -> identical YLT with
    1, 2, 4 tenants; schedule model orders makespans 1 > 2 > 4."""
    cfg = RiskAppConfig().reduced()
    tables = generate(cfg)
    ylts = {}
    for tenants in (1, 2, 4):
        ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, tenants))
        ylts[tenants] = ara.run_tenant_chunked(tables).ylt
    np.testing.assert_allclose(ylts[1], ylts[2], rtol=1e-6)
    np.testing.assert_allclose(ylts[1], ylts[4], rtol=1e-6)
    spans = [simulate_cells(SimInputs(TenancyConfig(4, t))).makespan
             for t in (1, 2, 4)]
    assert spans[0] > spans[1] > spans[2]


def test_planner_drives_deployment():
    m = pm.PerfModelInputs(net=pm.FDR)
    d = plan(m, "time")
    cfg = dataclasses.replace(RiskAppConfig().reduced(),
                              tenants_per_device=d.tenants_per_pdev)
    ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, d.tenants_per_pdev))
    tables = generate(cfg)
    rep = ara.run_tenant_chunked(tables)
    assert len(rep.per_tenant_s) == d.tenants_per_pdev


def test_lm_trains_and_loss_falls():
    cfg = dataclasses.replace(get_config("internlm2-1.8b").reduced(),
                              microbatches=2)
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    opt = make_optimizer(cfg)
    state = init_train_state(bundle, opt, params)
    step = jax.jit(build_train_step(bundle, sh, opt,
                                    lr_fn=lambda s: jnp.float32(5e-3)))
    dc = DataConfig(8, 32, cfg.vocab_size)
    losses = []
    for i in range(30):
        state, metrics = step(state, {k: jnp.asarray(v) for k, v in
                                      synth_batch(dc, i).items()})
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatch_tenancy_matches_single_shot():
    """Tenant microbatch accumulation == one big batch (same grads/loss)."""
    cfg1 = get_config("internlm2-1.8b").reduced()
    cfg2 = dataclasses.replace(cfg1, microbatches=4)
    sh = null_sharder()
    b1 = build_model(cfg1)
    params, _ = pp.split(b1.init(jax.random.PRNGKey(0)))
    opt = make_optimizer(cfg1)
    dc = DataConfig(8, 32, cfg1.vocab_size)
    batch = {k: jnp.asarray(v) for k, v in synth_batch(dc, 0).items()}
    s1, m1 = jax.jit(build_train_step(b1, sh, opt))(
        init_train_state(b1, opt, params), batch)
    b2 = build_model(cfg2)
    s2, m2 = jax.jit(build_train_step(b2, sh, opt))(
        init_train_state(b2, opt, params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)


def test_train_checkpoint_restart_bitexact(tmp_path):
    """Crash after step 3, restore, continue: same state as uninterrupted."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    bundle = build_model(cfg)
    sh = null_sharder()
    params, _ = pp.split(bundle.init(jax.random.PRNGKey(0)))
    opt = make_optimizer(cfg)
    step = jax.jit(build_train_step(bundle, sh, opt))
    dc = DataConfig(4, 16, cfg.vocab_size)

    def advance(state, lo, hi):
        for i in range(lo, hi):
            state, _ = step(state, {k: jnp.asarray(v) for k, v in
                                    synth_batch(dc, i).items()})
        return state

    ref = advance(init_train_state(bundle, opt, params), 0, 6)
    mid = advance(init_train_state(bundle, opt, params), 0, 3)
    ckpt.save(tmp_path, 3, mid)
    restored = ckpt.restore(tmp_path, 3, mid)
    final = advance(restored, 3, 6)
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(final["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
