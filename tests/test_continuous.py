"""Continuous batching + paged KV-cache: exactness, eviction, compile count.

The headline harness for the PR 3 serving subsystem:

* greedy token-exactness of :class:`repro.serving.continuous.
  ContinuousBatchingEngine` against ``ServingEngine.generate`` on the same
  page-aligned padded prompt, per request, under ragged prompt/budget mixes
  (decoder-only attention and pure-SSM families);
* :class:`repro.serving.kvcache.PagedKVCache` page reuse after eviction:
  under pool pressure later requests must recycle freed pages and still
  decode token-exactly (stale positions cannot leak through the mask);
* compile-count stability: the masked fixed-step decode round traces once
  per batch capacity regardless of the ``max_new_tokens`` mix, and
  admission traces once per prompt bucket;
* per-request sampling (temperature / top-k / seed) through both the
  continuous slot-table carry and the split engine's scan carry;
* the scheduler's ``mode="continuous"`` end to end: token-exact responses,
  per-tenant accounting, monotone CompletionWaiter-stamped round windows.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request


def _make_engine(arch: str, temperature: float = 0.0) -> ServingEngine:
    cfg = get_config(arch).reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params, temperature=temperature)


@pytest.fixture(scope="module")
def engine():
    return _make_engine("internlm2-1.8b")


@pytest.fixture(scope="module")
def ceng(engine):
    # one shared continuous engine per module: jit caches are per-instance
    # and a drained slot table is fully reusable
    return ContinuousBatchingEngine(engine, capacity=3, page_size=8,
                                    inner_steps=4, max_prompt_len=64)


def _oracle(engine: ServingEngine, ceng: ContinuousBatchingEngine,
            req: Request) -> np.ndarray:
    """generate() on the request's page-aligned left-padded prompt — the
    continuous path's exactness contract."""
    b = ceng.bucket_len(req.prompt.size)
    padded = np.zeros((1, b), np.int32)
    padded[0, b - req.prompt.size:] = req.prompt
    return engine.generate(padded, max_new_tokens=req.max_new_tokens,
                           seed=req.seed).tokens[0]


def _ragged_requests(cfg, rng, n=5):
    return [Request(f"t{i % 2}",
                    rng.integers(1, cfg.vocab_size,
                                 8 + 5 * (i % 3)).astype(np.int32),
                    max_new_tokens=3 + 2 * (i % 3))
            for i in range(n)]


def test_continuous_token_exact_vs_generate(engine, ceng, rng):
    """Each admitted request decodes token-for-token like the blocking
    engine on the same padded prompt, independent of its slot neighbours
    (ragged prompts, ragged budgets, capacity < request count)."""
    reqs = _ragged_requests(engine.cfg, rng)
    done = ceng.run_all(reqs)
    assert len(done) == len(reqs)
    for req, tokens in done:
        np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)
        assert tokens.shape == (req.max_new_tokens,)


def test_continuous_token_exact_ssm_family(rng):
    """Pure-SSM family (no attention pool at all): slot-table states carry
    the whole cache; exactness must hold there too."""
    engine = _make_engine("mamba2-2.7b")
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=3, max_prompt_len=32)
    reqs = [Request("a", rng.integers(1, engine.cfg.vocab_size,
                                      6 + 3 * i).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    for req, tokens in ceng.run_all(reqs):
        np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)


def test_page_reuse_after_eviction_token_exact(engine, rng):
    """Pool pressure: capacity 4 slots but pages for only ~2 concurrent
    rings, so admission must wait for eviction and recycle freed pages —
    and recycled pages must decode exactly (no stale position/KV leaks).
    Runs unshared (prefix_sharing=False) so the PR-3 LIFO allocation counts
    stay exact; the sharing paths have their own counters tests."""
    ceng = ContinuousBatchingEngine(engine, capacity=4, page_size=8,
                                    num_pages=2 + 4, inner_steps=2,
                                    max_prompt_len=16, prefix_sharing=False)
    reqs = [Request("a", rng.integers(1, engine.cfg.vocab_size,
                                      12).astype(np.int32),
                    max_new_tokens=3) for _ in range(5)]
    done = ceng.run_all(reqs)
    assert len(done) == 5
    # 5 requests x 2 pages each through a 4-page pool: reuse is forced
    assert ceng.kv.pages_allocated == 10
    assert ceng.kv.pages_reused >= 6
    assert ceng.kv.free_pages() == 4                    # all evicted back
    for req, tokens in done:
        np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)


def test_compile_count_stable_under_ragged_mix(engine, rng):
    """The decode round is shape-stable: one trace per (capacity, sampling
    tier) no matter how ragged the max_new_tokens mix; the admission scatter
    traces once per prompt bucket; the batched admission prefill traces once
    per (prompt bucket, power-of-two admission width)."""
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=4, max_prompt_len=32)
    cfg = engine.cfg
    mk = lambda plen, steps: Request("a", rng.integers(
        1, cfg.vocab_size, plen).astype(np.int32), max_new_tokens=steps)
    # one prompt bucket (8), three different token budgets: the first two
    # admissions batch into one width-2 prefill, the third runs at width 1
    ceng.run_all([mk(6, 1), mk(8, 5), mk(7, 9)])
    assert ceng.decode_traces == 1
    assert ceng.admit_traces == 1
    assert ceng.prefill_traces == 2        # (bucket 8, widths 2 and 1)
    assert ceng.prefill_calls == 2         # 3 requests, 2 host calls
    # second bucket (16) compiles admission once more and one width-2
    # prefill, decode not at all
    ceng.run_all([mk(12, 2), mk(16, 7)])
    assert ceng.decode_traces == 1
    assert ceng.admit_traces == 2
    assert ceng.prefill_traces == 3        # + (bucket 16, width 2)
    assert ceng.prefill_calls == 3
    # replaying both buckets with fresh ragged budgets only fills in the
    # not-yet-seen (bucket 16, width 1) tier; nothing else retraces
    ceng.run_all([mk(5, 11), mk(14, 3)])
    assert ceng.decode_traces == 1
    assert ceng.admit_traces == 2
    assert ceng.prefill_traces == 4        # + (bucket 16, width 1)
    assert ceng.prefill_calls == 5


def test_per_request_sampling_continuous(engine, ceng, rng):
    """Per-row sampling params in the slot-table carry: top_k=1 collapses to
    greedy, temperature rows vary by seed, and a greedy row sharing the
    table with temperature rows stays token-exact with generate()."""
    cfg = engine.cfg
    p = rng.integers(1, cfg.vocab_size, 10).astype(np.int32)
    greedy = Request("a", p, 6)
    topk1 = Request("a", p.copy(), 6, temperature=0.9, top_k=1, seed=3)
    temp5 = Request("a", p.copy(), 6, temperature=1.2, seed=5)
    temp9 = Request("a", p.copy(), 6, temperature=1.2, seed=9)
    out = {id(r): t for r, t in ceng.run_all([greedy, topk1, temp5, temp9])}
    np.testing.assert_array_equal(out[id(greedy)],
                                  _oracle(engine, ceng, greedy))
    np.testing.assert_array_equal(out[id(greedy)], out[id(topk1)])
    assert not np.array_equal(out[id(temp5)], out[id(temp9)])


def test_per_request_sampling_dispatch(rng):
    """The same sampling triple threads through the split engine's scanned
    decode-loop carry: greedy rows match the scalar dispatch token-exactly
    while a temperature neighbour varies by seed."""
    engine = _make_engine("internlm2-1.8b")
    cfg = engine.cfg
    prompts = rng.integers(1, cfg.vocab_size, (3, 12)).astype(np.int32)
    scalar = engine.await_result(engine.dispatch(prompts, 5))
    a = engine.await_result(engine.dispatch(
        prompts, 5, temperatures=[0.0, 0.0, 1.3], seeds=[0, 0, 4]))
    b = engine.await_result(engine.dispatch(
        prompts, 5, temperatures=[0.0, 0.0, 1.3], seeds=[0, 0, 11]))
    np.testing.assert_array_equal(scalar.tokens[:2], a.tokens[:2])
    np.testing.assert_array_equal(scalar.tokens[:2], b.tokens[:2])
    assert not np.array_equal(a.tokens[2], b.tokens[2])
    # top_k=1 == greedy row-wise even at temperature
    c = engine.await_result(engine.dispatch(
        prompts, 5, temperatures=[0.8] * 3, top_ks=[1] * 3, seeds=[7] * 3))
    np.testing.assert_array_equal(scalar.tokens, c.tokens)


def test_scheduler_continuous_end_to_end(engine, ceng, rng):
    """mode='continuous' through the scheduler: every response token-exact
    per request, per-tenant accounting complete, round windows monotone and
    stamped at device readiness."""
    cfg = engine.cfg
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng)
    assert sched.continuous_engine is ceng
    rounds0 = ceng.rounds
    reqs = _ragged_requests(cfg, rng, n=7)
    for r in reqs:
        sched.submit(r)
    responses = sched.drain()
    assert len(responses) == 7
    # every dispatched round was collected and stamped: no dangling
    # all-masked round left in flight after the drain
    assert sched._cont_inflight is None
    assert len(sched.timeline) == ceng.rounds - rounds0
    for resp in responses:
        assert resp.tenant in {"t0", "t1"}
        assert resp.latency_s > 0
    rep = sched.utilization_report()
    assert sum(r["requests"] for r in rep.values()) == 7
    assert sum(r["tokens"] for r in rep.values()) == \
        sum(r.max_new_tokens for r in reqs)
    for e in sched.timeline:
        assert e.transfer_start <= e.transfer_end <= e.compute_start \
            <= e.compute_end, vars(e)
    # every batch-admitted request got an admission window stamped: one
    # entry per request, transfer window well-formed, slot = tenant slot
    assert len(sched.admission_timeline) == 7
    for e in sched.admission_timeline:
        assert e.transfer_start <= e.transfer_end == e.compute_end
        assert e.slot in (sched._slot_of["t0"], sched._slot_of["t1"])
    # responses are retirement-ordered; match tokens by tenant sequence
    per_tenant_resp = {"t0": [], "t1": []}
    for resp in responses:
        per_tenant_resp[resp.tenant].append(resp)
    # token-exactness at scheduler level: rerun the same mix through
    # run_all on a fresh-but-shared engine and compare against the oracle
    for req in reqs:
        want = _oracle(engine, ceng, req)
        got = [resp for resp in per_tenant_resp[req.tenant]
               if np.array_equal(resp.tokens, want)]
        assert got, (req.tenant, req.prompt.size, req.max_new_tokens)


def test_continuous_pending_and_close(engine, ceng, rng):
    """pending() counts queued + admitted-but-unretired requests so drain()
    cannot exit with rows in flight."""
    cfg = engine.cfg
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng)
    for i in range(4):
        sched.submit(Request(f"t{i % 2}", rng.integers(
            1, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=2))
    assert sched.pending() == 4
    # capacity 3, budgets of 2 < inner_steps: the first round retires all
    # three admitted rows; the fourth request is still queued
    r = sched.step()
    assert len(r) == 3
    assert sched.pending() == 1
    sched.drain()
    assert sched.pending() == 0
    assert ceng.active_count() == 0


def test_prefix_sharing_token_exact_with_cow(engine, rng):
    """The tentpole exactness contract: requests sharing a system-prompt
    prefix decode through refcounted shared pages + copy-on-write forks and
    stay token-exact with blocking generate — including after a CoW fork
    (every row writes block 0 on its first decode step, forking the shared
    page), after full-prefix repeats that skip their prefill entirely, and
    after the shared chain's pages have been evicted and reused."""
    cfg = engine.cfg
    ceng = ContinuousBatchingEngine(engine, capacity=3, page_size=8,
                                    inner_steps=4, max_prompt_len=64)
    assert ceng.prefix_sharing
    sys_prompt = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
    mk = lambda t: Request(f"t{t}", np.concatenate(
        [sys_prompt, rng.integers(1, cfg.vocab_size, 8).astype(np.int32)]),
        max_new_tokens=6)
    wave = [mk(t) for t in range(4)]
    done = ceng.run_all(wave)
    assert len(done) == 4
    for req, tokens in done:
        np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)
    # the prefix actually shared and the first decode write actually forked
    assert ceng.kv.pages_shared > 0
    assert ceng.kv.cow_forks + ceng.kv.pristine_forks > 0
    ceng.kv.assert_conserved()

    # exact repeat of an already-seen prompt: full-prefix hit skips its
    # prefill (cached logits + shared pages) and still decodes exactly
    calls0, skips0 = ceng.prefill_calls, ceng.prefill_skips
    repeat = Request("t0", wave[0].prompt.copy(), max_new_tokens=6)
    (req, tokens), = ceng.run_all([repeat])
    np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)
    assert ceng.prefill_skips == skips0 + 1
    assert ceng.prefill_calls == calls0
    ceng.kv.assert_conserved()

    # churn the pool with share-nothing traffic until the cached chain is
    # evicted, then replay the shared wave through the recycled pages
    churn = [Request("x", rng.integers(1, cfg.vocab_size,
                                       48).astype(np.int32),
                     max_new_tokens=2) for _ in range(8)]
    ceng.run_all(churn)
    for req, tokens in ceng.run_all([mk(t) for t in range(4)]):
        np.testing.assert_array_equal(_oracle(engine, ceng, req), tokens)
    ceng.kv.assert_conserved()


def test_prefix_sharing_saves_pages_and_prefills(engine, rng):
    """A/B on the shared-system-prompt workload: sharing+batching allocate
    measurably fewer pages and issue fewer prefill calls than the PR-3
    baseline (prefix_sharing=False, batch_admission=False), at identical
    tokens."""
    cfg = engine.cfg
    sys_prompt = rng.integers(1, cfg.vocab_size, 24).astype(np.int32)
    reqs = [Request(f"t{i}", np.concatenate(
        [sys_prompt, rng.integers(1, cfg.vocab_size, 8).astype(np.int32)]),
        max_new_tokens=4) for i in range(6)]

    def run(shared: bool):
        ceng = ContinuousBatchingEngine(engine, capacity=3, page_size=8,
                                        inner_steps=4, max_prompt_len=32,
                                        prefix_sharing=shared,
                                        batch_admission=shared)
        done = {id(r): t for r, t in ceng.run_all(reqs)}
        return ceng, done

    ceng_a, done_a = run(False)
    # fresh identical requests through a sharing engine
    ceng_b, done_b = run(True)
    for r in reqs:
        np.testing.assert_array_equal(done_a[id(r)], done_b[id(r)])
    assert ceng_b.kv.pages_allocated < ceng_a.kv.pages_allocated
    assert ceng_b.prefill_calls < ceng_a.prefill_calls
    assert ceng_a.kv.pages_shared == 0
    assert ceng_b.kv.pages_shared > 0


def test_state_donated_in_place(engine, rng):
    """The slot-table state pytree is donated to the round/admission jits:
    the pre-call buffers die (XLA reuses them in place instead of copying
    the pools), and the number of live device buffers stays flat across
    micro-rounds."""
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=2, max_prompt_len=16)
    req = Request("a", rng.integers(1, engine.cfg.vocab_size,
                                    12).astype(np.int32),
                  max_new_tokens=12)
    old_pool = ceng.state["caches"][ceng.kv.attn_subs[0]]["k"]
    old_pos = ceng.state["pos_pool"]
    assert ceng.try_admit(req)
    # admission donated the pre-admission state
    assert old_pool.is_deleted() and old_pos.is_deleted()
    old_pool = ceng.state["caches"][ceng.kv.attn_subs[0]]["k"]
    ceng.collect(ceng.dispatch_round())
    assert old_pool.is_deleted()
    # steady state: repeated rounds neither copy pools nor accumulate
    # buffers (the ever-used pool pages are updated in place)
    ceng.collect(ceng.dispatch_round())
    n0 = len(jax.live_arrays())
    ceng.collect(ceng.dispatch_round())
    ceng.collect(ceng.dispatch_round())
    assert len(jax.live_arrays()) == n0


def test_retire_before_dispatch_fast_path(engine, rng):
    """A request finishing in round k is evicted — slot and pages free —
    before round k+1 dispatches, whenever round k has already landed when
    the scheduler steps: its replacement joins round k+1 instead of the
    PR-3 behaviour of riding one extra round behind a masked lane."""
    cfg = engine.cfg
    sched = MultiTenantScheduler(
        engine, mode="continuous",
        continuous=dict(capacity=2, page_size=8, num_pages=2 + 4,
                        inner_steps=4, max_prompt_len=16,
                        prefix_sharing=False))
    eng = sched.continuous_engine
    mk = lambda t, n: Request(t, rng.integers(
        1, cfg.vocab_size, 12).astype(np.int32), max_new_tokens=n)
    r1, r2, r3 = mk("a", 8), mk("b", 20), mk("c", 8)
    for r in (r1, r2, r3):
        sched.submit(r)

    dispatches = []                  # (free pages, tenants) at dispatch time
    orig = eng.dispatch_round

    def recording_dispatch():
        dispatches.append((eng.kv.free_pages(),
                           [s.req.tenant if s is not None else None
                            for s in eng._slots]))
        return orig()

    eng.dispatch_round = recording_dispatch
    # step 1: admits r1+r2 (pool full -> r3 queued), dispatches rounds 1
    # and 2 (r1 finishes inside round 2)
    sched.step()
    assert sched._cont_inflight is not None
    # force "round 2 has landed" before the next step
    jax.block_until_ready(sched._cont_inflight.handle.emitted)
    responses = sched.step()
    # the fast path collected round 2 first: r1 retired, r3 admitted into
    # round 3's dispatch — with the PR-3 ordering round 3 would have been
    # dispatched before r1's retirement, with r3 still queued
    assert [r.tenant for r in responses] == ["a"]
    assert len(dispatches) >= 3
    assert "c" in dispatches[2][1] and "a" not in dispatches[2][1]
    sched.drain()
    assert eng.kv.free_pages() == 4
    eng.dispatch_round = orig


def test_exact_fit_pool_admits_under_refined_reserve(engine, rng):
    """PR-4's coarse CoW reserve (one page per to-be-written block) rejected
    a request whose fresh pages exactly fill the pool — 2 fresh + 1 reserve
    > 2 usable — even though every write would land on an exclusively owned
    page and could never fork.  The sharer-count reserve charges those
    writes nothing, admits the request, and decode stays token-exact."""
    cfg = engine.cfg
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    num_pages=2 + 2, inner_steps=2,
                                    max_prompt_len=16)
    assert ceng.prefix_sharing          # will_write headroom is in play
    req = Request("a", rng.integers(1, cfg.vocab_size, 16).astype(np.int32),
                  max_new_tokens=4)
    (r, toks), = ceng.run_all([req])
    np.testing.assert_array_equal(_oracle(engine, ceng, r), toks)
    ceng.kv.assert_conserved()
    # the unwritten block's page may linger as evictable pristine cache
    assert ceng.kv.free_pages() + ceng.kv.cached_pages() == 2


def test_unadmittable_request_rejects_not_spins(engine, rng, monkeypatch):
    """Persistent admission failure with nothing in flight must terminate in
    an explicit REJECTED outcome from both drain paths — never an exception
    (the PR-5 contract) and never a busy-loop on pending().  Since the
    sharer-count reserve, a legal request against an idle pool always
    admits (and the constructor rejects pools smaller than one full
    sequence), so the bounded retry is exercised by a simulated
    page-pressure failure."""
    cfg = engine.cfg
    with pytest.raises(ValueError, match="cannot hold"):
        ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                 num_pages=2 + 1, max_prompt_len=16)
    kwargs = dict(capacity=2, page_size=8, inner_steps=2, max_prompt_len=16)
    ceng = ContinuousBatchingEngine(engine, **kwargs)
    monkeypatch.setattr(ceng, "try_admit_batch",
                        lambda reqs: [False] * len(reqs))
    req = Request("a", rng.integers(1, cfg.vocab_size, 16).astype(np.int32),
                  max_new_tokens=4)
    assert ceng.run_all([req]) == []
    assert ceng.rejected == [req]
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous=dict(kwargs))
    monkeypatch.setattr(sched.continuous_engine, "try_admit_batch",
                        lambda reqs: [False] * len(reqs))
    sched.submit(Request("a", req.prompt.copy(), 4))
    out = sched.drain()
    assert [r.outcome for r in out] == ["rejected"]
    assert out[0].tokens.size == 0
    assert sched.stats["a"]["rejected"] == 1


def test_enc_dec_continuous_token_exact(rng):
    """Encoder-decoder family (PR 9): cross-attention KV pages into the
    pool's separate per-request cross space at admission, decode gathers it
    read-only per step, and every request is token-exact vs blocking
    generate on the same padded prompt + (default zero) frames."""
    engine = _make_engine("whisper-base")
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    inner_steps=3, max_prompt_len=32)
    assert {k.name for k in ceng.state_kinds} == {"attn", "cross"}
    assert ceng.cross_blocks > 0
    cfg = engine.cfg
    reqs = [Request("e", rng.integers(1, cfg.vocab_size,
                                      6 + 3 * i).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    done = ceng.run_all(reqs)
    assert len(done) == 3
    from repro.serving.engine import resolve_extra_inputs
    for req, tokens in done:
        b = ceng.bucket_len(req.prompt.size)
        padded = np.zeros((1, b), np.int32)
        padded[0, b - req.prompt.size:] = req.prompt
        extra = {k: np.asarray(v)[None] for k, v in
                 resolve_extra_inputs(cfg, req).items()}
        want = engine.generate(padded, max_new_tokens=req.max_new_tokens,
                               extra_inputs=extra, seed=req.seed).tokens[0]
        np.testing.assert_array_equal(want, tokens)
    # all cross pages returned to the cross free list at drain
    ceng.kv.assert_conserved(host_pages={"attn": 0, "cross": 0, "ssm": 0})


def test_prompt_longer_than_max_rejected(engine, ceng):
    with pytest.raises(ValueError, match="max_prompt_len"):
        ceng.try_admit(Request("a", np.ones(999, np.int32), 2))
