"""Flash-attention Pallas kernel vs the naive oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention_core import blockwise_attention, naive_attention


def _qkv(B, Sq, Skv, Hq, Hkv, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, Sq, Hq, D)),
            jax.random.normal(ks[1], (B, Skv, Hkv, D)),
            jax.random.normal(ks[2], (B, Skv, Hkv, D)))


SWEEP = [
    # B, S, Hq, Hkv, D, causal, window, bq, bk
    (2, 64, 4, 2, 16, True, None, 16, 32),
    (1, 128, 8, 2, 32, True, None, 32, 32),
    (2, 64, 4, 4, 16, False, None, 32, 16),
    (1, 128, 4, 1, 16, True, 32, 32, 32),      # MQA + sliding window
    (1, 32, 2, 2, 64, False, 8, 16, 16),
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,bq,bk", SWEEP)
def test_matches_naive(B, S, Hq, Hkv, D, causal, window, bq, bk):
    q, k, v = _qkv(B, S, S, Hq, Hkv, D)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_kv=bk)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_matches_blockwise_hlo_standin():
    """The jnp blockwise path is the kernel's HLO stand-in — same numerics."""
    q, k, v = _qkv(1, 64, 64, 4, 2, 16)
    a = flash_attention_pallas(q, k, v, causal=True, block_q=16, block_kv=32)
    b = blockwise_attention(q, k, v, causal=True, block_q=16, block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_bf16_inputs():
    q, k, v = _qkv(1, 32, 32, 2, 2, 16)
    got = flash_attention_pallas(q.astype(jnp.bfloat16),
                                 k.astype(jnp.bfloat16),
                                 v.astype(jnp.bfloat16), causal=True,
                                 block_q=16, block_kv=16)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_cross_lengths():
    q, k, v = _qkv(1, 32, 128, 4, 2, 16)
    got = flash_attention_pallas(q, k, v, causal=False, block_q=16,
                                 block_kv=32)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
