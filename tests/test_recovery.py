"""Crash-safe serving: journal WAL contracts + kill-and-restart recovery.

The tentpole recovery contract (``serving/journal.py`` +
``MultiTenantScheduler.save_checkpoint/recover``):

* **WAL discipline** — every record fsync'd before the mutation it
  describes; a torn *final* line is dropped silently (the mutation never
  happened), mid-file corruption raises; the record schema is pinned by
  ``tests/golden/journal_schema.json`` (regenerate with
  REPRO_REGEN_GOLDEN=1 after an intentional change).
* **token-exact recovery** — a journalled run SIGKILLed mid-round (or
  mid-preemption, inside the host swap ``put``) restarts in a fresh
  process, rebuilds live/swapped slots from the latest engine checkpoint,
  re-queues journaled-never-recovered rids, and deterministically replays
  rounds past the checkpoint: every recovered request finishes with
  tokens bitwise identical to an uninterrupted run (greedy AND seeded
  temperature sampling), on a meshless engine and across a 1×8 sharded
  pool.  Retires that landed after the checkpoint are cross-checked
  against their journal RETIRE records (the replay oracle).
* **terminal-swap hygiene** — a swapped request that fails terminally
  (restore retry budget against an idle engine) drops its host record
  AND its ticket bookkeeping; ``drain()`` audits two-tier conservation
  plus empty ticket maps, so a leak fails loudly.

SIGKILL mid-JAX needs process isolation, and the mesh variant needs 8
host devices before jax initialisation — so the kill-and-restart harness
runs in subprocesses, like tests/test_mesh_serving.py.
"""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving import journal as jm
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "journal_schema.json")


# ---------------------------------------------------------------------------
# journal unit contracts (no engine)
# ---------------------------------------------------------------------------
def _writer(tmp_path):
    return jm.JournalWriter(str(tmp_path / "j.jsonl"))


def test_journal_append_enforces_schema(tmp_path):
    w = _writer(tmp_path)
    with pytest.raises(ValueError, match="unknown journal record kind"):
        w.append("NOPE", rid=0)
    with pytest.raises(ValueError, match="!= schema"):
        w.append("ADMIT", rid=0)                       # missing fields
    with pytest.raises(ValueError, match="!= schema"):
        w.append("RETIRE", rid=0, tokens=[1], extra=2)  # widened
    w.append("ADMIT", rid=0, slot=1, bucket=16, ring=16)
    w.close()
    assert len(jm.read_journal(w.path)) == 1


def test_journal_torn_tail_dropped_midfile_raises(tmp_path):
    w = _writer(tmp_path)
    w.append("ADMIT", rid=0, slot=0, bucket=16, ring=16)
    w.append("RETIRE", rid=0, tokens=[1, 2, 3])
    w.close()
    with open(w.path, "ab") as f:                 # crash mid-append: no \n
        f.write(b'{"v": 1, "seq": 2, "kind": "RET')
    recs = jm.read_journal(w.path)
    assert [r["kind"] for r in recs] == ["ADMIT", "RETIRE"]
    # the same damage anywhere BEFORE the tail is corruption, not a crash
    with open(w.path, "rb") as f:
        lines = f.read().splitlines()
    with open(w.path, "wb") as f:
        f.write(b"\n".join([lines[0][:10], lines[1]]) + b"\n")
    with pytest.raises(ValueError, match="corrupt record"):
        jm.read_journal(w.path)


def test_journal_reopen_repairs_torn_tail_and_continues_seq(tmp_path):
    """Regression (crash mid-append, then reopen): generation 2 must
    truncate generation 1's torn tail before its first append, or the new
    record concatenates onto the partial line and every future
    read_journal raises mid-file corruption — breaking the 'a second
    crash during replay recovers too' contract.  The reopened writer also
    seeds its seq past the surviving records instead of restarting at 0."""
    w = _writer(tmp_path)
    w.append("ADMIT", rid=0, slot=0, bucket=16, ring=16)
    w.append("RETIRE", rid=0, tokens=[1, 2, 3])
    w.close()
    with open(w.path, "ab") as f:                 # crash mid-append: no \n
        f.write(b'{"v": 1, "seq": 2, "kind": "RET')
    w2 = jm.JournalWriter(w.path)                 # generation 2 reopens
    w2.append("RECOVER", step=-1, restored_live=0, restored_swapped=0,
              requeued=1, rounds_replayed=0)
    w2.append("ROUND_COMMIT", rnd=1, emitted={"1": 2})
    w2.close()
    recs = jm.read_journal(w.path)                # parseable end to end
    assert [r["kind"] for r in recs] == \
        ["ADMIT", "RETIRE", "RECOVER", "ROUND_COMMIT"]
    assert [r["seq"] for r in recs] == [0, 1, 2, 3]   # monotone across gens
    # a second reopen of the now-clean file continues the seq again
    w3 = jm.JournalWriter(w.path)
    w3.append("CHECKPOINT", step=0, rnd=1)
    w3.close()
    assert jm.read_journal(w.path)[-1]["seq"] == 4
    # reopening an empty path stays a no-op create
    w4 = jm.JournalWriter(str(tmp_path / "fresh.jsonl"))
    assert w4._seq == 0
    w4.close()


def test_journal_rejected_outside_continuous_mode(tmp_path):
    """Only the continuous collect loop emits ROUND_COMMIT/RETIRE; a
    journal armed under the slot-based schedules would replay every
    completed request as pending, so the constructor refuses it."""
    for mode in ("overlapped", "blocking"):
        with pytest.raises(ValueError, match="continuous"):
            MultiTenantScheduler(None, mode=mode,
                                 journal=str(tmp_path / "j.jsonl"))
        with pytest.raises(ValueError, match="continuous"):
            MultiTenantScheduler(None, mode=mode,
                                 checkpoint_dir=str(tmp_path / "ckpt"))
    assert not os.path.exists(tmp_path / "j.jsonl")   # rejected pre-create


def test_journal_replay_folds_checkpoint_window(tmp_path):
    w = _writer(tmp_path)
    for rid in range(3):
        w.append("SUBMIT", **jm.request_to_record(
            rid, Request(f"t{rid}", np.asarray([1, 2, 3], np.int32), 8)))
    w.append("ADMIT", rid=0, slot=0, bucket=16, ring=16)
    w.append("ADMIT", rid=1, slot=1, bucket=16, ring=16)
    w.append("ROUND_COMMIT", rnd=1, emitted={"0": 4, "1": 4})
    w.append("CHECKPOINT", step=0, rnd=1)
    w.append("ROUND_COMMIT", rnd=2, emitted={"0": 8, "1": 8})
    w.append("RETIRE", rid=0, tokens=list(range(8)))
    w.append("ROUND_COMMIT", rnd=3, emitted={"1": 10})
    w.close()
    st = jm.replay(jm.read_journal(w.path))
    assert st.pending() == [1, 2]
    assert st.terminal == {0: "RETIRE"}
    assert st.retired_tokens[0] == list(range(8))
    assert st.admitted == {0, 1}
    assert st.last_checkpoint["step"] == 0
    assert st.rounds_after_checkpoint == 2
    # emitted deltas past the checkpoint: (8-4) + (10-4)
    assert st.tokens_after_checkpoint == 10
    assert st.next_rid == 3
    assert st.last_round == 3


def test_journal_replay_resets_round_bookkeeping_at_recover(tmp_path):
    """Regression (double-counted replay): a recovery re-commits the
    rounds past the checkpoint under fresh rnd numbers, so after a
    *second* crash the rounds-after-checkpoint count must restart at the
    RECOVER marker — otherwise generation 1's rounds and generation 2's
    re-commits of the same logical rounds are both counted."""
    w = _writer(tmp_path)
    w.append("SUBMIT", **jm.request_to_record(
        0, Request("t0", np.asarray([1, 2, 3], np.int32), 16)))
    w.append("CHECKPOINT", step=0, rnd=1)
    w.append("ROUND_COMMIT", rnd=2, emitted={"0": 4})    # gen 1, then crash
    w.append("ROUND_COMMIT", rnd=3, emitted={"0": 8})
    w.append("RECOVER", step=0, restored_live=1, restored_swapped=0,
             requeued=0, rounds_replayed=2)
    w.append("ROUND_COMMIT", rnd=2, emitted={"0": 4})    # gen 2 re-commits
    w.append("ROUND_COMMIT", rnd=3, emitted={"0": 8})
    w.append("ROUND_COMMIT", rnd=4, emitted={"0": 12})   # ...and goes on
    w.close()
    st = jm.replay(jm.read_journal(w.path))
    assert st.rounds_after_checkpoint == 3               # not 5
    # token deltas stay cumulative-vs-checkpoint: last write wins, the
    # re-committed counts overwrite rather than add
    assert st.tokens_after_checkpoint == 12
    assert st.last_round == 4


def test_request_record_roundtrip_lossless():
    req = Request("acme", np.asarray([5, 7, 11], np.int32), 6,
                  temperature=0.9, top_k=12, seed=42, priority=0,
                  deadline_s=3.5,
                  extra_inputs={"mel": np.arange(6, dtype=np.float32)})
    rec = jm.request_to_record(9, req)
    assert rec["rid"] == 9 and rec["extras_hash"] != ""
    json.dumps(rec)                               # journal-able as-is
    back = jm.request_from_record(rec)
    np.testing.assert_array_equal(back.prompt, req.prompt)
    np.testing.assert_array_equal(back.extra_inputs["mel"],
                                  req.extra_inputs["mel"])
    assert (back.tenant, back.max_new_tokens, back.temperature, back.top_k,
            back.seed, back.priority, back.deadline_s) == \
        ("acme", 6, 0.9, 12, 42, 0, 3.5)
    assert jm.extras_hash(back.extra_inputs) == rec["extras_hash"]
    assert jm.extras_hash(None) == ""


def test_golden_journal_schema():
    """The on-disk record schema is a cross-process-generation contract:
    widening/renaming a field must be an explicit golden update, never
    silent drift.  Regenerate with REPRO_REGEN_GOLDEN=1."""
    got = {"version": jm.JOURNAL_VERSION,
           "records": {k: list(v)
                       for k, v in sorted(jm.RECORD_FIELDS.items())}}
    if os.environ.get("REPRO_REGEN_GOLDEN"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
    with open(GOLDEN) as f:
        want = json.load(f)
    assert got == want


# ---------------------------------------------------------------------------
# in-process: checkpoint/recover cycle + terminal-swap hygiene
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine():
    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    return ServingEngine(cfg, params)


def _ceng(engine, **kw):
    kw = dict(dict(capacity=2, page_size=8, num_pages=24, inner_steps=4,
                   max_prompt_len=16), **kw)
    return ContinuousBatchingEngine(engine, **kw)


def test_checkpoint_recover_token_exact_in_process(engine, tmp_path):
    """Abandon a journalled+checkpointed scheduler mid-flight (the
    in-process stand-in for a crash: the on-disk pair is all recovery may
    read), recover into a fresh engine/scheduler, and require every
    request — greedy and seeded-sampling — to finish bitwise identical to
    an uninterrupted run.  Pre-crash retires surface from the journal via
    ``already_complete`` without re-decoding."""
    rng = np.random.default_rng(0)
    cfg = engine.cfg
    prompts = [rng.integers(1, cfg.vocab_size, 8 + i).astype(np.int32)
               for i in range(4)]

    def mkreqs():
        return [Request(f"r{i}", prompts[i].copy(), max_new_tokens=10 + 2 * i,
                        seed=7 + i, temperature=0.8 if i % 2 else None)
                for i in range(4)]

    sa = MultiTenantScheduler(engine, mode="continuous",
                              continuous_engine=_ceng(engine))
    for r in mkreqs():
        sa.submit(r)
    base = {r.tenant: np.asarray(r.tokens) for r in sa.drain()
            if r.outcome == "completed"}
    assert len(base) == 4

    jpath = str(tmp_path / "journal.jsonl")
    cdir = str(tmp_path / "ckpt")
    sb = MultiTenantScheduler(engine, mode="continuous",
                              continuous_engine=_ceng(engine),
                              journal=jpath, checkpoint_dir=cdir,
                              checkpoint_every=2)
    for r in mkreqs():
        sb.submit(r)
    for _ in range(6):                      # abandon mid-flight
        if sb.pending():
            sb.step()
    assert sb.checkpoints_taken >= 1
    # checkpoint cadence: every K=2 committed rounds exactly, not K+1
    # (the dispatch-suppression test counts the round it is about to
    # commit, so the quiesce bubble lands on time)
    cks = [r["rnd"] for r in jm.read_journal(jpath)
           if r["kind"] == "CHECKPOINT"]
    assert cks[0] == 2
    assert all(b - a == 2 for a, b in zip(cks, cks[1:]))

    cc = _ceng(engine)
    sc = MultiTenantScheduler(engine, mode="continuous",
                              continuous_engine=cc, journal=jpath,
                              checkpoint_dir=cdir, checkpoint_every=2)
    summary = sc.recover()
    assert summary.checkpoint_step is not None
    assert summary.restored_live + summary.restored_swapped \
        + summary.requeued + len(summary.already_complete) >= 4
    got = {r.tenant: np.asarray(r.tokens) for r in sc.drain()
           if r.outcome == "completed"}
    js = jm.replay(jm.read_journal(jpath))
    for rid, toks in summary.already_complete.items():
        got[js.submitted[rid]["tenant"]] = np.asarray(toks, np.int32)
    assert set(got) == set(base)
    for t in base:
        np.testing.assert_array_equal(base[t], got[t])
    # recovered pool passes the two-tier audit; RECOVER was journaled so a
    # second crash during replay recovers too
    cc.kv.assert_conserved(host_pages=cc.swap_store.pages_by_kind())
    assert [r["kind"] for r in jm.read_journal(jpath)].count("RECOVER") == 1


def test_failed_swapped_request_drops_store_and_tickets(engine, tmp_path):
    """Regression (terminal-swap leak): a swapped-out request whose
    restore exhausts the retry budget against an idle engine must fail
    terminally, dropping its HostSwapStore record AND both ticket
    bookkeeping maps — ``drain()`` now audits exactly that, so the leak
    would hang the audit assert rather than silently skew accounting."""
    rng = np.random.default_rng(1)
    cfg = engine.cfg
    ceng = _ceng(engine)
    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng, preemption=True,
                                 admission_retry_limit=1)
    for i in range(2):
        sched.submit(Request(f"lo{i}", rng.integers(
            1, cfg.vocab_size, 12).astype(np.int32),
            max_new_tokens=40, priority=1))
    sched.step()
    sched.submit(Request("hi", rng.integers(1, cfg.vocab_size,
                                            8).astype(np.int32),
                         max_new_tokens=6, priority=0))
    while ceng.preemptions == 0 and sched.pending():
        sched.step()
    assert len(ceng.swap_store) == 1
    # from here the victim is unrestorable: every re-admission attempt
    # fails, so the idle-engine retry budget is the only way out
    ceng.try_restore = lambda ticket: False
    out = sched.drain()
    outcomes = sorted(r.outcome for r in out)
    assert outcomes == ["completed", "completed", "failed"]
    failed, = [r for r in out if r.outcome == "failed"]
    assert failed.preemptions >= 1
    assert len(ceng.swap_store) == 0              # host record dropped
    assert sched._ticket_attempts == {} and sched._ticket_backoff == {}
    ceng.kv.assert_conserved(host_pages=ceng.swap_store.pages_by_kind())


# ---------------------------------------------------------------------------
# subprocess kill-and-restart harness (SIGKILL mid-round / mid-preemption)
# ---------------------------------------------------------------------------
def _run_child(script: str, *argv: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", script, *argv],
                          capture_output=True, text=True, env=env,
                          timeout=600)


CRASH_RECOVER_SCRIPT = textwrap.dedent("""
    import dataclasses, json, os, sys
    import numpy as np
    import jax

    phase, mode, root = sys.argv[1], sys.argv[2], sys.argv[3]

    from repro.configs import get_config
    from repro.distributed.fault import FaultPlane
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving import journal as jm
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import MultiTenantScheduler, Request

    cfg = get_config("internlm2-1.8b").reduced()
    sh = None
    if mode == "mesh":
        from repro.distributed.sharding import parse_mesh, serving_sharder
        assert len(jax.devices()) == 8, jax.devices()
        # reduced() clamps to 2 KV heads; re-widen so 8 ways divide
        cfg = dataclasses.replace(cfg, num_heads=16, num_kv_heads=8)
        sh = serving_sharder(parse_mesh("1x8"))
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params, sh=sh)
    # crash injection: mid-round (exact dispatched round) or mid-swap
    # (inside HostSwapStore.put, the mid-preemption window)
    fp = None
    if phase == "crash":
        fp = (FaultPlane(crash_at_swap=1) if mode == "swap"
              else FaultPlane(crash_at_round=6))
    ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                    num_pages=24, inner_steps=4,
                                    max_prompt_len=16, fault_plane=fp)
    sched = MultiTenantScheduler(
        engine, mode="continuous", continuous_engine=ceng, preemption=True,
        journal=os.path.join(root, "journal.jsonl"),
        checkpoint_dir=os.path.join(root, "ckpt"), checkpoint_every=3)

    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, 8 + 2 * i).astype(np.int32)
               for i in range(4)]

    def mkreq(i, prio=1, steps=None):
        return Request("t%d" % i, prompts[i].copy(),
                       max_new_tokens=24 + 2 * i if steps is None else steps,
                       seed=11 + i, priority=prio,
                       temperature=0.7 if i % 2 else None)

    # swap mode: two long rows fill the slot table, a tier-0 arrival
    # forces a preemption whose swap-out put() is the crash site.  round
    # modes: rows 0/1 decode through the SIGKILL at dispatched round 6
    # (checkpointed mid-flight at round 3, the next checkpoint due at 6
    # never lands), row 2 waits in the checkpointed queue, and row 3 is
    # submitted only after the first checkpoint — its SUBMIT is on disk
    # but in no snapshot, so recovery must re-queue it from the journal
    # alone (the "never lost" half of the WAL contract)
    reqs = ([mkreq(0), mkreq(1), mkreq(2, prio=0, steps=8)]
            if mode == "swap" else [mkreq(i) for i in range(4)])

    if phase == "crash":
        if mode == "swap":
            sched.submit(reqs[0]); sched.submit(reqs[1])
            sched.step()
            sched.submit(reqs[2])
            sched.drain()
        else:
            for r in reqs[:3]:
                sched.submit(r)
            late = False
            while sched.pending() or not late:
                if not late and sched.checkpoints_taken >= 1:
                    sched.submit(reqs[3])
                    late = True
                sched.step()
        sys.exit(3)          # sentinel: the injected crash never fired

    summary = sched.recover()
    out = sched.drain()
    js = jm.replay(jm.read_journal(os.path.join(root, "journal.jsonl")))
    got = {r.tenant: np.asarray(r.tokens) for r in out
           if r.outcome == "completed"}
    for rid, toks in summary.already_complete.items():
        got[js.submitted[rid]["tenant"]] = np.asarray(toks, np.int32)
    assert set(got) == {r.tenant for r in reqs}, sorted(got)

    # bitwise vs an uninterrupted run of each request alone on the same
    # engine (same jit caches, no contention -> no preemption)
    for r in reqs:
        clone = Request(r.tenant, r.prompt.copy(), r.max_new_tokens,
                        temperature=r.temperature, seed=r.seed)
        (_, want), = ceng.run_all([clone])
        np.testing.assert_array_equal(np.asarray(want), got[r.tenant])

    # post-checkpoint retires were re-decoded: their journal RETIRE
    # records are the replay oracle
    for rid, toks in summary.replay_check.items():
        np.testing.assert_array_equal(
            np.asarray(toks, np.int32), got[js.submitted[rid]["tenant"]])

    ceng.kv.assert_conserved(host_pages=ceng.swap_store.pages_by_kind())
    assert sched._ticket_attempts == {} and sched._ticket_backoff == {}
    print("RECOVERY_EXACT_OK " + json.dumps(dict(
        step=summary.checkpoint_step, live=summary.restored_live,
        swapped=summary.restored_swapped, requeued=summary.requeued,
        rounds=summary.rounds_replayed,
        preserved=summary.tokens_preserved)))
""")


def _crash_then_recover(mode: str) -> dict:
    root = tempfile.mkdtemp(prefix=f"recovery_{mode}_")
    crash = _run_child(CRASH_RECOVER_SCRIPT, "crash", mode, root)
    assert crash.returncode == -9, (
        f"expected SIGKILL, got rc={crash.returncode}\n"
        + crash.stderr[-3000:])
    assert os.path.exists(os.path.join(root, "journal.jsonl"))
    rec = _run_child(CRASH_RECOVER_SCRIPT, "recover", mode, root)
    assert rec.returncode == 0, rec.stderr[-3000:]
    line, = [ln for ln in rec.stdout.splitlines()
             if ln.startswith("RECOVERY_EXACT_OK")]
    return json.loads(line.split(" ", 1)[1])


def test_sigkill_mid_round_recovery_subprocess():
    """SIGKILL at an exact dispatched round; restart recovers every
    request token-exactly: checkpointed rows replay deterministically
    past the snapshot, never-admitted rids are re-queued (not lost)."""
    s = _crash_then_recover("round")
    assert s["step"] is not None
    assert s["live"] + s["swapped"] >= 1
    assert s["requeued"] >= 1                 # rows 2/3 never held a slot


def test_sigkill_mid_preemption_recovery_subprocess():
    """SIGKILL *inside* the host swap-out put() — the widest WAL window
    (preemption mutation in flight, PREEMPT record not yet durable).  The
    journal + last checkpoint still reconstruct a consistent state and
    every request finishes bitwise-identical."""
    s = _crash_then_recover("swap")
    assert s["requeued"] + s["live"] + s["swapped"] >= 1


def test_sigkill_recovery_mesh_1x8_subprocess():
    """The same mid-round kill-and-restart on a 1×8 mesh-sharded pool:
    checkpoint payloads round-trip through host numpy and restore through
    the per-slice staging lanes, token-exact."""
    s = _crash_then_recover("mesh")
    assert s["step"] is not None
    assert s["requeued"] >= 1
