"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, using the per-device numbers recorded by
launch/dryrun.py:

    compute term    = HLO_FLOPs_per_dev / PEAK_FLOPS          [s]
    memory term     = HLO_bytes_per_dev / HBM_BW              [s]
    collective term = collective_bytes_per_dev / LINK_BW      [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
`bytes accessed` from HloCostAnalysis counts every operand/result of every
HLO op, i.e. an *upper bound* on HBM traffic (fusion keeps most of it on
chip); the memory term is therefore pessimistic and is read comparatively.

MODEL_FLOPS = 6*N*tokens (train) or 2*N*tokens (serve), N = active params
(experts scaled by top_k/E, embedding gather excluded, unembed included).
model_ratio = MODEL_FLOPS / (HLO_FLOPs * chips) — the "useful compute"
fraction (catches remat/dispatch/causal waste).
mfu_bound = ideal compute time / dominant term — the MFU the compiled
program could at best reach on this mesh.
"""
from __future__ import annotations

import json
import math
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "results" / "dryrun"

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def active_param_count(arch: str) -> Dict[str, float]:
    """(total, active, embedding) parameter counts from the shape tree."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    from repro.configs import get_config
    from repro.models import params as pp
    from repro.models.model import build_model

    cfg = get_config(arch)
    bundle = build_model(cfg)
    sds = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    vals, _ = pp.split(sds)
    flat = jax.tree.flatten_with_path(vals)[0]
    total = active = embed = 0.0
    mc = cfg.moe
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        keys = [getattr(p, "key", str(p)) for p in path]
        total += n
        if "embedding" in keys:
            embed += n
            continue  # gather: not matmul flops
        if mc is not None and any(k in ("w_gate", "w_up", "w_down")
                                  for k in keys) and "moe" in keys and \
                "shared" not in keys:
            active += n * (mc.top_k / mc.num_experts)
        else:
            active += n
    out = {"total": total, "active": active, "embed": embed}
    _PARAM_CACHE[arch] = out
    return out


def model_flops(rec: Dict) -> float:
    if rec["kind"] == "risk":
        # useful ALU work: ~4 ops per (event x ELT) pair per trial wave
        from repro.configs.risk_app import CONFIG as RC
        waves = rec.get("tenants", 1)
        t_step = max(512, (RC.num_trials // waves // 512) * 512)
        return 4.0 * t_step * RC.events_per_trial * RC.num_elts
    from repro.configs import get_shape
    shape = get_shape(rec["shape"])
    n = active_param_count(rec["arch"])["active"]
    tokens = shape.global_batch * (shape.seq_len if rec["kind"] != "decode"
                                   else 1)
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n * tokens


def analyse(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    compute = rec["cost"]["flops"] / PEAK_FLOPS
    # memory bounds: lb = params/states/IO touched once (fusion-optimal);
    # ub = HloCostAnalysis bytes-accessed (every op's operands; pessimistic)
    mem_lb = (rec["memory"]["argument_bytes"] +
              rec["memory"]["output_bytes"]) / HBM_BW
    mem_ub = rec["cost"]["bytes_accessed"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute": compute, "memory": mem_lb, "collective": coll}
    dominant = max(terms, key=terms.get)
    if dominant != "memory" and mem_ub > 3 * terms[dominant]:
        dominant = f"{dominant}|memory?"   # ambiguous: ub would dominate
    mf = model_flops(rec)
    hlo_global = rec["cost"]["flops"] * chips
    ideal = mf / chips / PEAK_FLOPS
    dom_s = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "compute_s": compute, "memory_s": mem_lb, "memory_ub_s": mem_ub,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf,
        "model_ratio": mf / hlo_global if hlo_global else 0.0,
        "mfu_bound": ideal / dom_s if dom_s else 0.0,
        "temp_gb": rec["memory"]["temp_bytes"] / 1e9,
        "arg_gb": rec["memory"]["argument_bytes"] / 1e9,
        "fits_hbm": (rec["memory"]["temp_bytes"] +
                     rec["memory"]["argument_bytes"]) < 16e9,
    }


def load_all(directory: pathlib.Path = DRYRUN_DIR) -> List[Dict]:
    out = []
    for p in sorted(directory.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh", "?"), "kind": "skipped",
                        "dominant": "-", "reason": rec.get("reason", "")})
            continue
        a = analyse(rec)
        if a:
            out.append(a)
        elif rec.get("status") == "error":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec.get("mesh", "?"), "kind": "error",
                        "dominant": "-", "reason": rec.get("error", "")[-200:]})
    return out


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | mem lb s | mem ub s | "
           "collective s | dominant | model/HLO | MFU bound | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["kind"] in ("skipped", "error"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['kind']}: {r.get('reason','')[:60]} |" +
                         " - |" * 7)
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['memory_ub_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['model_ratio']:.2f} | {r['mfu_bound']:.3f} "
            f"| {r['temp_gb']:.1f} |")
    return hdr + "\n".join(lines)


def run() -> List:
    """benchmark-harness entry: name, us_per_call, derived."""
    rows = load_all()
    out = []
    for r in rows:
        if r["kind"] in ("skipped", "error"):
            out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                        0.0, r["kind"]))
            continue
        dom_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                    dom_us,
                    f"dom={r['dominant']};mfu_bound={r['mfu_bound']:.3f};"
                    f"model_ratio={r['model_ratio']:.2f}"))
    return out


def main() -> None:
    rows = load_all()
    csv_path = ROOT / "results" / "roofline.csv"
    with open(csv_path, "w") as f:
        f.write("arch,shape,mesh,kind,compute_s,memory_lb_s,memory_ub_s,"
                "collective_s,dominant,model_ratio,mfu_bound,temp_gb,"
                "fits_hbm\n")
        for r in rows:
            if r["kind"] in ("skipped", "error"):
                f.write(f"{r['arch']},{r['shape']},{r['mesh']},{r['kind']},"
                        ",,,,,,,,\n")
                continue
            f.write(f"{r['arch']},{r['shape']},{r['mesh']},{r['kind']},"
                    f"{r['compute_s']:.6f},{r['memory_s']:.6f},"
                    f"{r['memory_ub_s']:.6f},"
                    f"{r['collective_s']:.6f},{r['dominant']},"
                    f"{r['model_ratio']:.3f},{r['mfu_bound']:.4f},"
                    f"{r['temp_gb']:.2f},{r['fits_hbm']}\n")
    md = to_markdown(rows)
    (ROOT / "results" / "roofline.md").write_text(md)
    print(md)
    print(f"\nwrote {csv_path}")


if __name__ == "__main__":
    main()
