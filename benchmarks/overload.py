"""Trace-driven overload harness: the serving stack past saturation.

The paper's sharing argument is an *efficiency* claim; this harness checks
the *robustness* half — what the shared device does when offered more work
than it can hold.  It drives the continuous-batching scheduler with
deterministic seeded traces:

* **open-loop** arrivals — Poisson interarrivals at a configurable multiple
  of the measured service capacity (2x = the oversubscribed regime the
  acceptance criteria name), heavy-tail lognormal prompt/output mixes, a
  small fraction of high-priority (tier 0) requests among bulk tier-1
  traffic;
* **closed-loop** burst — the whole trace submitted at once (backlog
  driven), the worst-case admission pressure.

Each trace runs twice on one shared compiled engine — preemption+swap ON
vs OFF (the no-preemption baseline row) — and once more with the
:class:`repro.distributed.fault.FaultPlane` injecting round drops,
admission stalls and poisoned swap reads.  Rows record per-priority
p50/p99 TTFT, goodput-per-page (useful completed tokens per device page
allocated), preemption / swap-in / swap-drop counts, shed + rejected +
failed counts and the injected-fault survival accounting.  The fault run
additionally audits two-tier page conservation at drain
(``assert_conserved(host_pages=...)``) — a violated invariant fails the
bench loudly rather than skewing a row.

    PYTHONPATH=src python -m benchmarks.run --only overload
    PYTHONPATH=src python -m benchmarks.run --json out.json \\
        --only serving,overload
"""
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

Row = Tuple[str, float, str]


def make_trace(n: int, seed: int, mean_gap_s: float,
               vocab: int, max_prompt: int = 16, hi_every: int = 5,
               lo_steps: Tuple[int, int] = (12, 48),
               ) -> List[Dict[str, Any]]:
    """Deterministic request specs: Poisson arrival offsets, heavy-tail
    lognormal prompt/output lengths, every ``hi_every``-th request tier 0
    (short, latency-sensitive) among bulk tier 1 (long, throughput, output
    budget clipped to ``lo_steps``)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_gap_s, n)
    offs = np.cumsum(gaps) - gaps[0]
    specs: List[Dict[str, Any]] = []
    for i in range(n):
        hi = hi_every > 0 and i % hi_every == hi_every - 1
        plen = int(np.clip(rng.lognormal(2.0, 0.6), 4, max_prompt))
        steps = (int(np.clip(rng.lognormal(1.8, 0.4), 4, 8)) if hi
                 else int(np.clip(rng.lognormal(
                     np.log(1.3 * lo_steps[0]), 0.4), *lo_steps)))
        specs.append(dict(
            arrival=float(offs[i]),
            tenant=f"hi-{i % 2}" if hi else f"lo-{i % 3}",
            prompt=rng.integers(1, vocab, plen).astype(np.int32),
            max_new_tokens=steps,
            priority=0 if hi else 1))
    return specs


def drive(sched, specs: List[Dict[str, Any]], open_loop: bool = True,
          ) -> List[Any]:
    """Run a trace to completion: submit each spec when the wall clock
    passes its arrival offset (open loop) or all upfront (closed-loop
    burst), stepping the scheduler in between; drain the rest.  Returns
    every terminal response — completed, rejected and failed."""
    from repro.serving.multitenant import Request

    out: List[Any] = []
    start = time.perf_counter()
    i = 0
    while i < len(specs) or sched.pending():
        now = time.perf_counter() - start
        while i < len(specs) and (not open_loop
                                  or specs[i]["arrival"] <= now):
            s = specs[i]
            sched.submit(Request(s["tenant"], s["prompt"],
                                 s["max_new_tokens"],
                                 priority=s["priority"]))
            i += 1
        r = sched.step()
        if r:
            out.extend(r)
        if r is None and i < len(specs) and not sched.pending():
            # idle gap before the next arrival: sleep it off
            time.sleep(max(0.0, min(
                specs[i]["arrival"] - (time.perf_counter() - start), 0.05)))
    out.extend(sched.drain())
    return out


def _ttft_ms(responses: List[Any], priority: int) -> np.ndarray:
    v = [r.ttft_s * 1e3 for r in responses
         if r.outcome == "completed" and r.priority == priority
         and r.ttft_s is not None]
    return np.asarray(v) if v else np.asarray([float("nan")])


def _summarise(responses: List[Any], sched, ceng,
               c0: Tuple[int, int, int, int], extra: str = "",
               ) -> Tuple[float, str]:
    hi, lo = _ttft_ms(responses, 0), _ttft_ms(responses, 1)
    n = {o: sum(r.outcome == o for r in responses)
         for o in ("completed", "rejected", "failed")}
    useful = sum(r.tokens.size for r in responses
                 if r.outcome == "completed")
    pre0, res0, drop0, pages0 = c0
    pages = max(ceng.kv.pages_allocated - pages0, 1)
    shed = sum(int(s["shed"]) for s in sched.stats.values())
    derived = (f"completed={n['completed']};rejected={n['rejected']};"
               f"failed={n['failed']};shed={shed};"
               f"hi_p50_ttft_ms={np.percentile(hi, 50):.1f};"
               f"hi_p99_ttft_ms={np.percentile(hi, 99):.1f};"
               f"lo_p50_ttft_ms={np.percentile(lo, 50):.1f};"
               f"lo_p99_ttft_ms={np.percentile(lo, 99):.1f};"
               f"preemptions={ceng.preemptions - pre0};"
               f"restores={ceng.restores - res0};"
               f"swap_drops={ceng.kv.swap_drops - drop0};"
               f"goodput_tok_per_page={useful / pages:.2f}" + extra)
    return float(np.percentile(hi, 99)), derived


def bench_serving_overload() -> List[Row]:
    """2x-oversubscribed open-loop trace + closed-loop burst, preemption
    A/B, and a fault-injected run — the PR-6 acceptance rows.  One shared
    compiled engine serves every run (jit caches are per-engine), reset by
    draining between runs."""
    import jax
    from repro.configs import get_config
    from repro.distributed.fault import FaultPlane
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import MultiTenantScheduler

    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    # 4 rows and pages for ~4 long rings (16 prompt + 96 decode = 14 pages
    # each): slots, not pages, are the contended resource, so a tier-0
    # arrival against a full slot table exercises the slot-exhaustion
    # preemption path (victim swapped to host, restored when a slot frees)
    kw = dict(capacity=4, page_size=8, num_pages=64, inner_steps=4,
              max_prompt_len=16)
    n_req = 24
    # tier-1 rows must hold slots for much longer than a tier-0 request
    # can afford to wait: with ~250-step budgets a lo row occupies its slot
    # for ~60 micro-rounds, so natural retirements are far apart and a
    # blocked tier-0 arrival genuinely needs preemption (short lo budgets
    # degenerate: slots turn over faster than a swap cycle costs, and
    # waiting beats preempting)
    lo_steps = (192, 384)
    hi_every = 6
    # placeholder: calibrated below from the measured burst service rate,
    # so the "2x" in the row names holds whatever this host's speed is
    gap_s = 0.02

    # ONE shared engine across every run: jit caches are per-engine, and a
    # per-run fresh engine would spend the first arrivals' wall-clock on
    # compiles, collapsing any open-loop trace into a burst.  The fault
    # plane is swapped in and out around the injected run, and all engine
    # counters are read as deltas.
    ceng = ContinuousBatchingEngine(engine, **kw)

    def run(preempt: bool, open_loop: bool, plane: Optional[FaultPlane],
            seed: int):
        ceng.fault_plane = plane
        if ceng.swap_store is not None:
            ceng.swap_store.fault_plane = plane
        sched = MultiTenantScheduler(
            engine, mode="continuous", continuous_engine=ceng,
            preemption=preempt, fault_plane=plane, max_backlog=2 * n_req)
        c0 = (ceng.preemptions, ceng.restores, ceng.kv.swap_drops,
              ceng.kv.pages_allocated)
        t0 = time.perf_counter()
        rs = drive(sched, make_trace(n_req, seed, gap_s, cfg.vocab_size,
                                     hi_every=hi_every, lo_steps=lo_steps),
                   open_loop)
        wall = time.perf_counter() - t0
        ceng.fault_plane = None
        if ceng.swap_store is not None:
            ceng.swap_store.fault_plane = None
        return rs, sched, c0, wall

    # warm: *every* admission shape first — prefill jits key on
    # (batch size, prompt bucket), and an open-loop trace groups
    # admissions differently than the closed-loop warm burst does, so any
    # shape left cold becomes a several-hundred-ms compile stall in the
    # middle of a timed row (the stall backs up every later arrival into
    # one burst and lands entirely on whichever A/B row runs first) —
    # then the evict/restore jits (a forced preempt-restore cycle) and a
    # burst of the trace itself.  The *second* warm burst measures this
    # host's steady-state service rate, and the open-loop interarrival
    # gap is calibrated to offer 2x that — a hard-coded gap is 10x
    # oversubscribed on a loaded CI box and undersubscribed on a fast
    # idle one, and either extreme degenerates (all-queued burst / tier-0
    # lands in a free slot, no preemption)
    _warm_admission_shapes(engine, ceng, cfg, max_prompt=16)
    _warm_preempt(engine, ceng, cfg)
    run(True, False, None, seed=0)
    _, _, _, service_wall = run(True, False, None, seed=0)
    gap_s = service_wall / n_req / 2.0

    out: List[Row] = []
    rs, sched, c0, wall = run(True, True, None, seed=0)
    hi99_pre, derived = _summarise(rs, sched, ceng, c0)
    out.append((f"serving/overload_open2x_preempt_{n_req}r", wall * 1e6,
                derived))
    rs, sched, c0, wall = run(False, True, None, seed=0)
    hi99_base, derived = _summarise(rs, sched, ceng, c0)
    out.append((f"serving/overload_open2x_nopreempt_{n_req}r", wall * 1e6,
                derived + f";hi_p99_vs_preempt="
                          f"{hi99_base / max(hi99_pre, 1e-9):.2f}x"))

    rs, sched, c0, wall = run(True, False, None, seed=0)
    _, derived = _summarise(rs, sched, ceng, c0)
    out.append((f"serving/overload_burst_preempt_{n_req}r", wall * 1e6,
                derived))

    plane = FaultPlane(drop_round_every=9, stall_admission_every=7,
                       poison_swap_every=3)
    rs, sched, c0, wall = run(True, True, plane, seed=0)
    # robustness contract: every request reached exactly one terminal
    # outcome and the two-tier page ledger balances at drain
    assert len(rs) == n_req, (len(rs), n_req)
    ceng.kv.assert_conserved(
        host_pages=ceng.swap_store.pages() if ceng.swap_store else 0)
    _, derived = _summarise(
        rs, sched, ceng, c0,
        extra=(f";faults_injected={plane.total_injected()};"
               f"faults_survived={sched.faults_survived};"
               f"heartbeat_suspects={sched.heartbeat_suspects}"))
    out.append((f"serving/overload_faults_{n_req}r", wall * 1e6, derived))
    return out


def _warm_admission_shapes(engine, ceng, cfg, max_prompt: int) -> None:
    """Compile every (admission batch size, prompt bucket) prefill shape
    the trace can produce: k in 1..capacity same-bucket requests admitted
    together, for each bucket up to ``max_prompt``.  Short budgets keep
    each warm run to a few rounds."""
    from repro.serving.multitenant import MultiTenantScheduler, Request

    rng = np.random.default_rng(2)
    buckets = sorted({ceng.bucket_len(p)
                      for p in range(4, max_prompt + 1)})
    for bucket in buckets:
        for k in range(1, ceng.capacity + 1):
            sched = MultiTenantScheduler(engine, mode="continuous",
                                         continuous_engine=ceng)
            for j in range(k):
                sched.submit(Request(
                    f"warm-b{bucket}-{j}",
                    rng.integers(1, cfg.vocab_size,
                                 bucket).astype(np.int32),
                    max_new_tokens=4))
            sched.drain()


def _warm_preempt(engine, ceng, cfg) -> None:
    """Compile the evict/restore jits before any timed row: fill every slot
    with long tier-1 rows, then submit a tier-0 request so the scheduler
    preempts a victim, and drain (restore included)."""
    from repro.serving.multitenant import MultiTenantScheduler, Request

    sched = MultiTenantScheduler(engine, mode="continuous",
                                 continuous_engine=ceng, preemption=True)
    rng = np.random.default_rng(1)
    for i in range(ceng.capacity):
        sched.submit(Request(f"warm-lo{i}",
                             rng.integers(1, cfg.vocab_size,
                                          16).astype(np.int32),
                             max_new_tokens=48, priority=1))
    sched.step()
    sched.submit(Request("warm-hi",
                         rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                         max_new_tokens=4, priority=0))
    sched.drain()


# ----------------------------------------------------------------------
# Crash recovery: kill-and-restart wall-time row
# ----------------------------------------------------------------------
_RECOVERY_CHILD = r"""
import json, os, sys
import numpy as np, jax
from repro.configs import get_config
from repro.distributed.fault import FaultPlane
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine
from repro.serving.multitenant import MultiTenantScheduler, Request

phase, root = sys.argv[1], sys.argv[2]
cfg = get_config("internlm2-1.8b").reduced()
params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
engine = ServingEngine(cfg, params)
fp = FaultPlane(crash_at_round=12) if phase == "crash" else None
ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                num_pages=24, inner_steps=4,
                                max_prompt_len=16, fault_plane=fp)
sched = MultiTenantScheduler(
    engine, mode="continuous", continuous_engine=ceng,
    journal=os.path.join(root, "journal.jsonl"),
    checkpoint_dir=os.path.join(root, "ckpt"), checkpoint_every=3)
rng = np.random.default_rng(0)
if phase == "crash":
    for i in range(4):
        sched.submit(Request(
            "r%d" % i, rng.integers(1, cfg.vocab_size, 8 + i).astype(np.int32),
            max_new_tokens=24 + 2 * i, seed=7 + i,
            temperature=0.8 if i % 2 else None))
    sched.drain()                      # SIGKILLed at dispatched round 12
    sys.exit(3)                        # must never get here
import time
t0 = time.perf_counter()
s = sched.recover()
resp = sched.drain()
wall = time.perf_counter() - t0
print(json.dumps({
    "wall_s": wall, "rounds_replayed": s.rounds_replayed,
    "tokens_preserved": s.tokens_preserved,
    "tokens_replayed": s.tokens_replayed,
    "restored_live": s.restored_live,
    "restored_swapped": s.restored_swapped, "requeued": s.requeued,
    "completed": sum(r.outcome == "completed" for r in resp)
                 + len(s.already_complete)}))
"""


def bench_serving_recovery() -> List[Row]:
    """Kill-and-restart: a journalled+checkpointed serving child is
    SIGKILLed mid-round by the :class:`~repro.distributed.fault.
    FaultPlane` crash injector, then a fresh process recovers from the
    (journal, latest checkpoint) pair and drains to completion.  Rows
    report the recovery wall time (journal replay + checkpoint load +
    pool rebuild + replayed decode rounds), the rounds replayed, and the
    preserved-vs-lost token split (lost = emitted after the checkpoint,
    regenerated bitwise by deterministic replay — never silently gone)."""
    import json
    import os
    import subprocess
    import sys
    import tempfile

    root = tempfile.mkdtemp(prefix="bench_recovery_")
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.abspath("src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    crash = subprocess.run(
        [sys.executable, "-c", _RECOVERY_CHILD, "crash", root],
        env=env, capture_output=True, timeout=600)
    if crash.returncode != -9:
        raise RuntimeError(
            f"crash child exited {crash.returncode}, expected SIGKILL:\n"
            f"{crash.stderr.decode()[-2000:]}")
    rec = subprocess.run(
        [sys.executable, "-c", _RECOVERY_CHILD, "recover", root],
        env=env, capture_output=True, timeout=600)
    if rec.returncode != 0:
        raise RuntimeError(
            f"recovery child failed:\n{rec.stderr.decode()[-2000:]}")
    r = json.loads(rec.stdout.decode().strip().splitlines()[-1])
    if r["completed"] != 4:
        raise RuntimeError(f"recovery lost requests: {r}")
    return [
        ("recovery: wall time (SIGKILL -> drained)", r["wall_s"], "s"),
        ("recovery: rounds replayed", float(r["rounds_replayed"]),
         "rounds"),
        ("recovery: tokens preserved (checkpointed)",
         float(r["tokens_preserved"]), "tokens"),
        ("recovery: tokens replayed (post-ckpt, regenerated)",
         float(r["tokens_replayed"]), "tokens"),
        ("recovery: requests completed after restart",
         float(r["completed"]), "requests"),
    ]


ALL = [bench_serving_overload, bench_serving_recovery]
