"""Executable-pipeline benchmarks: what the overlap actually buys.

Two A/B comparisons on the real (CPU-reduced) stack:

* **blocking vs overlapped** — the legacy stage-all-then-compute schedule
  against :class:`repro.core.pipeline.PipelineExecutor` (stage k+1 under
  compute k), same tenancy, same data.  Emits per-tenant transfer/compute
  windows so the harness can verify transfer(k+1) starts before compute(k)
  ends, plus resident-table-cache and trace-count rows for the repeated-run
  (serving) regime.
* **serving blocking vs overlapped** — the multi-tenant scheduler on the
  engine's host-blocking ``generate`` loop against the dispatch/await split
  (prefill + on-device ``lax.scan`` decode enqueued without blocking, tenant
  k+1's batch assembly + staging running under tenant k's decode).  Emits
  wall-time rows for both schedules plus the realised overlap-pair count
  from the serving ``TenantTimeline`` (same falsifiable predicate as the
  risk pipeline rows).
* **gather vs one-hot** — the two aggregate_loss Pallas lookup strategies in
  interpret mode.  Interpret-mode wall time is an emulation artefact, not
  device time (the numbers rank Python-level op counts); the structural win
  of the one-hot path (MXU matmul instead of per-lane gather) only shows on
  real TPUs — the rows exist to track both variants' health and relative
  drift.

* **paged-attention backends** — the serving decode's dense jnp KV gather
  against the fused Pallas page-streaming kernel at several (bucket,
  page-size) points, with pages-touched and bytes-moved derived columns
  (the structural metric that transfers to real accelerators) plus a
  serving-level per-round A/B.

Run with ``python -m benchmarks.run --only pipeline [--json out.json]``.
Scale trials/devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
``--device-time`` switches the timers from bare wall time to
``jax.block_until_ready``-bracketed device timing: each timed call blocks
on every device array it returned before the clock stops, so on real
accelerators the number is time-to-device-completion instead of
time-to-enqueue (deferred from PR 1; on CPU the two coincide for the
host-blocking drains and differ only for benches that return device
arrays).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

# set by ``benchmarks.run --device-time``: bracket every timed call with
# jax.block_until_ready on its result (device timing, not enqueue timing)
DEVICE_TIME = False


_BLOCK = None                                  # jax.block_until_ready, lazy


def _ready(result):
    """Under --device-time, block on every device array in ``result``
    before the caller stops its clock; otherwise a pass-through."""
    global _BLOCK
    if DEVICE_TIME and result is not None:
        if _BLOCK is None:                     # resolve once, outside the
            import jax                         # per-sample timed region
            _BLOCK = jax.block_until_ready
        _BLOCK(result)
    return result


def _best_of(fn, n: int = 3) -> float:
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        _ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _min_ab(fn_a, fn_b, n: int = 9) -> Tuple[float, float, float, float]:
    """Interleaved A/B times; returns (min_a, min_b, med_a, med_b).

    The minimum is the noise-robust estimator on shared/throttled CPU hosts
    (scheduling noise is strictly additive); the median is reported alongside
    for drift tracking.  Under --device-time each call is bracketed by
    ``jax.block_until_ready`` on its return value."""
    ts_a, ts_b = [], []
    for _ in range(n):
        t0 = time.perf_counter()
        _ready(fn_a())
        ts_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _ready(fn_b())
        ts_b.append(time.perf_counter() - t0)
    return (min(ts_a), min(ts_b),
            sorted(ts_a)[n // 2], sorted(ts_b)[n // 2])


def bench_pipeline_overlap() -> List[Row]:
    import jax
    from repro.configs.risk_app import RiskAppConfig
    from repro.core.tenancy import TenancyConfig
    from repro.risk.analysis import AggregateRiskAnalysis
    from repro.risk.tables import generate

    devices = jax.devices()
    n_pdev = len(devices)
    # transfer-heavy shape: big YET, one cache-resident ELT, single event
    # chunk — staging is a large share of the step, which is the regime the
    # overlap targets (paper Fig 13; on TPU the DMA engines make this the
    # common case, on CPU hosts compute shares cores with the memcpy)
    cfg = dataclasses.replace(RiskAppConfig().reduced(), num_trials=131072,
                              events_per_trial=128, event_catalog=512,
                              num_elts=1, chunk_events=128)
    tables = generate(cfg, seed=0)
    tenancy = TenancyConfig(n_pdev, 2, "sequential")
    ara = AggregateRiskAnalysis(cfg, tenancy, devices=devices)

    # warm both schedules (compile once; uniform padding -> one trace)
    ara.run_tenant_chunked(tables, overlapped=False)
    ara.run_tenant_chunked(tables, overlapped=True)

    out: List[Row] = []
    t_blk, t_ovl, med_blk, med_ovl = _min_ab(
        lambda: ara.run_tenant_chunked(tables, overlapped=False),
        lambda: ara.run_tenant_chunked(tables, overlapped=True))
    tag = f"{n_pdev}p_2v"
    out.append((f"pipeline/blocking_{tag}", t_blk * 1e6,
                f"trials={cfg.num_trials};median_us={med_blk * 1e6:.0f}"))
    from repro.core.pipeline import timeline_overlaps
    rep = ara.run_tenant_chunked(tables, overlapped=True)
    # falsifiable overlap signal: transfer(k+1) began inside compute(k)'s
    # execution window (see repro.core.pipeline module docstring).  A
    # blocking schedule scores 0 pairs; noise on a shared host can drain
    # isolated pairs early, so "realised" = majority of pairs overlapped.
    overlaps = timeline_overlaps(rep.timeline)
    out.append((f"pipeline/overlapped_{tag}", t_ovl * 1e6,
                f"speedup={t_blk / t_ovl:.2f}x;"
                f"median_us={med_ovl * 1e6:.0f};"
                f"overlap_pairs={sum(overlaps)}/{len(overlaps)};"
                f"overlap_realised={sum(overlaps) > len(overlaps) // 2}"))
    for tl in rep.timeline:
        out.append((f"pipeline/tenant_v{tl.vdev}", tl.compute_s * 1e6,
                    f"pdev={tl.pdev};slot={tl.slot};"
                    f"tr={tl.transfer_start * 1e3:.2f}-"
                    f"{tl.transfer_end * 1e3:.2f}ms;"
                    f"cp={tl.compute_start * 1e3:.2f}-"
                    f"{tl.compute_end * 1e3:.2f}ms"))

    # repeated-run regime: resident tables + trace cache must both hit
    up0, tr0 = ara.table_uploads, ara.trace_count
    t_rerun = _best_of(lambda: ara.run_tenant_chunked(tables), n=2)
    out.append(("pipeline/rerun_resident", t_rerun * 1e6,
                f"table_uploads_delta={ara.table_uploads - up0};"
                f"trace_delta={ara.trace_count - tr0}"))
    return out


def bench_serving_overlap() -> List[Row]:
    import jax
    from repro.configs import get_config
    from repro.core.pipeline import timeline_overlaps
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import MultiTenantScheduler, Request

    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    tenants, requests, steps, plen = 3, 12, 16, 32
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
               for _ in range(requests)]

    def run(overlapped: bool) -> MultiTenantScheduler:
        sched = MultiTenantScheduler(engine, max_batch=4,
                                     overlapped=overlapped)
        for i, p in enumerate(prompts):
            sched.submit(Request(f"tenant-{i % tenants}", p,
                                 max_new_tokens=steps))
        sched.drain()                     # reaps the waiter thread too
        return sched

    run(False)                 # warm: prefill + per-token decode compiles
    run(True)                  # warm: prefill + scanned decode-loop compile

    out: List[Row] = []
    t_blk, t_ovl, med_blk, med_ovl = _min_ab(lambda: run(False),
                                             lambda: run(True), n=5)
    tag = f"{tenants}t_{requests}r_{steps}s"
    out.append((f"serving/blocking_{tag}", t_blk * 1e6,
                f"median_us={med_blk * 1e6:.0f};arch=internlm2-1.8b-reduced"))
    sched = run(True)
    ov = timeline_overlaps(sched.timeline)
    out.append((f"serving/overlapped_{tag}", t_ovl * 1e6,
                f"speedup={t_blk / t_ovl:.2f}x;"
                f"median_us={med_ovl * 1e6:.0f};"
                f"overlap_pairs={sum(ov)}/{len(ov)};"
                f"overlap_realised={sum(ov) > len(ov) // 2}"))
    for i, tl in enumerate(sched.timeline):
        out.append((f"serving/batch{i}_slot{tl.slot}", tl.compute_s * 1e6,
                    f"tr={tl.transfer_start * 1e3:.2f}-"
                    f"{tl.transfer_end * 1e3:.2f}ms;"
                    f"cp={tl.compute_start * 1e3:.2f}-"
                    f"{tl.compute_end * 1e3:.2f}ms"))
    return out


def bench_serving_continuous() -> List[Row]:
    """Continuous batching (paged KV-cache + persistent slot table) vs the
    slot-based overlapped schedule on a *ragged* request mix — the regime
    the new subsystem targets: mixed prompt lengths and token budgets, where
    slot batches pad every row to the batch max and drain between tenants.

    Emits wall-time A/B rows plus the occupancy comparison the paper's
    utilisation argument predicts: decode micro-rounds (device decode steps)
    sustained per wall-second, useful-token throughput, and the continuous
    engine's slot-occupancy / page-reuse counters.
    """
    import jax
    from repro.configs import get_config
    from repro.core.pipeline import timeline_overlaps
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import MultiTenantScheduler, Request

    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    # one shared continuous engine: its jitted decode round / admission are
    # compiled once and reused across every timed run
    ceng = ContinuousBatchingEngine(engine, capacity=4, page_size=8,
                                    inner_steps=8, max_prompt_len=16)
    # every tenant's slot batch pairs one 256-token straggler with three
    # 32-token rows, so the slot path decodes 256 serial padded steps per
    # batch while continuous retires the short rows and refills their lanes
    tenants, per_tenant = 3, 4
    steps_pat = [256, 32, 32, 32]
    rng = np.random.default_rng(0)
    mix = []
    for i in range(per_tenant):
        for t in range(tenants):
            mix.append((f"tenant-{t}",
                        rng.integers(1, cfg.vocab_size, 8).astype(np.int32),
                        steps_pat[i % len(steps_pat)]))
    useful_tokens = sum(s for _, _, s in mix)

    def run(mode: str) -> MultiTenantScheduler:
        sched = MultiTenantScheduler(
            engine, max_batch=4, mode=mode,
            continuous_engine=ceng if mode == "continuous" else None)
        for tenant, p, s in mix:
            sched.submit(Request(tenant, p, max_new_tokens=s))
        sched.drain()
        return sched

    run("overlapped")          # warm: per-steps decode-loop compiles
    run("continuous")          # warm: round + per-bucket admission compiles

    t_slot, t_cont, med_slot, med_cont = _min_ab(
        lambda: run("overlapped"), lambda: run("continuous"), n=5)

    # fresh measured runs for the occupancy counters (deltas per run).
    # micro-rounds/wall-second compares each schedule's decode granule —
    # the boundary at which it can admit/retire work: one padded batch
    # decode for the slot path vs one masked inner_steps round for
    # continuous — the headline occupancy claim of the A/B.
    d0 = engine.decode_steps
    t0 = time.perf_counter()
    sched_slot = run("overlapped")
    wall_slot = time.perf_counter() - t0
    slot_steps = engine.decode_steps - d0
    slot_batches = len(sched_slot.timeline)

    r0, rs0, pr0 = ceng.rounds, ceng.row_steps, ceng.kv.pages_reused
    t0 = time.perf_counter()
    sched_cont = run("continuous")
    wall_cont = time.perf_counter() - t0
    cont_rounds = ceng.rounds - r0
    cont_steps = cont_rounds * ceng.inner_steps
    cont_row_steps = ceng.row_steps - rs0

    tag = f"{tenants}t_{len(mix)}r_ragged"
    out: List[Row] = []
    out.append((f"serving/slotbatch_{tag}", t_slot * 1e6,
                f"median_us={med_slot * 1e6:.0f};"
                f"micro_rounds_per_s={slot_batches / wall_slot:.1f};"
                f"decode_steps={slot_steps};"
                f"steps_per_s={slot_steps / wall_slot:.1f};"
                f"useful_tok_per_s={useful_tokens / wall_slot:.1f}"))
    ov = timeline_overlaps(sched_cont.timeline)
    out.append((f"serving/continuous_{tag}", t_cont * 1e6,
                f"speedup={t_slot / t_cont:.2f}x;"
                f"median_us={med_cont * 1e6:.0f};"
                f"micro_rounds_per_s={cont_rounds / wall_cont:.1f};"
                f"decode_steps={cont_steps};"
                f"steps_per_s={cont_steps / wall_cont:.1f};"
                f"useful_tok_per_s={useful_tokens / wall_cont:.1f};"
                f"occupancy={cont_row_steps / max(cont_steps * ceng.capacity, 1):.2f};"
                f"pages_reused={ceng.kv.pages_reused - pr0};"
                f"overlap_pairs={sum(ov)}/{len(ov)}"))
    return out


def bench_serving_prefix_sharing() -> List[Row]:
    """Refcounted prefix sharing + batched admission vs the PR-3 continuous
    baseline (one B=1 prefill per admission, private pages per request) on
    the workload the sharing targets: N tenants whose every query carries
    the same system prompt, with a tail of exact repeat queries (dashboard
    refreshes).

    Emits the cold-run allocator comparison the tentpole's acceptance
    criteria name — pages allocated and prefill calls with sharing+batching
    off vs on — plus steady-state (warm trie) deltas and the wall-time A/B.
    Token-exactness of the shared path is locked in by
    ``tests/test_continuous.py``, not re-checked here.
    """
    import jax
    from repro.configs import get_config
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import Request

    cfg = get_config("internlm2-1.8b").reduced()
    params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    tenants, queries = 4, 2
    page, sys_len, user_len, new_tok = 16, 48, 16, 8
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, cfg.vocab_size, sys_len).astype(np.int32)
    originals, repeats = [], []
    for t in range(tenants):
        for _ in range(queries):
            user = rng.integers(1, cfg.vocab_size,
                                user_len).astype(np.int32)
            originals.append(Request(
                f"tenant-{t}", np.concatenate([system_prompt, user]),
                max_new_tokens=new_tok))
        repeats.append(Request(f"tenant-{t}",
                               originals[-1].prompt.copy(),
                               max_new_tokens=new_tok))
    mix = originals + repeats        # repeats arrive after their originals

    def make(shared: bool) -> ContinuousBatchingEngine:
        # the baseline disables both tentpole halves: B=1 admission prefill
        # and private pages per request — exactly mode="continuous" as of
        # PR 3
        return ContinuousBatchingEngine(
            engine, capacity=8, page_size=page, inner_steps=4,
            max_prompt_len=sys_len + user_len, prefix_sharing=shared,
            batch_admission=shared)

    ceng_base, ceng_share = make(False), make(True)
    # cold-run counters: what one pass over the workload allocates/prefills
    ceng_base.run_all(mix)
    pages_base, calls_base = (ceng_base.kv.pages_allocated,
                              ceng_base.prefill_calls)
    ceng_share.run_all(mix)
    pages_share, calls_share = (ceng_share.kv.pages_allocated,
                                ceng_share.prefill_calls)
    skips_cold = ceng_share.prefill_skips
    shared_cold, forks_cold, pristine_cold = (
        ceng_share.kv.pages_shared, ceng_share.kv.cow_forks,
        ceng_share.kv.pristine_forks)

    # steady state: the trie retains the shared chains, so a repeat pass
    # shares nearly everything
    p0, c0, s0 = (ceng_share.kv.pages_allocated, ceng_share.prefill_calls,
                  ceng_share.prefill_skips)
    ceng_share.run_all(mix)
    steady_pages = ceng_share.kv.pages_allocated - p0
    steady_calls = ceng_share.prefill_calls - c0
    steady_skips = ceng_share.prefill_skips - s0

    t_base, t_share, med_base, med_share = _min_ab(
        lambda: ceng_base.run_all(mix), lambda: ceng_share.run_all(mix),
        n=5)

    # reuse-aware pristine-preserve A/B: on a share-nothing workload the
    # PR-4 preserve-always policy pays one page copy per admission to cache
    # chains nobody ever re-shares; the reuse-aware default (preserve only
    # after a recorded sharing hit) should pay none — while the shared
    # workload above keeps its pristine cache (hits recorded)
    lonely = [Request(f"t{i}", rng.integers(1, cfg.vocab_size,
                                            sys_len).astype(np.int32),
                      max_new_tokens=new_tok) for i in range(8)]
    policy_rows = []
    for policy in ("always", True):
        ceng_p = ContinuousBatchingEngine(
            engine, capacity=8, page_size=page, inner_steps=4,
            max_prompt_len=sys_len + user_len, preserve_pristine=policy)
        ceng_p.run_all(lonely)
        policy_rows.append((policy, ceng_p.kv.pristine_forks,
                            ceng_p.kv.pages_allocated))

    tag = f"{tenants}t_{len(mix)}r_sysprompt"
    out: List[Row] = []
    (_, forks_always, pages_always), (_, forks_reuse, pages_reuse) = \
        policy_rows
    out.append((f"serving/pristine_policy_sharenothing_{tag}",
                float(forks_always),
                f"pristine_forks_always={forks_always};"
                f"pristine_forks_reuse_aware={forks_reuse};"
                f"pages_allocated_always={pages_always};"
                f"pages_allocated_reuse_aware={pages_reuse};"
                f"copies_eliminated={forks_always - forks_reuse}"))
    out.append((f"serving/prefix_unshared_{tag}", t_base * 1e6,
                f"median_us={med_base * 1e6:.0f};"
                f"pages_allocated={pages_base};"
                f"prefill_calls={calls_base};"
                f"arch=internlm2-1.8b-reduced"))
    out.append((f"serving/prefix_shared_{tag}", t_share * 1e6,
                f"speedup={t_base / t_share:.2f}x;"
                f"median_us={med_share * 1e6:.0f};"
                f"pages_allocated={pages_share};"
                f"pages_saved={1 - pages_share / pages_base:.0%};"
                f"prefill_calls={calls_share};"
                f"prefill_call_ratio="
                f"{calls_base / max(calls_share, 1):.1f}x;"
                f"prefill_skips={skips_cold};"
                f"pages_shared={shared_cold};"
                f"cow_forks={forks_cold};"
                f"pristine_forks={pristine_cold};"
                f"steady_pages={steady_pages};"
                f"steady_prefill_calls={steady_calls};"
                f"steady_prefill_skips={steady_skips}"))
    return out


def bench_paged_attention() -> List[Row]:
    """Paged-attention backend A/B: the dense jnp gather (materialise every
    row's full logical window per decode step) against the fused Pallas
    kernel (stream page blocks in place through the page table) at several
    (bucket, page-size) points, plus a serving-level per-round comparison.

    Two metric families per point:

    * **wall/device time** — honest but, for the pallas rows on CPU, an
      *interpret-mode emulation artefact* (every grid cell is a Python-level
      block evaluation): rank them for drift, not for speed.  On real TPUs
      the time ratio follows the bytes ratio.
    * **derived traffic columns** — pages touched and pool bytes moved per
      call, computed from the page tables: the gather path always touches
      ``C x NB`` page blocks *and* materialises them as a dense
      ``[C, NB*P, Hkv, D]`` intermediate (written then re-read by the
      attention einsum); the fused path touches only the live pages (+ the
      shared SENTINEL page for table padding) and materialises nothing.
      This is the structural O(bucket) -> O(live-tokens) claim, measured
      from the same tables the kernels consume.

    The serving-level rows run one ragged workload through both backends of
    the continuous engine and derive per-round pool traffic from the
    allocator's live-page counts at each dispatch.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.serving.kvcache import (POS_SENTINEL, PagedKVCache,
                                       paged_attend)

    cfg = get_config("internlm2-1.8b").reduced()
    C, Hkv, D, H = 4, cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    rng = np.random.default_rng(0)
    out: List[Row] = []
    bf16 = 2                                   # pool bytes per element

    for bucket, page in ((64, 8), (128, 16), (256, 16)):
        NB = bucket // page
        NP_ = PagedKVCache.RESERVED + C * NB
        k_pool = jnp.asarray(rng.standard_normal((NP_, page, Hkv, D)),
                             jnp.bfloat16)
        v_pool = jnp.asarray(rng.standard_normal((NP_, page, Hkv, D)),
                             jnp.bfloat16)
        pos_pool = np.full((NP_, page), POS_SENTINEL, np.int32)
        page_table = np.full((C, NB), PagedKVCache.SENTINEL, np.int32)
        next_page, live_pages, live_tokens = PagedKVCache.RESERVED, 0, 0
        for c in range(C):                     # ragged: 1/4 .. 4/4 of NB
            nb_c = max(1, ((c + 1) * NB) // C)
            pos = nb_c * page - 1
            live_pages += nb_c
            live_tokens += pos + 1
            for b in range(nb_c):
                page_table[c, b] = next_page
                pos_pool[next_page] = np.arange(b * page, (b + 1) * page)
                next_page += 1
        pt = jnp.asarray(page_table)
        pp_ = jnp.asarray(pos_pool)
        pos = jnp.asarray([max(1, ((c + 1) * NB) // C) * page - 1
                           for c in range(C)], jnp.int32)
        q = jnp.asarray(rng.standard_normal((C, H, D)).astype(np.float32))

        fn_jnp = jax.jit(lambda q, k, v, pp_, pt, ps: paged_attend(
            q, {"k": k, "v": v}, pt, ps, cfg, pos_pool=pp_, backend="jnp"))
        fn_pal = jax.jit(lambda q, k, v, pp_, pt, ps: paged_attend(
            q, {"k": k, "v": v}, pt, ps, cfg, pos_pool=pp_,
            backend="pallas"))
        a = fn_jnp(q, k_pool, v_pool, pp_, pt, pos)     # warm + validate
        b = fn_pal(q, k_pool, v_pool, pp_, pt, pos)
        ok = bool(np.allclose(np.asarray(a), np.asarray(b), rtol=3e-5,
                              atol=3e-6))
        t_jnp, t_pal, med_jnp, med_pal = _min_ab(
            lambda: fn_jnp(q, k_pool, v_pool, pp_, pt, pos),
            lambda: fn_pal(q, k_pool, v_pool, pp_, pt, pos))

        page_bytes = page * Hkv * D * bf16 * 2          # k + v
        dense_blocks = C * NB                           # every table entry
        gather_bytes = dense_blocks * page_bytes        # pool reads
        dense_interm = dense_blocks * page_bytes * 2    # write + re-read
        fused_blocks = live_pages + 1                   # + shared SENTINEL
        fused_bytes = fused_blocks * page_bytes
        tag = f"{bucket}b_{page}p"
        out.append((f"paged/attend_jnp_{tag}", t_jnp * 1e6,
                    f"median_us={med_jnp * 1e6:.0f};"
                    f"pages_touched={dense_blocks};"
                    f"bytes_moved={gather_bytes + dense_interm};"
                    f"dense_intermediate_bytes={dense_interm};"
                    f"live_tokens={live_tokens}"))
        out.append((f"paged/attend_pallas_{tag}", t_pal * 1e6,
                    f"median_us={med_pal * 1e6:.0f};"
                    f"pages_touched={fused_blocks};"
                    f"bytes_moved={fused_bytes};"
                    f"dense_intermediate_bytes=0;"
                    f"live_tokens={live_tokens};"
                    f"bytes_saved={(gather_bytes + dense_interm) / fused_bytes:.1f}x;"
                    f"matches_jnp={ok};interp_emulation=True"))

    # serving-level per-round A/B on a ragged continuous workload
    from repro.models import params as pp2
    from repro.models.model import build_model
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine
    from repro.serving.multitenant import Request

    from repro.serving.kvcache import attn_subs
    params, _ = pp2.split(build_model(cfg).init(jax.random.PRNGKey(0)))
    engine = ServingEngine(cfg, params)
    reqs = [Request(f"t{i % 2}",
                    rng.integers(1, cfg.vocab_size,
                                 8 + 8 * (i % 3)).astype(np.int32),
                    max_new_tokens=4 + 4 * (i % 2)) for i in range(8)]
    n_attn = len(attn_subs(cfg))

    rows = {}
    for backend in ("jnp", "pallas"):
        ceng = ContinuousBatchingEngine(engine, capacity=4, page_size=8,
                                        inner_steps=4, max_prompt_len=32,
                                        backend=backend)
        live_at_dispatch = []
        orig = ceng.dispatch_round

        def probe(ceng=ceng, live=live_at_dispatch, orig=orig):
            kv = ceng.kv
            live.append(kv.num_pages - kv.RESERVED - kv.free_pages()
                        - kv.cached_pages())
            return orig()

        ceng.dispatch_round = probe
        ceng.run_all(reqs)                      # warm (compiles)
        live_at_dispatch.clear()
        r0 = ceng.rounds
        t = _best_of(lambda: ceng.run_all(reqs), n=3)
        rounds = (ceng.rounds - r0) // 3
        rows[backend] = (t, rounds, float(np.mean(live_at_dispatch)), ceng)

    page_bytes = 8 * Hkv * D * bf16 * 2
    n_layers = n_attn * rows["jnp"][3].n_stages
    per_round = {}
    for backend, (t, rounds, live_mean, ceng) in rows.items():
        steps = ceng.inner_steps
        if backend == "jnp":
            blocks = 4 * ceng.kv.max_blocks             # capacity x NB
            traffic = steps * n_layers * blocks * page_bytes * 3
        else:
            traffic = steps * n_layers * (live_mean + 1) * page_bytes
        per_round[backend] = traffic
        out.append((f"paged/serving_round_{backend}", t / max(rounds, 1) * 1e6,
                    f"rounds_per_drain={rounds};"
                    f"mean_live_pages={live_mean:.1f};"
                    f"pool_bytes_per_round={traffic:.0f};"
                    + (f"bytes_improvement="
                       f"{per_round['jnp'] / traffic:.1f}x;"
                       f"interp_emulation=True" if backend == "pallas"
                       else "dense_window=full")))
    return out


def bench_kernel_variants() -> List[Row]:
    import jax.numpy as jnp
    from repro.kernels.aggregate_loss import aggregate_loss_pallas
    from repro.kernels.ref import aggregate_loss_chunked_ref

    rng = np.random.default_rng(0)
    T, K, M, cat = 256, 64, 8, 2048
    ids = jnp.asarray(rng.integers(0, cat + 1, (T, K)).astype(np.int32))
    elt = np.abs(rng.normal(size=(cat + 1, M))).astype(np.float32)
    elt[0] = 0.0
    elt = jnp.asarray(elt)
    occ_r = jnp.asarray((np.abs(rng.normal(size=M)) * 0.5).astype(np.float32))
    occ_l = jnp.asarray((np.abs(rng.normal(size=M)) + 1.0).astype(np.float32))
    args = (ids, elt, occ_r, occ_l, np.float32(K * 0.1), np.float32(K * 0.8))
    want = np.asarray(aggregate_loss_chunked_ref(*args, chunk=32))

    out: List[Row] = []
    for variant in ("gather", "onehot"):
        run = lambda: aggregate_loss_pallas(*args, chunk=32, trial_block=64,
                                            variant=variant)
        got = np.asarray(run())                      # warm + validate
        ok = bool(np.allclose(got, want, rtol=1e-5, atol=1e-3))
        t = _best_of(run, n=2)
        out.append((f"pipeline/agg_variant_{variant}_interp", t * 1e6,
                    f"matches_ref={ok};T={T};K={K};cat={cat}"))
    return out


_MESH_CHILD = r'''
import dataclasses, json, os, sys, time
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()
import numpy as np
import jax
from repro.configs import get_config
from repro.distributed.sharding import parse_mesh, serving_sharder
from repro.models import params as pp
from repro.models.model import build_model
from repro.serving.continuous import ContinuousBatchingEngine
from repro.serving.engine import ServingEngine

# reduced() clamps to 2 KV heads; re-widen so 8 ways divide the pools
cfg = dataclasses.replace(get_config("internlm2-1.8b").reduced(),
                          num_heads=16, num_kv_heads=8)
params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
rng = np.random.default_rng(0)


class R:
    def __init__(self, rid, prompt, n):
        self.rid = rid
        self.prompt = prompt
        self.max_new_tokens = n
        self.temperature = 0.0
        self.top_k = 0
        self.seed = 0


prompts = [rng.integers(1, cfg.vocab_size, 8 + 4 * (i % 3)).astype(np.int32)
           for i in range(8)]


def run(sh, capacity, num_pages):
    eng = ServingEngine(cfg, params, sh=sh)
    ceng = ContinuousBatchingEngine(eng, capacity=capacity, page_size=8,
                                    num_pages=num_pages, inner_steps=2,
                                    max_prompt_len=32)
    ceng.run_all([R(-1, prompts[0], 2)])           # warm the jit caches
    t0 = time.perf_counter()
    out = ceng.run_all([R(i, p, 8) for i, p in enumerate(prompts)])
    dt = time.perf_counter() - t0
    # completion order depends on capacity; key by request id
    return dt, {req.rid: toks for req, toks in out}, ceng


base_dt, base_toks, bceng = run(None, 4, 48)
# one sharded engine instance at 2x the slots: per-device KV stays flat
# because the pool splits 8 ways along KV heads
mesh_dt, mesh_toks, mceng = run(serving_sharder(parse_mesh("1x8")), 8, 96)
exact = all(np.array_equal(base_toks[i], mesh_toks[i])
            for i in range(len(prompts)))
name = mceng.kv.attn_subs[0]
pool = mceng.state["caches"][name]["k"]
shard_bytes = next(iter(pool.addressable_shards)).data.nbytes
print(json.dumps({
    "base_s": base_dt, "mesh_s": mesh_dt, "token_exact": bool(exact),
    "base_capacity": 4, "mesh_capacity": 8,
    "n_shards": len(pool.sharding.device_set),
    "pool_bytes_full": int(pool.nbytes), "pool_bytes_shard": int(shard_bytes),
    "decode_traces": mceng.decode_traces}))
'''


def bench_serving_mesh() -> List[Row]:
    """One mesh-sharded engine instance against the single-device baseline:
    same eight-request greedy workload, but the 1x8 engine runs 2x the slot
    capacity while each device holds 1/8 of the KV pool.  Spawned as a
    subprocess because the mesh needs 8 host devices and XLA_FLAGS is fixed
    at interpreter start (the bench parent may be running on one device)."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _MESH_CHILD], env=env,
                          capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        return [("pipeline/serving_mesh_error", float("nan"),
                 proc.stderr.strip().splitlines()[-1][:120]
                 if proc.stderr.strip() else "child failed")]
    rep = json.loads(proc.stdout.strip().splitlines()[-1])
    cap_ratio = rep["mesh_capacity"] / rep["base_capacity"]
    shard_frac = rep["pool_bytes_shard"] / rep["pool_bytes_full"]
    tag = (f"token_exact={rep['token_exact']};mesh=1x8;"
           f"decode_traces={rep['decode_traces']}")
    return [
        ("pipeline/serving_mesh_base_cap4_drain", rep["base_s"] * 1e6,
         f"{tag};capacity={rep['base_capacity']}"),
        ("pipeline/serving_mesh_1x8_cap8_drain", rep["mesh_s"] * 1e6,
         f"{tag};capacity={rep['mesh_capacity']}"),
        ("pipeline/serving_mesh_capacity_per_engine_x", cap_ratio,
         f"derived;slots_per_instance_vs_single_device;{tag}"),
        ("pipeline/serving_mesh_pool_shard_fraction", shard_frac,
         f"derived;per_device_kv_bytes/full={rep['pool_bytes_shard']}"
         f"/{rep['pool_bytes_full']};n_shards={rep['n_shards']}"),
    ]


def bench_serving_archs() -> List[Row]:
    """Continuous serving across the state-kind-representative archs the
    paged-state pool (PR 9) unlocks: whisper-base (attn KV pages plus
    read-only cross-attention pages written once at admission), mamba2-2.7b
    (no pages at all — checkpointed SSM slot records) and h2o-danube-1.8b
    (sliding-window attn with window-phase chain keys).  Each drains a small
    ragged request mix and reports wall time plus the pool's per-kind
    counters; every row also replays the same requests through the blocking
    oracle and asserts token-exactness, so the bench doubles as an
    end-to-end smoke for every non-attention serving path."""
    import jax
    from repro.configs import get_config
    from repro.models import params as pp
    from repro.models.model import build_model
    from repro.serving.continuous import ContinuousBatchingEngine
    from repro.serving.engine import ServingEngine, resolve_extra_inputs
    from repro.serving.multitenant import Request

    out: List[Row] = []
    for arch in ("whisper-base", "mamba2-2.7b", "h2o-danube-1.8b"):
        cfg = get_config(arch).reduced()
        params, _ = pp.split(build_model(cfg).init(jax.random.PRNGKey(0)))
        engine = ServingEngine(cfg, params)
        ceng = ContinuousBatchingEngine(engine, capacity=2, page_size=8,
                                        inner_steps=4, max_prompt_len=16)
        rng = np.random.default_rng(0)
        reqs = [Request(f"t{i}", rng.integers(1, cfg.vocab_size,
                        int(n)).astype(np.int32), max_new_tokens=8)
                for i, n in enumerate((5, 9, 13))]
        # warm: admission + round jits compile outside the timed drain
        ceng.run_all([Request("warm", reqs[0].prompt.copy(),
                              max_new_tokens=2)])
        t0 = time.perf_counter()
        done = {req.tenant: toks for req, toks in ceng.run_all(list(reqs))}
        dt = time.perf_counter() - t0
        exact = True
        for req in reqs:
            # blocking replay under the continuous path's conventions: the
            # prompt left-padded to its admission bucket and the same
            # resolved per-request extras (e.g. default zero enc-dec frames)
            b = ceng.bucket_len(req.prompt.size)
            padded = np.zeros((1, b), np.int32)
            padded[0, b - req.prompt.size:] = req.prompt
            extra = {k: np.asarray(v)[None] for k, v in
                     resolve_extra_inputs(cfg, req).items()}
            ref = engine.generate(padded, req.max_new_tokens,
                                  extra_inputs=extra or None,
                                  seed=req.seed).tokens[0]
            exact = exact and np.array_equal(done[req.tenant], ref)
        kinds = "+".join(k.name for k in ceng.kv.state_kinds)
        out.append((f"serving/archs_{arch}_drain", dt * 1e6,
                    f"token_exact={exact};kinds={kinds};"
                    f"rounds={ceng.rounds};"
                    f"pages_shared={ceng.kv.pages_shared};"
                    f"cross_pages={ceng.kv.num_cross_pages}"))
    return out


ALL = [bench_pipeline_overlap, bench_serving_overlap,
       bench_serving_continuous, bench_serving_prefix_sharing,
       bench_paged_attention, bench_kernel_variants, bench_serving_mesh,
       bench_serving_archs]
