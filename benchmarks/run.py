# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure + kernels + pipeline rows.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig13,roofline
    PYTHONPATH=src python -m benchmarks.run --only pipeline \
        --json BENCH_pipeline.json

``--json`` additionally writes the rows as a machine-readable perf record
(list of {name, us_per_call, derived} plus run metadata) so the perf
trajectory — e.g. blocking vs overlapped wall time for both the risk
pipeline (``pipeline/*``) and the multi-tenant serving scheduler
(``serving/*``), with per-tenant transfer/compute windows and realised
overlap-pair counts — can be tracked across PRs.  ``--only recovery``
selects the crash-recovery row (``overload.bench_serving_recovery``): a
journalled child is SIGKILLed mid-round and a fresh process recovers,
reporting recovery wall time, rounds replayed and the preserved-vs-
replayed token split.  With ``--json`` the
global telemetry plane is enabled for the run and each row carries the
counter *delta* its bench produced (``telemetry``: pages allocated/shared,
bytes moved through staging lanes, preemptions/restores, fault
injections...), plus a final full snapshot in the record metadata — the
perf trajectory and the resource trajectory travel in one artifact.
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on bench names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to PATH as a JSON perf record")
    ap.add_argument("--device-time", action="store_true",
                    help="bracket every timed call with jax.block_until_"
                         "ready on its result: on accelerators the rows "
                         "measure device completion instead of host "
                         "enqueue (min-of-N wall time otherwise)")
    args = ap.parse_args()
    filters = args.only.split(",") if args.only else None

    from benchmarks import overload, paper_figures, pipeline, roofline
    if args.device_time:
        pipeline.DEVICE_TIME = True
    benches = (list(paper_figures.ALL) + list(pipeline.ALL)
               + list(overload.ALL) + [roofline.run])

    if filters:
        # a filter matching nothing is a typo (e.g. --only sevring), not an
        # empty run: fail loudly with the matchable names instead of
        # printing a healthy-looking header and exiting 0
        names = [b.__module__ + "." + b.__name__ for b in benches]
        unknown = [f for f in filters
                   if not any(f in bname for bname in names)]
        if unknown:
            print(f"--only: no bench matches {','.join(unknown)!r}; "
                  f"known benches: {', '.join(names)}", file=sys.stderr)
            sys.exit(2)

    tel = None
    if args.json is not None:
        from repro.obs import TELEMETRY
        tel = TELEMETRY.enable()

    print("name,us_per_call,derived")
    rows, errors = [], []
    for bench in benches:
        bname = bench.__module__ + "." + bench.__name__
        if filters and not any(f in bname for f in filters):
            continue
        before = tel.counter_snapshot() if tel is not None else {}
        bench_rows = []
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.2f},{derived}")
                row = {"name": name, "us_per_call": us,
                       "derived": derived, "bench": bname}
                rows.append(row)
                bench_rows.append(row)
        except Exception as e:
            errors.append({"bench": bname, "error": repr(e)})
            print(f"{bname},0.0,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
        if tel is not None and bench_rows:
            # per-bench counter delta (pages, bytes moved, preemptions...)
            # attached to each of the bench's rows
            after = tel.counter_snapshot()
            delta = {k: v - before.get(k, 0) for k, v in after.items()
                     if v != before.get(k, 0)}
            for row in bench_rows:
                row["telemetry"] = delta

    if args.json is not None:
        import jax
        # rows carry their source bench and errors name the failed benches,
        # so a trajectory consumer can tell partial coverage from healthy
        record = {
            "schema": "repro-bench-rows/v1",
            "devices": [str(d) for d in jax.devices()],
            "device_time": bool(args.device_time),
            "failures": len(errors),
            "errors": errors,
            "rows": rows,
            "telemetry": tel.metric_snapshot(),
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)
    if errors:
        sys.exit(1)


if __name__ == '__main__':
    main()
