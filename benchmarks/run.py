# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure + kernels + roofline rows.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig13,roofline
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters on bench names")
    args = ap.parse_args()
    filters = args.only.split(",") if args.only else None

    from benchmarks import paper_figures, roofline
    benches = list(paper_figures.ALL) + [roofline.run]

    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        bname = bench.__module__ + "." + bench.__name__
        if filters and not any(f in bname for f in filters):
            continue
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.2f},{derived}")
        except Exception:
            failures += 1
            print(f"{bname},0.0,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
