"""One benchmark per paper table/figure.

Each `bench_*` returns a list of (name, us_per_call, derived) rows.  Model
and simulator rows derive from the paper's Table II constants; `measured`
rows time the real CPU-reduced stack (jit'd ARA engine + staging engine), so
the harness exercises every layer it reports on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


# ---------------------------------------------------------------------------
# Table I + Fig 1 + Fig 6 — local scalability & compute/transfer split
# ---------------------------------------------------------------------------
def bench_table1_scalability() -> List[Row]:
    from repro.core import perfmodel as pm
    out: List[Row] = []
    m = pm.PerfModelInputs(net=pm.FDR)
    # paper Table I measured totals (CUDA, local): 10.928 / 5.53 / 2.857
    paper = {1: 10.928, 2: 5.53, 4: 2.857}
    for n, total in paper.items():
        model_t = pm.t_computation(n, m) + 1.378 / n ** 0.7  # calibrated local
        norm = total / paper[1]
        offset = norm - 1.0 / n
        out.append((f"table1/local_cuda_{n}gpu", total * 1e6,
                    f"paper_norm={norm:.3f};offset={offset:.3f};"
                    f"model={model_t:.3f}s"))
    return out


def bench_fig6_split() -> List[Row]:
    """Measured compute vs staging split on the reduced CPU stack."""
    import jax.numpy as jnp
    from repro.configs.risk_app import RiskAppConfig
    from repro.core.tenancy import TenancyConfig
    from repro.risk.analysis import AggregateRiskAnalysis
    from repro.risk.tables import generate

    cfg = dataclasses.replace(RiskAppConfig().reduced(), num_trials=512,
                              events_per_trial=64)
    tables = generate(cfg)
    out: List[Row] = []
    for splits in (1, 2, 4):
        ara = AggregateRiskAnalysis(cfg, TenancyConfig(1, splits))
        # blocking schedule: this bench *decomposes* wall time into compute
        # vs staging, which only adds up when the phases don't overlap (the
        # overlapped pipeline has its own A/B bench in benchmarks/pipeline.py)
        rep = ara.run_tenant_chunked(tables, overlapped=False)   # warm
        rep = ara.run_tenant_chunked(tables, overlapped=False)
        compute = sum(rep.per_tenant_s.values())
        stage = max((e["ready_s"] for e in rep.staging_log), default=0.0)
        out.append((f"fig6/measured_split_{splits}v", rep.wall_s * 1e6,
                    f"compute={compute*1e3:.1f}ms;staging={stage*1e3:.1f}ms"))
    return out


# ---------------------------------------------------------------------------
# Fig 8 / Fig 10 — concurrent transfer bandwidth sharing
# ---------------------------------------------------------------------------
def bench_fig8_bandwidth() -> List[Row]:
    from repro.core.simulator import effective_bandwidth
    out: List[Row] = []
    for bw, net in ((6000.0, "pinned_local"), (5600.0, "fdr_rcuda")):
        for n in (1, 2, 4, 8, 16):
            eff = effective_bandwidth(n, bw)
            out.append((f"fig8/{net}_{n}streams", 1e6 / eff,
                        f"per_stream_mb_s={eff:.0f}"))
    return out


# ---------------------------------------------------------------------------
# Fig 9 — rCUDA scaling up to 16 remote vdevs (QDR/FDR)
# ---------------------------------------------------------------------------
def bench_fig9_remote_scaling() -> List[Row]:
    from repro.core import perfmodel as pm
    out: List[Row] = []
    for net in (pm.QDR, pm.FDR):
        m = pm.PerfModelInputs(net=net)
        for n in (1, 2, 4, 8, 16):
            t = pm.exec_time_no_mt(n, m)
            out.append((f"fig9/{net.name}_{n}gpu", t * 1e6,
                        f"compute={pm.t_computation(n, m):.3f}s;"
                        f"transfer={pm.t_transfer(n, m):.3f}s"))
    return out


# ---------------------------------------------------------------------------
# Fig 11 / Fig 12 — transfer modes; Fig 13 / Fig 14 — multi-tenancy
# ---------------------------------------------------------------------------
def bench_fig11_transfer_modes() -> List[Row]:
    from repro.core.simulator import SimInputs, simulate_cells
    from repro.core.tenancy import TenancyConfig
    out: List[Row] = []
    for mode in ("concurrent", "sequential"):
        r = simulate_cells(SimInputs(TenancyConfig(4, 1, mode)))
        out.append((f"fig11/{mode}_4pdev", r.makespan * 1e6,
                    f"cells={r.steps()};util={r.utilization*100:.1f}%"))
    return out


def bench_fig13_multitenancy() -> List[Row]:
    from repro.core.simulator import SimInputs, simulate_cells
    from repro.core.tenancy import TenancyConfig
    out: List[Row] = []
    paper_cells = {1: 88, 2: 80, 4: 76}
    for t, want in paper_cells.items():
        r = simulate_cells(SimInputs(TenancyConfig(4, t, "sequential")))
        out.append((f"fig13/{t}vdev_per_pdev", r.makespan * 1e6,
                    f"cells={r.steps()};paper={want};"
                    f"match={r.steps() == want}"))
    return out


def bench_fig14_energy() -> List[Row]:
    from repro.core.simulator import SimInputs, simulate_cells
    from repro.core.tenancy import TenancyConfig
    out: List[Row] = []
    paper = {1: 1145.0, 2: 1094.0, 4: 1041.0}
    for t, want in paper.items():
        r = simulate_cells(SimInputs(TenancyConfig(4, t, "sequential")))
        out.append((f"fig14/energy_{t}vdev", r.makespan * 1e6,
                    f"model={r.energy_ws:.0f}Ws;paper={want:.0f}Ws;"
                    f"util={r.utilization*100:.1f}%"))
    return out


# ---------------------------------------------------------------------------
# Fig 15 / 16 — measured-style sweeps over (pdev, tenants)
# ---------------------------------------------------------------------------
def bench_fig15_16_combinations() -> List[Row]:
    from repro.core import perfmodel as pm
    out: List[Row] = []
    for net in (pm.QDR, pm.FDR):
        m = pm.PerfModelInputs(net=net)
        for p in (1, 2, 4, 6, 12):
            for v in (1, 2, 4):
                if not pm.feasible(p, v, m):
                    continue
                nv = p * v
                t = pm.exec_time_multitenancy(p, v, m)
                overlapped = (pm.t_transfer(nv, m) + pm.t_computation(nv, m)
                              - t)
                out.append((f"fig15_16/{net.name}_{p}p_{v}v", t * 1e6,
                            f"overlapped={max(overlapped,0):.3f}s"))
    return out


# ---------------------------------------------------------------------------
# Figs 17-22 — perf/energy/EDP model surfaces and optima
# ---------------------------------------------------------------------------
def bench_fig17_22_models() -> List[Row]:
    from repro.core import perfmodel as pm
    from repro.core.planner import plan
    out: List[Row] = []
    for net in (pm.QDR, pm.FDR):
        m = pm.PerfModelInputs(net=net)
        for obj in ("time", "energy", "edp"):
            d = plan(m, obj)
            out.append((f"fig17_22/{net.name}_{obj}_opt",
                        d.exec_time_s * 1e6,
                        f"deploy={d.n_pdev}x{d.tenants_per_pdev};"
                        f"energy={d.energy_ws:.0f}Ws;"
                        f"mem={d.memory_per_pdev_mb:.0f}MB"))
    return out


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (CPU wall; interpret-mode Pallas is *not* timed —
# it validates, the jnp path is what executes on CPU)
# ---------------------------------------------------------------------------
def bench_kernels() -> List[Row]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    out: List[Row] = []
    rng = np.random.default_rng(0)
    T, K, M, cat = 2048, 256, 5, 4096
    ids = jnp.asarray(rng.integers(0, cat + 1, (T, K)), jnp.int32)
    elt = jnp.asarray(np.abs(rng.normal(size=(cat + 1, M))), jnp.float32)
    occ_r = jnp.asarray(np.abs(rng.normal(size=M)), jnp.float32)
    occ_l = jnp.asarray(np.abs(rng.normal(size=M)) + 1, jnp.float32)

    f = jax.jit(lambda i: kops.aggregate_loss(i, elt, occ_r, occ_l,
                                              np.float32(1), np.float32(1e9),
                                              chunk=128))
    f(ids).block_until_ready()
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        f(ids).block_until_ready()
    us = (time.perf_counter() - t0) / n * 1e6
    ev_s = T * K * M / (us / 1e6)
    out.append(("kernels/aggregate_loss_2048x256", us,
                f"event_lookups_per_s={ev_s:.2e}"))

    b, L, H, P, N = 2, 512, 8, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))[None, None] * dt
    B = jax.random.normal(ks[3], (b, L, H, N))
    C = jax.random.normal(ks[4], (b, L, H, N))
    g = jax.jit(lambda x: kops.ssd(x, dt, a, B, C, chunk=64)[0])
    g(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        g(x).block_until_ready()
    us = (time.perf_counter() - t0) / n * 1e6
    out.append(("kernels/ssd_scan_b2_L512", us,
                f"tok_per_s={b*L/(us/1e6):.2e}"))
    return out


ALL = [bench_table1_scalability, bench_fig6_split, bench_fig8_bandwidth,
       bench_fig9_remote_scaling, bench_fig11_transfer_modes,
       bench_fig13_multitenancy, bench_fig14_energy,
       bench_fig15_16_combinations, bench_fig17_22_models, bench_kernels]
