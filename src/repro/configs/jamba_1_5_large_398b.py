"""Jamba-1.5-Large 398B [hybrid] — arXiv:2403.19887 (hf-verified).

72L, d_model=8192, 64 heads, GQA kv=8, d_ff=24576, vocab=65536.
Mamba:attention 7:1 interleave (attention at index 4 of every 8-layer period),
MoE 16 experts top-2 on every other layer.  Sub-quadratic at 512k: only the
9 attention layers carry KV.
"""
from repro.configs import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_period=8,
    attn_offset=4,
    moe_period=2,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=24576,
        num_shared_experts=0,
        capacity_factor=1.25,
        group_size=1024,
    ),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk_size=256),
    param_dtype="bfloat16",
    optimizer="adafactor",
    fsdp=True,
    microbatches=4,
    remat="full",
    subquadratic=True,
)
