"""OLMoE-1B-7B [moe] — arXiv:2409.02060 (hf-verified).

16L, d_model=2048, 16 heads (GQA kv=16 ⇒ MHA), per-expert d_ff=1024,
vocab=50304, MoE 64 experts top-8, no shared expert. ~6.9B total / 1.3B active.
"""
from repro.configs import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,                      # every MLP is routed; no dense fallback
    vocab_size=50304,
    moe_period=1,
    moe=MoEConfig(
        num_experts=64,
        top_k=8,
        d_ff_expert=1024,
        num_shared_experts=0,
        capacity_factor=1.25,
        group_size=1024,
    ),
    qk_norm=True,                # OLMoE uses QK-norm
    rope_theta=10000.0,
    fsdp=True,
    microbatches=1,
    remat="full",
)
