"""Whisper-base [audio] — arXiv:2212.04356 (unverified).

Encoder-decoder, 6+6L, d_model=512, 8 heads (MHA; pool lists GQA kv=8 = MHA at
8 heads), d_ff=2048, vocab=51865.  The conv frontend is a STUB per the harness:
``input_specs()`` provides precomputed frame embeddings for the encoder.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                # decoder layers
    num_encoder_layers=6,
    enc_dec=True,
    encoder_seq_len=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    use_rope=False,              # whisper uses absolute positions (sinusoidal stub)
    fsdp=False,
    microbatches=1,
    remat="none",
)
