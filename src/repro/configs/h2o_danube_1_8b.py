"""H2O-Danube-1.8B [dense] — arXiv:2401.16818 (hf-verified).

24L, d_model=2560, 32 heads, GQA kv=8, d_ff=6912, vocab=32000.
Llama+Mistral mix with sliding-window attention (window 4096) ⇒ sub-quadratic
cache, so the long_500k cell runs (window-bounded KV).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,                 # 2560 / 32; kept faithful (not 128-padded)
    d_ff=6912,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10000.0,
    fsdp=False,
    microbatches=1,
    remat="full",
    subquadratic=True,
)
