"""InternLM2-1.8B [dense] — arXiv:2403.17297 (hf-verified).

24L, d_model=2048, 16 heads, GQA kv=8, d_ff=8192, vocab=92544.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92544,
    rope_theta=1000000.0,
    fsdp=False,
    microbatches=1,
    remat="full",
)
