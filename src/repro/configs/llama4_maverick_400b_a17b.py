"""Llama-4 Maverick 400B-A17B [moe] — hf:meta-llama/Llama-4-Scout-17B-16E (unverified).

48L, d_model=5120, 40 query heads, GQA kv=8, dense d_ff=8192, vocab=202048,
MoE 128 experts top-1 + 1 shared expert (Maverick early-fusion design).
Active params ≈ 17B/token; total ≈ 784B with the pool's literal per-layer MoE
reading (the pool marks the 400B label unverified).
"""
from repro.configs import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe_period=1,
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff_expert=8192,
        num_shared_experts=1,
        capacity_factor=1.25,
        group_size=1024,
    ),
    rope_theta=500000.0,
    param_dtype="bfloat16",
    optimizer="adafactor",
    fsdp=True,
    microbatches=4,
    remat="full",
)
