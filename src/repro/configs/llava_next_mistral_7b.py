"""LLaVA-NeXT (Mistral-7B backbone) [vlm] — hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified).

Backbone: 32L, d_model=4096, 32 heads, GQA kv=8, d_ff=14336, vocab=32000.
The anyres-tiling vision frontend is a STUB per the harness: ``input_specs()``
provides precomputed patch embeddings (576 base-resolution patches) which are
merged into the leading positions of the token sequence.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_patches=576,
    rope_theta=1000000.0,
    fsdp=True,
    microbatches=1,
    remat="full",
)
