"""Architecture configuration system.

Every assigned architecture is a selectable config (``--arch <id>``).  A config
is a frozen dataclass consumed by ``repro.models.model.build_model``; the same
config object parameterises smoke tests (via ``.reduced()``), the multi-pod
dry-run (full shapes, ShapeDtypeStruct only) and the roofline harness.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block schedule atoms
# ---------------------------------------------------------------------------
# A model is a stack of (mixer, mlp) blocks.  ``stage`` grouping drives
# scan-over-layers: layers are grouped into ``n_stages`` identical stages and
# scanned; within a stage the (possibly heterogeneous) sublayers are unrolled.
ATTN = "attn"          # GQA attention (optionally sliding-window / qk-norm)
MAMBA = "mamba"        # Mamba-2 SSD mixer
DENSE = "dense"        # SwiGLU MLP
MOE = "moe"            # top-k routed experts
NONE = "none"          # no MLP sublayer (mamba2 blocks carry their own gating)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int            # per-expert hidden size
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    group_size: int = 1024      # routing-group size (tokens) for capacity dispatch
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    dispatch: str = "scatter"   # scatter (paper-era baseline) | ep (shard_map)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free archs)
    num_kv_heads: int
    d_ff: int                    # dense-MLP hidden size (0 if none)
    vocab_size: int
    head_dim: int = 128
    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA window (h2o-danube)
    rope_theta: float = 10000.0
    use_rope: bool = True                  # False => sinusoidal abs positions
    # --- block schedule ----------------------------------------------------
    # mixer schedule: "attn" everywhere unless overridden
    attn_period: int = 1         # hybrid: one attention layer per this many
    attn_offset: int = 0         # index within a period that is attention
    moe_period: int = 0          # 0 = no MoE; 1 = every layer; 2 = every other
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- encoder-decoder (whisper) -----------------------------------------
    enc_dec: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # precomputed frame embeddings (frontend stub)
    # --- multimodal stub ----------------------------------------------------
    num_patches: int = 0         # llava: patch embeddings prepended (stub)
    # --- numerics / distribution -------------------------------------------
    param_dtype: str = "float32"       # bf16 for the 100B+ archs
    compute_dtype: str = "bfloat16"
    fsdp: bool = False                 # shard d_model dim of big mats over data
    remat: str = "full"                # none | full | dots
    optimizer: str = "adamw"           # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    microbatches: int = 1              # tenancy: tenant chunks per train step
    logical_rules_override: Tuple[Tuple[str, Optional[str]], ...] = ()
    # --- capability flags ---------------------------------------------------
    subquadratic: bool = False   # may run long_500k
    tie_embeddings: bool = False

    # ------------------------------------------------------------------
    def mixer_kind(self, layer_idx: int) -> str:
        if self.num_heads == 0:
            return MAMBA
        if self.attn_period <= 1:
            return ATTN
        return ATTN if (layer_idx % self.attn_period) == self.attn_offset else MAMBA

    def mlp_kind(self, layer_idx: int) -> str:
        if self.d_ff == 0 and self.moe is None:
            return NONE
        if self.moe is not None and self.moe_period > 0 and (
            layer_idx % self.moe_period == self.moe_period - 1
        ):
            return MOE
        return DENSE if self.d_ff > 0 else NONE

    def block_schedule(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(
            (self.mixer_kind(i), self.mlp_kind(i)) for i in range(self.num_layers)
        )

    @property
    def stage_period(self) -> int:
        """Smallest period after which the block schedule repeats."""
        sched = self.block_schedule()
        n = len(sched)
        for p in range(1, n + 1):
            if n % p == 0 and all(sched[i] == sched[i % p] for i in range(n)):
                return p
        return n

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        period = max(self.stage_period, 1)
        n_layers = 2 * period if period <= 4 else period
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads else 0
        heads = 0 if self.num_heads == 0 else max(kv * 2, 2)
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                group_size=32,
            )
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, d_state=16, head_dim=8, chunk_size=16)
        return replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            moe=moe,
            ssm=ssm,
            sliding_window=8 if self.sliding_window else None,
            param_dtype="float32",
            compute_dtype="float32",
            fsdp=False,
            microbatches=1,
            encoder_seq_len=16,
            num_patches=4 if self.num_patches else 0,
            remat="none",
        )


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCH_IDS = (
    "llama4-maverick-400b-a17b",
    "olmoe-1b-7b",
    "internlm2-1.8b",
    "qwen3-32b",
    "mistral-large-123b",
    "h2o-danube-1.8b",
    "mamba2-2.7b",
    "llava-next-mistral-7b",
    "whisper-base",
    "jamba-1.5-large-398b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULE_FOR["risk-analysis"] = "risk_app"


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch_id]}")
    return mod.CONFIG


def cell_is_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: unbounded KV at 512k (DESIGN.md §5)"
    return True, ""
