"""Mamba2-2.7B [ssm] — arXiv:2405.21060 (unverified).

64L, d_model=2560, attention-free (SSD state-space duality), ssm_state=128,
vocab=50280.  d_inner = 2*d_model = 5120, head_dim 64 ⇒ 80 SSD heads.
"""
from repro.configs import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,                 # attention-free
    num_kv_heads=0,
    d_ff=0,                      # SSD block carries its own gating; no MLP
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk_size=256),
    fsdp=False,
    microbatches=1,
    remat="full",
    subquadratic=True,
    tie_embeddings=True,
)
