"""The paper's own workload: Aggregate Risk Analysis (Section IV).

Paper-scale inputs: YET = 1M trials x 1000 (event, timestamp) pairs (~4 GB
int32 pairs when packed), 15 ELTs covered by one layer (ELT total ~120 MB),
PF ~4 MB of financial terms.  The dry-run lowers the tenant-chunked analysis
step over the production mesh.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class RiskAppConfig:
    name: str = "risk-analysis"
    family: str = "risk"
    num_trials: int = 1_000_000
    events_per_trial: int = 1000
    num_elts: int = 15              # ELTs covered by the layer (3..30 per paper)
    event_catalog: int = 2_000_000  # direct-access table size per ELT
    num_programs: int = 1
    num_layers: int = 1
    chunk_events: int = 128         # paper's "chunking" (shared-mem → VMEM tile)
    tenants_per_device: int = 2     # vGPUs per pGPU
    transfer_mode: str = "sequential"  # sequential | concurrent
    dtype: str = "float32"

    def reduced(self) -> "RiskAppConfig":
        return RiskAppConfig(
            name="risk-analysis-reduced",
            num_trials=64,
            events_per_trial=32,
            num_elts=3,
            event_catalog=512,
            chunk_events=16,
            tenants_per_device=2,
        )


CONFIG = RiskAppConfig()

# Shape cells for the risk app (trials x tenancy), used by dryrun/roofline.
RISK_SHAPES: Tuple[Tuple[str, int, int], ...] = (
    # (name, num_trials, tenants_per_device)
    ("risk_1m_t1", 1_000_000, 1),
    ("risk_1m_t2", 1_000_000, 2),
    ("risk_1m_t4", 1_000_000, 4),
)
