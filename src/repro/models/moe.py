"""Mixture-of-Experts with capacity-based dispatch.

Baseline (paper-era faithful, GShard/Switch semantics): tokens are routed
top-k, grouped, and *scattered* into a per-group (E, C) capacity buffer; the
expert FFN runs as a dense batched GEMM over the buffer; results gather back.
Scatter/gather dispatch avoids the quadratic one-hot-einsum dispatch cost
(T x E x C x d) that the classic GShard formulation pays — the dispatch is
O(T*k*d) bytes and zero FLOPs.

Sharding: groups over ("pod","data"), experts over "model" (EP).  GSPMD turns
the group-sharded -> expert-sharded reshard into all-to-alls.

An auxiliary load-balance loss (Switch-style) and router-z loss are returned.

Two dispatch paths (EXPERIMENTS.md §Perf):
  * "scatter" — the baseline above.  Faithful GShard-with-capacity semantics,
    but the global scatter is partitioner-hostile: under GSPMD the dispatch
    buffer gets materialised per model shard and all-reduced (measured:
    ~13 TB/device/step on llama4-maverick train_4k).
  * "ep"      — beyond-paper optimised expert parallelism via shard_map:
    route locally, exchange token payloads with a single all-to-all over the
    "model" axis, run the expert GEMMs on local (E/M) experts, all-to-all
    back.  Collectives drop to O(tokens x d) per layer.
Select with MoEConfig.dispatch or env REPRO_MOE_DISPATCH.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig, MoEConfig
from repro.distributed.sharding import Sharder
from repro.models import params as pp
from repro.models.layers import dtype_of


def init_moe(key, cfg: ArchConfig) -> Dict[str, Any]:
    mc = cfg.moe
    dt = dtype_of(cfg.param_dtype)
    d, ff, E = cfg.d_model, mc.d_ff_expert, mc.num_experts
    ks = jax.random.split(key, 5)
    s_in = 0.02
    s_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    p = {
        "router": pp.normal(ks[0], (d, E), 0.02, jnp.float32, (None, None)),
        "w_gate": pp.normal(ks[1], (E, d, ff), s_in, dt, ("expert", "fsdp", None)),
        "w_up": pp.normal(ks[2], (E, d, ff), s_in, dt, ("expert", "fsdp", None)),
        "w_down": pp.normal(ks[3], (E, ff, d), s_out, dt, ("expert", None, "fsdp")),
    }
    if mc.num_shared_experts:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], cfg, d_ff=mc.d_ff_expert * mc.num_shared_experts)
    return p


def _routing(router_logits: jax.Array, mc: MoEConfig, capacity: int):
    """router_logits: (G, S, E) fp32 -> dispatch metadata.

    Returns ids (G,N), gates (G,N), pos (G,N), keep (G,N) with N = S*top_k,
    plus aux losses.
    """
    G, S, E = router_logits.shape
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, mc.top_k)            # (G,S,k)
    # renormalise the kept gates (standard for k>1)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    ids_flat = ids.reshape(G, S * mc.top_k)
    gates_flat = gates.reshape(G, S * mc.top_k)
    onehot = jax.nn.one_hot(ids_flat, E, dtype=jnp.int32)  # (G,N,E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)          # (G,N)
    keep = pos < capacity

    # Switch aux loss: E * sum_e f_e * p_e  (f = fraction dispatched, p = mean prob)
    f = jnp.mean(jax.nn.one_hot(ids[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * pmean)
    zloss = jnp.mean(jax.nn.logsumexp(router_logits, axis=-1) ** 2)
    return ids_flat, gates_flat, pos, keep, aux, zloss


def _dispatch_mode(mc: MoEConfig) -> str:
    return os.environ.get("REPRO_MOE_DISPATCH", mc.dispatch)


def apply_moe(p, x: jax.Array, cfg: ArchConfig, sh: Sharder,
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, aux_losses).  Dispatches per MoEConfig.dispatch."""
    if _dispatch_mode(cfg.moe) == "ep" and sh.mesh is not None:
        B, S = x.shape[0], x.shape[1]
        shape = dict(sh.mesh.shape)
        M = shape.get("model", 1)
        n_dp = math.prod(v for a, v in shape.items() if a in ("pod", "data"))
        if (M > 1 and B % max(n_dp, 1) == 0 and S % M == 0
                and cfg.moe.num_experts % M == 0):
            return apply_moe_ep(p, x, cfg, sh)
    return apply_moe_scatter(p, x, cfg, sh)


def apply_moe_scatter(p, x: jax.Array, cfg: ArchConfig, sh: Sharder,
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Baseline capacity dispatch (GShard semantics, global scatter)."""
    mc = cfg.moe
    cdt = dtype_of(cfg.compute_dtype)
    B, S, d = x.shape
    T = B * S
    gsz = min(mc.group_size, T)
    while T % gsz:
        gsz //= 2
    G = T // gsz
    E = mc.num_experts
    capacity = max(1, int(math.ceil(gsz * mc.top_k * mc.capacity_factor / E)))
    xg = x.reshape(G, gsz, d)
    xg = sh.constrain(xg, ("batch", None, None))

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    ids, gates, pos, keep, aux, zloss = _routing(logits, mc, capacity)
    N = gsz * mc.top_k
    # token index for each of the N=(S*k) choices (row-major (s, k))
    tok = jnp.broadcast_to((jnp.arange(N) // mc.top_k)[None, :], (G, N))

    # ---- scatter tokens into capacity buffer --------------------------------
    xe = jnp.zeros((G, E, capacity, d), cdt)
    gidx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, N))
    pos_c = jnp.where(keep, pos, capacity)                 # dropped -> clipped
    # out-of-range scatter indices are dropped by XLA scatter semantics
    xe = xe.at[gidx, ids, pos_c].add(
        jnp.take_along_axis(xg, tok[..., None], axis=1).astype(cdt),
        mode="drop")
    xe = sh.constrain(xe, ("batch", "expert", None, None))

    # ---- expert FFN (dense batched GEMM over the capacity buffer) ----------
    g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(cdt))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cdt))
    ye = sh.constrain(ye, ("batch", "expert", None, None))

    # ---- gather back & combine ---------------------------------------------
    yt = ye[gidx, ids, pos_c]                              # (G, N, d)
    yt = yt * (gates * keep).astype(cdt)[..., None]
    # sum the k choices per token
    yt = yt.reshape(G, gsz, mc.top_k, d).sum(axis=2)
    y = yt.reshape(B, S, d)

    if mc.num_shared_experts and "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, cfg, sh)

    y = sh.constrain(y, ("batch", "seq", None))
    losses = {"moe_aux": aux * mc.aux_loss_weight, "moe_z": zloss * 1e-3}
    return y, losses


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map (optimised path)
# ---------------------------------------------------------------------------
def _capacity_scatter(x, ids, n_bins: int, cap: int, valid=None):
    """Scatter rows of x (N, d) into (n_bins, cap, d) by bin id with
    positional capacity; returns (buffer, pos, keep).  Local arrays only."""
    N = ids.shape[0]
    onehot = jax.nn.one_hot(ids, n_bins, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
    keep = pos < cap
    if valid is not None:
        keep = keep & valid
    pos_c = jnp.where(keep, pos, cap)
    buf = jnp.zeros((n_bins, cap, x.shape[-1]), x.dtype)
    buf = buf.at[ids, pos_c].add(jnp.where(keep[:, None], x, 0), mode="drop")
    return buf, pos_c, keep


def apply_moe_ep(p, x: jax.Array, cfg: ArchConfig, sh: Sharder,
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """shard_map expert parallelism over the "model" mesh axis.

    Per chip: route local tokens; bucket them by destination model-shard
    (capacity cap_s); ONE all-to-all ships payloads; local capacity dispatch
    over the chip's E/M experts; expert GEMMs; all-to-all back; combine with
    local gates.  All scatters are chip-local, so GSPMD never replicates the
    dispatch buffer (the failure mode of the baseline path).
    """
    mc = cfg.moe
    mesh = sh.mesh
    cdt = dtype_of(cfg.compute_dtype)
    B, S, d = x.shape
    T = B * S
    axes = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    n_dp = math.prod(mesh.shape[a] for a in dp_axes)
    n_dev = math.prod(mesh.shape.values())
    M = mesh.shape.get("model", 1)
    E = mc.num_experts
    assert E % M == 0, (E, M)
    e_loc = E // M
    t_loc = T // n_dev
    # per-destination-shard send capacity and per-expert local capacity
    cap_s = max(1, int(math.ceil(t_loc * mc.top_k * mc.capacity_factor / M)))
    cap_e = max(1, int(math.ceil(M * cap_s / e_loc)))

    router = p["router"].astype(jnp.float32)
    w_gate, w_up, w_down = (p["w_gate"].astype(cdt), p["w_up"].astype(cdt),
                            p["w_down"].astype(cdt))

    def local(xb, wg, wu, wd):
        # xb: (B_loc, S_loc, d) native block; wg/wu: (e_loc, d, f)
        b_loc, s_loc = xb.shape[0], xb.shape[1]
        xt = xb.reshape(b_loc * s_loc, d)                     # local flatten
        logits = xt.astype(jnp.float32) @ router              # (t_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, mc.top_k)           # (t_loc, k)
        gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
        # aux losses (psum'ed below)
        f = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
        pmean = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(jax.lax.pmean(f, axes) * jax.lax.pmean(pmean, axes))
        zloss = jax.lax.pmean(
            jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2), axes)

        ids_f = ids.reshape(-1)                               # (t_loc*k,)
        xk = jnp.repeat(xt.astype(cdt), mc.top_k, axis=0)     # (t_loc*k, d)
        dest = ids_f // e_loc
        send, pos_s, keep_s = _capacity_scatter(xk, dest, M, cap_s)
        # ship the local expert id alongside (encoded, +1 so 0 = empty slot)
        eid = jnp.zeros((M, cap_s), jnp.int32).at[dest, pos_s].add(
            jnp.where(keep_s, ids_f % e_loc + 1, 0), mode="drop")

        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=False)                # (M, cap_s, d)
        recv_eid = jax.lax.all_to_all(eid, "model", split_axis=0,
                                      concat_axis=0, tiled=False)

        rx = recv.reshape(M * cap_s, d)
        re = recv_eid.reshape(M * cap_s)
        buf, pos_e, keep_e = _capacity_scatter(rx, jnp.maximum(re - 1, 0),
                                               e_loc, cap_e, valid=re > 0)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        # gather back into a2a slots, ship home, combine with gates
        y_slots = y_e[jnp.maximum(re - 1, 0), pos_e]          # (M*cap_s, d)
        y_slots = jnp.where(keep_e[:, None], y_slots, 0)
        back = jax.lax.all_to_all(y_slots.reshape(M, cap_s, d), "model",
                                  split_axis=0, concat_axis=0, tiled=False)
        y_tok = back[dest, pos_s]                             # (t_loc*k, d)
        y_tok = jnp.where(keep_s[:, None], y_tok, 0)
        y = (y_tok.reshape(b_loc * s_loc, mc.top_k, d)
             * gates[..., None].astype(cdt)).sum(axis=1)
        return y.reshape(b_loc, s_loc, d), aux, zloss

    from jax.experimental.shard_map import shard_map
    # native residual layout: batch over (pod, data), seq over model — no
    # token-flat reshard at the boundary (GSPMD falls back to
    # replicate-then-reshard on its transpose otherwise)
    blk_spec = P(dp_axes if len(dp_axes) != 1 else dp_axes[0],
                 "model" if "model" in axes else None, None)
    ew_spec = P("model", None, None) if "model" in axes else P(None, None, None)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(blk_spec, ew_spec, ew_spec, ew_spec),
                   out_specs=(blk_spec, P(), P()),
                   check_rep=False)
    # FSDP weight all-gather (if any) happens here, outside shard_map
    wg = jax.lax.with_sharding_constraint(
        w_gate, jax.NamedSharding(mesh, ew_spec))
    wu = jax.lax.with_sharding_constraint(
        w_up, jax.NamedSharding(mesh, ew_spec))
    wd = jax.lax.with_sharding_constraint(
        w_down, jax.NamedSharding(mesh, ew_spec))
    xin = jax.lax.with_sharding_constraint(
        x, jax.NamedSharding(mesh, blk_spec))
    y, aux, zloss = fn(xin, wg, wu, wd)

    if mc.num_shared_experts and "shared" in p:
        from repro.models.layers import apply_mlp
        y = y + apply_mlp(p["shared"], x, cfg, sh)
    y = sh.constrain(y, ("batch", "seq", None))
    losses = {"moe_aux": aux * mc.aux_loss_weight, "moe_z": zloss * 1e-3}
    return y, losses
