"""Attention primitives.

Two paths:

* :func:`naive_attention` — reference implementation (also the decode path,
  where the S_q=1 score tensor is tiny and GSPMD shards the KV-sequence
  reduction cleanly, including the long_500k sequence-sharded cache).
* :func:`blockwise_attention` — memory-linear flash-style attention in pure
  JAX (lax.scan over query and KV blocks, online softmax) with a custom VJP
  that recomputes per-block scores in the backward pass, so residuals are just
  (q, k, v, o, lse).  This is the HLO-level analogue of the Pallas flash
  kernel on the TPU target; it keeps 32k-prefill activation memory bounded.

Both support GQA (query heads grouped over KV heads), causal masking and
sliding windows.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def unroll_enabled() -> bool:
    """REPRO_UNROLL=1 replaces lax.scan loops with python loops so that XLA's
    HloCostAnalysis (which visits while bodies once, ignoring trip counts)
    reports exact FLOPs.  Used by the dry-run's auxiliary lowerings only."""
    return os.environ.get("REPRO_UNROLL", "0") == "1"


def _pick_block(size: int, target: int) -> int:
    """Largest divisor of ``size`` that is <= target (prefers powers of two)."""
    if size <= target:
        return size
    b = math.gcd(size, target)
    if b >= 16 or b == size:
        return b
    for cand in range(target, 0, -1):
        if size % cand == 0:
            return cand
    return size


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int],
               kv_valid: Optional[jax.Array]) -> jax.Array:
    """(q, k) additive bias: 0 where attendable, NEG_INF elsewhere."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    if kv_valid is not None:
        ok &= k_pos[None, :] < kv_valid
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def naive_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    q_offset=0, kv_valid: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D).  Returns (B,Sq,Hq,D).

    ``kv_positions`` overrides the assumed arange(Skv) absolute positions
    (used by ring/sliding-window caches).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, rep, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhrd,bkhd->bqhrk", qr, k,
                   preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = kv_positions if kv_positions is not None else jnp.arange(k.shape[1])
    bias = _mask_bias(q_pos, k_pos, causal, window, kv_valid)
    s = s + bias[None, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhrk,bkhd->bqhrd", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise flash attention with custom VJP
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_blockwise(causal: bool, window: Optional[int], block_q: int,
                    block_kv: int):
    scale_of = lambda D: 1.0 / math.sqrt(D)

    def _fwd_inner(q, k, v):
        B, Sq, Hkv, rep, D = q.shape
        Skv = k.shape[1]
        nq, nk = Sq // block_q, Skv // block_kv
        scale = scale_of(D)
        qs = jnp.moveaxis(q.reshape(B, nq, block_q, Hkv, rep, D), 1, 0)

        def per_qblock(carry, xs):
            del carry
            qi, qblk = xs
            q_pos = qi * block_q + jnp.arange(block_q)

            def kv_step(inner, j):
                m, l, acc = inner
                kj = lax.dynamic_slice_in_dim(k, j * block_kv, block_kv, 1)
                vj = lax.dynamic_slice_in_dim(v, j * block_kv, block_kv, 1)
                s = jnp.einsum("bqhrd,bkhd->bqhrk", qblk, kj,
                               preferred_element_type=jnp.float32) * scale
                k_pos = j * block_kv + jnp.arange(block_kv)
                bias = _mask_bias(q_pos, k_pos, causal, window, None)
                s = s + bias[None, :, None, None, :]
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + p.sum(axis=-1)
                pv = jnp.einsum("bqhrk,bkhd->bqhrd", p, vj,
                                preferred_element_type=jnp.float32)
                acc_new = acc * alpha[..., None] + pv
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((B, block_q, Hkv, rep), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, block_q, Hkv, rep), jnp.float32)
            a0 = jnp.zeros((B, block_q, Hkv, rep, D), jnp.float32)
            (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
            l_safe = jnp.where(l == 0.0, 1.0, l)
            o = acc / l_safe[..., None]
            lse = m + jnp.log(l_safe)
            return None, (o, lse)

        _, (o, lse) = lax.scan(per_qblock, None, (jnp.arange(nq), qs))
        # o: (nq, B, bq, Hkv, rep, D) -> (B, Sq, Hkv, rep, D)
        o = jnp.moveaxis(o, 0, 1).reshape(B, Sq, Hkv, rep, D)
        lse = jnp.moveaxis(lse, 0, 1).reshape(B, Sq, Hkv, rep)
        return o, lse

    @jax.custom_vjp
    def attn(q, k, v):
        o, _ = _fwd_inner(q, k, v)
        return o.astype(q.dtype)

    def attn_fwd(q, k, v):
        o, lse = _fwd_inner(q, k, v)
        o = o.astype(q.dtype)
        return o, (q, k, v, o, lse)

    def attn_bwd(res, do):
        q, k, v, o, lse = res
        B, Sq, Hkv, rep, D = q.shape
        Skv = k.shape[1]
        nq, nk = Sq // block_q, Skv // block_kv
        scale = scale_of(D)
        do = do.astype(jnp.float32)
        delta = jnp.sum(do * o.astype(jnp.float32), axis=-1)  # (B,Sq,Hkv,rep)
        qs = jnp.moveaxis(q.reshape(B, nq, block_q, Hkv, rep, D), 1, 0)
        dos = jnp.moveaxis(do.reshape(B, nq, block_q, Hkv, rep, D), 1, 0)
        lses = jnp.moveaxis(lse.reshape(B, nq, block_q, Hkv, rep), 1, 0)
        deltas = jnp.moveaxis(delta.reshape(B, nq, block_q, Hkv, rep), 1, 0)

        def per_qblock(carry, xs):
            dk_acc, dv_acc = carry
            qi, qblk, doblk, lseblk, dltblk = xs
            q_pos = qi * block_q + jnp.arange(block_q)

            def kv_step(dq_acc, j):
                kj = lax.dynamic_slice_in_dim(k, j * block_kv, block_kv, 1)
                vj = lax.dynamic_slice_in_dim(v, j * block_kv, block_kv, 1)
                s = jnp.einsum("bqhrd,bkhd->bqhrk", qblk, kj,
                               preferred_element_type=jnp.float32) * scale
                k_pos = j * block_kv + jnp.arange(block_kv)
                bias = _mask_bias(q_pos, k_pos, causal, window, None)
                s = s + bias[None, :, None, None, :]
                p = jnp.exp(s - lseblk[..., None])          # (B,bq,Hkv,rep,bk)
                dv_j = jnp.einsum("bqhrk,bqhrd->bkhd", p, doblk,
                                  preferred_element_type=jnp.float32)
                dp = jnp.einsum("bqhrd,bkhd->bqhrk", doblk, vj,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - dltblk[..., None]) * scale
                dq_c = jnp.einsum("bqhrk,bkhd->bqhrd", ds, kj,
                                  preferred_element_type=jnp.float32)
                dk_j = jnp.einsum("bqhrk,bqhrd->bkhd", ds, qblk,
                                  preferred_element_type=jnp.float32)
                return dq_acc + dq_c, (dk_j, dv_j)

            dq0 = jnp.zeros((B, block_q, Hkv, rep, D), jnp.float32)
            dq_blk, (dk_js, dv_js) = lax.scan(kv_step, dq0, jnp.arange(nk))
            dk_new = dk_acc + jnp.moveaxis(dk_js, 0, 1).reshape(B, Skv, Hkv, D)
            dv_new = dv_acc + jnp.moveaxis(dv_js, 0, 1).reshape(B, Skv, Hkv, D)
            return (dk_new, dv_new), dq_blk

        dk0 = jnp.zeros((B, Skv, Hkv, D), jnp.float32)
        dv0 = jnp.zeros_like(dk0)
        (dk, dv), dqs = lax.scan(
            per_qblock, (dk0, dv0), (jnp.arange(nq), qs, dos, lses, deltas))
        dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq, Hkv, rep, D)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


# ---------------------------------------------------------------------------
# Unrolled variant (python loops, causal block-skip) — exact HLO FLOP counts
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_unrolled(causal: bool, window: Optional[int], block_q: int,
                   block_kv: int):
    def _pairs(nq, nk):
        out = []
        for qi in range(nq):
            q_hi = (qi + 1) * block_q - 1
            q_lo = qi * block_q
            for j in range(nk):
                k_lo = j * block_kv
                k_hi = (j + 1) * block_kv - 1
                if causal and k_lo > q_hi:
                    continue  # fully masked (future)
                if window is not None and k_hi <= q_lo - window:
                    continue  # fully masked (outside window)
                out.append((qi, j))
        return out

    def _block(q, k, v, qi, j, scale):
        kj = lax.slice_in_dim(k, j * block_kv, (j + 1) * block_kv, axis=1)
        vj = lax.slice_in_dim(v, j * block_kv, (j + 1) * block_kv, axis=1)
        s = jnp.einsum("bqhrd,bkhd->bqhrk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        q_pos = qi * block_q + jnp.arange(block_q)
        k_pos = j * block_kv + jnp.arange(block_kv)
        bias = _mask_bias(q_pos, k_pos, causal, window, None)
        return s + bias[None, :, None, None, :], kj, vj

    def _fwd_inner(q, k, v):
        B, Sq, Hkv, rep, D = q.shape
        nq, nk = Sq // block_q, k.shape[1] // block_kv
        scale = 1.0 / math.sqrt(D)
        os_, lses = [], []
        for qi in range(nq):
            qblk = lax.slice_in_dim(q, qi * block_q, (qi + 1) * block_q, axis=1)
            m = jnp.full((B, block_q, Hkv, rep), NEG_INF, jnp.float32)
            l = jnp.zeros((B, block_q, Hkv, rep), jnp.float32)
            acc = jnp.zeros((B, block_q, Hkv, rep, D), jnp.float32)
            for j in range(nk):
                if (qi, j) not in set(_pairs(nq, nk)):
                    continue
                s, kj, vj = _block(qblk, k, v, qi, j, scale)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l = l * alpha + p.sum(axis=-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "bqhrk,bkhd->bqhrd", p, vj,
                    preferred_element_type=jnp.float32)
                m = m_new
            l_safe = jnp.where(l == 0.0, 1.0, l)
            os_.append(acc / l_safe[..., None])
            lses.append(m + jnp.log(l_safe))
        o = jnp.concatenate(os_, axis=1)
        lse = jnp.concatenate(lses, axis=1)
        return o, lse

    @jax.custom_vjp
    def attn(q, k, v):
        return _fwd_inner(q, k, v)[0].astype(q.dtype)

    def attn_fwd(q, k, v):
        o, lse = _fwd_inner(q, k, v)
        o = o.astype(q.dtype)
        return o, (q, k, v, o, lse)

    def attn_bwd(res, do):
        q, k, v, o, lse = res
        B, Sq, Hkv, rep, D = q.shape
        Skv = k.shape[1]
        nq, nk = Sq // block_q, Skv // block_kv
        scale = 1.0 / math.sqrt(D)
        do = do.astype(jnp.float32)
        delta = jnp.sum(do * o.astype(jnp.float32), axis=-1)
        dq = jnp.zeros(q.shape, jnp.float32)
        dk = jnp.zeros(k.shape, jnp.float32)
        dv = jnp.zeros(v.shape, jnp.float32)
        pairs = _pairs(nq, nk)
        for qi in range(nq):
            sl = (slice(None), slice(qi * block_q, (qi + 1) * block_q))
            qblk, doblk = q[sl], do[sl]
            lseblk, dltblk = lse[sl], delta[sl]
            dq_blk = jnp.zeros((B, block_q, Hkv, rep, D), jnp.float32)
            for j in range(nk):
                if (qi, j) not in pairs:
                    continue
                s, kj, vj = _block(qblk, k, v, qi, j, scale)
                p = jnp.exp(s - lseblk[..., None])
                dv_j = jnp.einsum("bqhrk,bqhrd->bkhd", p, doblk,
                                  preferred_element_type=jnp.float32)
                dp = jnp.einsum("bqhrd,bkhd->bqhrk", doblk, vj,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - dltblk[..., None]) * scale
                dq_blk = dq_blk + jnp.einsum(
                    "bqhrk,bkhd->bqhrd", ds, kj,
                    preferred_element_type=jnp.float32)
                dk_j = jnp.einsum("bqhrk,bqhrd->bkhd", ds, qblk,
                                  preferred_element_type=jnp.float32)
                ksl = slice(j * block_kv, (j + 1) * block_kv)
                dk = dk.at[:, ksl].add(dk_j)
                dv = dv.at[:, ksl].add(dv_j)
            dq = dq.at[sl].set(dq_blk)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 512, block_kv: int = 1024):
    """Flash-style attention.  q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    if unroll_enabled():
        bq = _pick_block(Sq, 2048)
        bk = _pick_block(k.shape[1], 2048)
        fn = _make_unrolled(causal, window, bq, bk)
    else:
        bq = _pick_block(Sq, block_q)
        bk = _pick_block(k.shape[1], block_kv)
        fn = _make_blockwise(causal, window, bq, bk)
    qr = q.reshape(B, Sq, Hkv, rep, D)
    o = fn(qr, k, v)
    return o.reshape(B, Sq, Hq, D)
