"""Parameter boxing: every parameter leaf carries logical sharding axes.

``init`` functions build trees whose leaves are :class:`Boxed` (array +
logical-axis names).  ``split`` separates the value tree from the axes tree so
the value tree is a plain jnp pytree (jit/optimizer friendly) while the axes
tree drives :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Boxed:
    value: Any
    axes: Tuple[Optional[str], ...]


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def split(tree):
    """Boxed tree -> (values, axes) trees with identical structure."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return values, axes


def normal(key, shape, scale, dtype, axes) -> Boxed:
    v = (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)
    assert len(axes) == len(shape), (axes, shape)
    return Boxed(v, tuple(axes))


def zeros(shape, dtype, axes) -> Boxed:
    assert len(axes) == len(shape), (axes, shape)
    return Boxed(jnp.zeros(shape, dtype=dtype), tuple(axes))


def ones(shape, dtype, axes) -> Boxed:
    assert len(axes) == len(shape), (axes, shape)
    return Boxed(jnp.ones(shape, dtype=dtype), tuple(axes))


def constant(value: np.ndarray, dtype, axes) -> Boxed:
    value = jnp.asarray(value, dtype=dtype)
    assert len(axes) == value.ndim, (axes, value.shape)
    return Boxed(value, tuple(axes))


def stack_layer_inits(init_fn, keys) -> Any:
    """vmap an init over a leading layer axis; prepends logical axis "layers"."""
    boxed = jax.vmap(lambda k: init_fn(k))(keys)
    # vmap maps over .value (pytree child); axes aux-data is unchanged, but the
    # arrays now carry a leading layer dim -> prepend the "layers" logical axis.
    def fix(b: Boxed) -> Boxed:
        assert b.value.ndim == len(b.axes) + 1
        return Boxed(b.value, ("layers",) + tuple(b.axes))

    return jax.tree.map(fix, boxed, is_leaf=is_boxed)


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), b.axes),
    lambda axes, children: Boxed(children[0], axes),
)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves))
