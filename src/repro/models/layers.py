"""Layer library: norms, RoPE, embeddings, GQA attention, SwiGLU MLP.

Every ``init_*`` returns a Boxed tree (value + logical sharding axes); every
``apply_*`` takes the plain value tree plus a :class:`Sharder` for activation
sharding constraints.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import Sharder
from repro.models import params as pp
from repro.models.attention_core import blockwise_attention, naive_attention


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int, dtype) -> Dict[str, pp.Boxed]:
    return {"scale": pp.ones((dim,), dtype, (None,))}


def apply_rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def apply_rmsnorm_heads(scale, x, eps: float = 1e-6):
    """Per-head qk-norm: x (..., D), scale (D,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (S,) or (B, S)."""
    D = x.shape[-1]
    inv = rope_frequencies(D, theta)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * inv[None, :]      # (S, half)
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * inv             # (B,S,half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key, cfg: ArchConfig) -> Dict[str, Any]:
    dt = dtype_of(cfg.param_dtype)
    v = pad_vocab(cfg.vocab_size)
    out = {"embedding": pp.normal(key, (v, cfg.d_model), 0.02, dt,
                                  ("vocab", "fsdp"))}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        out["unembed"] = pp.normal(k2, (cfg.d_model, v),
                                   0.02 / math.sqrt(cfg.d_model), dt,
                                   ("fsdp", "vocab"))
    return out


def apply_embedding(p, tokens: jax.Array, cfg: ArchConfig, sh: Sharder):
    emb = p["embedding"].astype(dtype_of(cfg.compute_dtype))
    x = jnp.take(emb, tokens, axis=0)
    return sh.constrain(x, ("batch", "seq", None))


def apply_unembed(p, x: jax.Array, cfg: ArchConfig, sh: Sharder):
    """Returns fp32 logits over the padded vocab with pad columns masked."""
    if cfg.tie_embeddings:
        w = p["embedding"].astype(dtype_of(cfg.compute_dtype)).T
    else:
        w = p["unembed"].astype(dtype_of(cfg.compute_dtype))
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    logits = sh.constrain(logits, ("batch", None, "vocab"))
    v_pad = w.shape[-1]
    if v_pad != cfg.vocab_size:
        col = jnp.arange(v_pad)
        logits = jnp.where(col[None, None, :] < cfg.vocab_size, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ArchConfig, cross: bool = False) -> Dict[str, Any]:
    dt = dtype_of(cfg.param_dtype)
    d, H, Hkv, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    s_in = 0.02
    s_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    p = {
        "wq": pp.normal(ks[0], (d, H * D), s_in, dt, ("fsdp", "heads")),
        "wk": pp.normal(ks[1], (d, Hkv * D), s_in, dt, ("fsdp", "kv")),
        "wv": pp.normal(ks[2], (d, Hkv * D), s_in, dt, ("fsdp", "kv")),
        "wo": pp.normal(ks[3], (H * D, d), s_out, dt, ("heads", "fsdp")),
    }
    if cfg.qk_norm:
        p["q_norm"] = pp.ones((D,), dt, (None,))
        p["k_norm"] = pp.ones((D,), dt, (None,))
    return p


def _project_qkv(p, x, x_kv, cfg: ArchConfig, sh: Sharder):
    cdt = dtype_of(cfg.compute_dtype)
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B, S = x.shape[0], x.shape[1]
    Skv = x_kv.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cdt)).reshape(B, S, H, D)
    k = jnp.einsum("bsd,dh->bsh", x_kv, p["wk"].astype(cdt)).reshape(B, Skv, Hkv, D)
    v = jnp.einsum("bsd,dh->bsh", x_kv, p["wv"].astype(cdt)).reshape(B, Skv, Hkv, D)
    q = sh.constrain(q, ("batch", None, "heads", None))
    k = sh.constrain(k, ("batch", None, "kv", None))
    v = sh.constrain(v, ("batch", None, "kv", None))
    if cfg.qk_norm:
        q = apply_rmsnorm_heads(p["q_norm"], q)
        k = apply_rmsnorm_heads(p["k_norm"], k)
    return q, k, v


def apply_attention(p, x, cfg: ArchConfig, sh: Sharder, *,
                    positions: Optional[jax.Array] = None,
                    causal: bool = True, return_kv: bool = False):
    """Full-sequence (train / prefill) self-attention."""
    q, k, v = _project_qkv(p, x, x, cfg, sh)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = blockwise_attention(q, k, v, causal=causal, window=cfg.sliding_window)
    o = o.reshape(x.shape[0], x.shape[1], -1)
    cdt = dtype_of(cfg.compute_dtype)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(cdt))
    out = sh.constrain(out, ("batch", "seq", None))
    if return_kv:
        return out, (k, v)
    return out


def apply_cross_attention(p, x, kv_cache: Tuple[jax.Array, jax.Array],
                          cfg: ArchConfig, sh: Sharder) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (no masking)."""
    cdt = dtype_of(cfg.compute_dtype)
    H, D = cfg.num_heads, cfg.head_dim
    B, S = x.shape[0], x.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cdt)).reshape(B, S, H, D)
    if cfg.qk_norm:
        q = apply_rmsnorm_heads(p["q_norm"], q)
    k, v = kv_cache
    o = naive_attention(q, k, v, causal=False)
    o = o.reshape(B, S, -1)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(cdt))


def precompute_cross_kv(p, enc_out, cfg: ArchConfig, sh: Sharder):
    cdt = dtype_of(cfg.compute_dtype)
    Hkv, D = cfg.num_kv_heads, cfg.head_dim
    B, S = enc_out.shape[0], enc_out.shape[1]
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"].astype(cdt)).reshape(B, S, Hkv, D)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"].astype(cdt)).reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        k = apply_rmsnorm_heads(p["k_norm"], k)
    return k, v


def apply_attention_decode(p, x, cache: Dict[str, jax.Array], cfg: ArchConfig,
                           sh: Sharder, cache_index: jax.Array):
    """Single-token decode with a (possibly ring) KV cache.

    cache: {"k": (B, S_c, Hkv, D), "v": ..., "pos": (B, S_c) absolute positions}
    Returns (out, new_cache).
    """
    cdt = dtype_of(cfg.compute_dtype)
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, x, cfg, sh)
    # absolute position of the new token
    pos = cache_index.astype(jnp.int32)
    if cfg.use_rope:
        q = apply_rope(q, jnp.full((B, 1), pos), cfg.rope_theta)
        k_new = apply_rope(k_new, jnp.full((B, 1), pos), cfg.rope_theta)
    s_c = cache["k"].shape[1]
    slot = jnp.mod(pos, s_c)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kpos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((B, 1), pos, cache["pos"].dtype), (0, slot))
    window = cfg.sliding_window
    # validity: positions <= pos and within window if SWA
    valid = kpos[0] <= pos
    if window is not None:
        valid &= kpos[0] > pos - window
    bias_pos = jnp.where(valid, 0.0, -1e30)
    rep = H // Hkv
    qr = q.reshape(B, 1, Hkv, rep, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhrd,bkhd->bqhrk", qr, k.astype(qr.dtype),
                   preferred_element_type=jnp.float32) * scale
    s = s + bias_pos[None, None, None, None, :]
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhrk,bkhd->bqhrd", pattn, v.astype(qr.dtype),
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H * D).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(cdt))
    new_cache = {"k": k, "v": v, "pos": kpos}
    return out, new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    s_c = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    shape = (batch, s_c, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # empty slots get a far-future position so `kpos <= pos` masks them out
        "pos": jnp.full((batch, s_c), 2 ** 30, jnp.int32),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict[str, Any]:
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s_in = 0.02
    s_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    return {
        "w_gate": pp.normal(ks[0], (d, ff), s_in, dt, ("fsdp", "ff")),
        "w_up": pp.normal(ks[1], (d, ff), s_in, dt, ("fsdp", "ff")),
        "w_down": pp.normal(ks[2], (ff, d), s_out, dt, ("ff", "fsdp")),
    }


def apply_mlp(p, x, cfg: ArchConfig, sh: Sharder):
    cdt = dtype_of(cfg.compute_dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cdt))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cdt))
    h = jax.nn.silu(g) * u
    h = sh.constrain(h, ("batch", None, "ff"))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cdt))
    return sh.constrain(out, ("batch", "seq", None))
