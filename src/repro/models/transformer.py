"""Decoder-only transformer stack with heterogeneous block schedules.

Layers are grouped into ``n_stages`` identical *stages* of ``stage_period``
sublayers (1 for uniform archs; 8 for jamba's 1-attention-per-8 interleave)
and scanned with optional remat.  The same machinery serves dense, MoE, SSM
and hybrid archs; encoder-decoder (whisper) and VLM wrappers live in
:mod:`repro.models.model`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ATTN, DENSE, MAMBA, MOE, NONE, ArchConfig
from repro.distributed.sharding import Sharder
from repro.models import params as pp
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_attention, apply_attention_decode,
                                 apply_mlp, apply_rmsnorm, dtype_of,
                                 init_attention, init_kv_cache, init_mlp,
                                 init_rmsnorm)


# ---------------------------------------------------------------------------
# Stage init
# ---------------------------------------------------------------------------
def init_stage(key, cfg: ArchConfig) -> Dict[str, Any]:
    period = cfg.stage_period
    sched = cfg.block_schedule()[:period]
    out: Dict[str, Any] = {}
    for i, (mixer, mlp) in enumerate(sched):
        k = jax.random.fold_in(key, i)
        ks = jax.random.split(k, 4)
        sub: Dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model,
                                                     dtype_of(cfg.param_dtype))}
        if mixer == ATTN:
            sub["attn"] = init_attention(ks[0], cfg)
        else:
            sub["mamba"] = ssm_mod.init_ssm(ks[0], cfg)
        if mlp != NONE:
            sub["norm2"] = init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype))
            if mlp == MOE:
                sub["moe"] = moe_mod.init_moe(ks[1], cfg)
            else:
                sub["mlp"] = init_mlp(ks[1], cfg)
        out[f"sub{i}"] = sub
    return out


def init_lm(key, cfg: ArchConfig) -> Dict[str, Any]:
    """Full decoder-only LM parameter tree (Boxed leaves)."""
    from repro.models.layers import init_embedding
    n_stages = cfg.num_layers // cfg.stage_period
    ks = jax.random.split(key, 4)
    stage_keys = jax.random.split(ks[0], n_stages)
    p = {
        "embed": init_embedding(ks[1], cfg),
        "stages": pp.stack_layer_inits(lambda k: init_stage(k, cfg), stage_keys),
        "final_norm": init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype)),
    }
    if cfg.num_patches:
        d_vis = 1024  # CLIP ViT-L/14 feature width (frontend stub)
        dt = dtype_of(cfg.param_dtype)
        p["mm_proj"] = {
            "w1": pp.normal(ks[2], (d_vis, cfg.d_model), 0.02, dt, (None, "fsdp")),
            "w2": pp.normal(ks[3], (cfg.d_model, cfg.d_model), 0.02, dt,
                            ("fsdp", None)),
        }
    return p


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def _stage_forward(stage_params, x, cfg: ArchConfig, sh: Sharder,
                   positions, collect_cache: bool):
    """One stage (period sublayers).  Returns (x, aux_scalar, caches)."""
    period = cfg.stage_period
    sched = cfg.block_schedule()[:period]
    aux = jnp.zeros((), jnp.float32)
    caches = {}
    for i, (mixer, mlp) in enumerate(sched):
        sub = stage_params[f"sub{i}"]
        h = apply_rmsnorm(sub["norm1"], x)
        if mixer == ATTN:
            if collect_cache:
                h, (k, v) = apply_attention(sub["attn"], h, cfg, sh,
                                            positions=positions, return_kv=True)
                caches[f"sub{i}"] = _kv_to_cache(k, v, positions, cfg)
            else:
                h = apply_attention(sub["attn"], h, cfg, sh, positions=positions)
        else:
            if collect_cache:
                h, st = ssm_mod.apply_ssm(sub["mamba"], h, cfg, sh,
                                          return_state=True)
                caches[f"sub{i}"] = st
            else:
                h = ssm_mod.apply_ssm(sub["mamba"], h, cfg, sh)
        x = x + h
        if mlp != NONE:
            h = apply_rmsnorm(sub["norm2"], x)
            if mlp == MOE:
                h, losses = moe_mod.apply_moe(sub["moe"], h, cfg, sh)
                aux = aux + sum(losses.values())
            else:
                h = apply_mlp(sub["mlp"], h, cfg, sh)
            x = x + h
        x = sh.constrain(x, ("batch", "seq", None))
    return x, aux, caches


def _kv_to_cache(k, v, positions, cfg: ArchConfig):
    """Turn full-sequence K/V into a decode cache (window-clipped for SWA)."""
    S = k.shape[1]
    w = cfg.sliding_window
    if w is not None and S > w:
        k, v = k[:, S - w:], v[:, S - w:]
        pos = jnp.broadcast_to(positions[S - w:][None, :], (k.shape[0], w))
    else:
        pos = jnp.broadcast_to(positions[None, :], (k.shape[0], S))
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16),
            "pos": pos.astype(jnp.int32)}


def lm_backbone(params, x, cfg: ArchConfig, sh: Sharder,
                positions: Optional[jax.Array] = None,
                collect_cache: bool = False):
    """x: (B, S, d) embedded inputs -> (hidden, aux, caches|None)."""
    if positions is None:
        positions = jnp.arange(x.shape[1])

    def body(carry, stage_params):
        h, aux = carry
        h, aux_s, caches = _stage_forward(stage_params, h, cfg, sh, positions,
                                          collect_cache)
        return (h, aux + aux_s), caches

    body_fn = body
    if cfg.remat == "full":
        body_fn = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)

    from repro.models.attention_core import unroll_enabled
    if unroll_enabled():
        n_stages = jax.tree.leaves(params["stages"])[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        cc = []
        for i in range(n_stages):
            sp = jax.tree.map(lambda a: a[i], params["stages"])
            carry, c = body_fn(carry, sp)
            cc.append(c)
        x, aux = carry
        caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *cc)
                  if collect_cache else None)
    else:
        (x, aux), caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), params["stages"])
    x = apply_rmsnorm(params["final_norm"], x)
    return x, aux, (caches if collect_cache else None)


# ---------------------------------------------------------------------------
# Decode (single token) forward
# ---------------------------------------------------------------------------
def lm_decode_backbone(params, x, caches, cache_index, cfg: ArchConfig,
                       sh: Sharder):
    """x: (B, 1, d) -> (hidden (B,1,d), new_caches)."""
    period = cfg.stage_period
    sched = cfg.block_schedule()[:period]

    def body(h, xs):
        stage_params, stage_cache = xs
        new_cache = {}
        for i, (mixer, mlp) in enumerate(sched):
            sub = stage_params[f"sub{i}"]
            hin = apply_rmsnorm(sub["norm1"], h)
            if mixer == ATTN:
                hout, nc = apply_attention_decode(sub["attn"], hin,
                                                  stage_cache[f"sub{i}"], cfg,
                                                  sh, cache_index)
            else:
                hout, nc = ssm_mod.apply_ssm_decode(sub["mamba"], hin,
                                                    stage_cache[f"sub{i}"],
                                                    cfg, sh)
            new_cache[f"sub{i}"] = nc
            h = h + hout
            if mlp != NONE:
                hin = apply_rmsnorm(sub["norm2"], h)
                if mlp == MOE:
                    hout, _ = moe_mod.apply_moe(sub["moe"], hin, cfg, sh)
                else:
                    hout = apply_mlp(sub["mlp"], hin, cfg, sh)
                h = h + hout
        return h, new_cache

    from repro.models.attention_core import unroll_enabled
    if unroll_enabled():
        n_stages = jax.tree.leaves(params["stages"])[0].shape[0]
        ncs = []
        for i in range(n_stages):
            xs_i = jax.tree.map(lambda a: a[i], (params["stages"], caches))
            x, nc = body(x, xs_i)
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
    else:
        x, new_caches = jax.lax.scan(body, x, (params["stages"], caches))
    x = apply_rmsnorm(params["final_norm"], x)
    return x, new_caches


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------
def init_lm_caches(cfg: ArchConfig, batch: int, seq_len: int):
    """Zero caches for decode: dict sub{i} -> stacked (n_stages, ...) pytrees."""
    period = cfg.stage_period
    n_stages = cfg.num_layers // period
    sched = cfg.block_schedule()[:period]
    out = {}
    for i, (mixer, _) in enumerate(sched):
        if mixer == ATTN:
            c = init_kv_cache(cfg, batch, seq_len)
        else:
            c = ssm_mod.init_ssm_state(cfg, batch)
        out[f"sub{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_stages,) + a.shape), c)
    return out


def maybe_scan(body, carry, xs):
    """lax.scan unless REPRO_UNROLL=1 (exact-cost-analysis mode: python loop)."""
    from repro.models.attention_core import unroll_enabled
    if not unroll_enabled():
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *ts: jnp.stack(ts), *ys)


ATTN_CACHE_AXES = {"k": ("layers", "batch", "kvseq", "kv", None),
                   "v": ("layers", "batch", "kvseq", "kv", None),
                   "pos": ("layers", "batch", "kvseq")}
SSM_CACHE_AXES = {"ssm": ("layers", "batch", "inner", None, None),
                  "conv": ("layers", "batch", None, "inner")}


def lm_cache_axes(cfg: ArchConfig):
    """Logical sharding axes matching init_lm_caches' structure."""
    period = cfg.stage_period
    sched = cfg.block_schedule()[:period]
    return {f"sub{i}": (ATTN_CACHE_AXES if mixer == ATTN else SSM_CACHE_AXES)
            for i, (mixer, _) in enumerate(sched)}
