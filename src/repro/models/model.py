"""Model factory: ArchConfig -> init / loss / prefill / decode callables.

This is the single public entry point the launcher, dry-run, smoke tests and
examples use:

    bundle = build_model(cfg)
    params_boxed = bundle.init(key)            # Boxed tree (values + axes)
    loss, metrics = bundle.loss_fn(values, batch, sh)
    logits, caches, idx = bundle.prefill_fn(values, batch, sh)
    logits, caches = bundle.decode_fn(values, tokens, caches, idx, sh)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, ShapeCell
from repro.distributed.sharding import Sharder
from repro.models import params as pp
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_attention, apply_attention_decode,
                                 apply_cross_attention, apply_embedding,
                                 apply_mlp, apply_rmsnorm, apply_unembed,
                                 dtype_of, init_attention, init_embedding,
                                 init_kv_cache, init_mlp, init_rmsnorm,
                                 precompute_cross_kv, sinusoidal_positions)
from repro.models.transformer import (ATTN_CACHE_AXES, init_lm, init_lm_caches,
                                      lm_backbone, lm_cache_axes,
                                      lm_decode_backbone, maybe_scan)

VIS_WIDTH = 1024  # CLIP ViT-L/14 stub feature width


@dataclasses.dataclass
class ModelBundle:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill_fn: Callable[..., Tuple[jax.Array, Any, jax.Array]]
    decode_fn: Callable[..., Tuple[jax.Array, Any]]
    init_caches: Callable[[int, int], Any]
    cache_axes: Callable[[], Any] = None  # logical axes for the cache pytree


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------
def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _sinusoid_at(pos, dim: int):
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) *
                   jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _merge_patches(params, x, patch_embeds, cfg: ArchConfig):
    cdt = dtype_of(cfg.compute_dtype)
    pe = jnp.einsum("bpv,vd->bpd", patch_embeds.astype(cdt),
                    params["mm_proj"]["w1"].astype(cdt))
    pe = jax.nn.gelu(pe)
    pe = jnp.einsum("bpd,de->bpe", pe, params["mm_proj"]["w2"].astype(cdt))
    return jax.lax.dynamic_update_slice(x, pe.astype(x.dtype), (0, 0, 0))


def _embed(params, batch, cfg: ArchConfig, sh: Sharder):
    x = apply_embedding(params["embed"], batch["tokens"], cfg, sh)
    if cfg.num_patches and "patch_embeds" in batch:
        x = _merge_patches(params, x, batch["patch_embeds"], cfg)
    if not cfg.use_rope:
        S = x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    return x


# ---------------------------------------------------------------------------
# Decoder-only family (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------
def _build_decoder_only(cfg: ArchConfig) -> ModelBundle:
    def init(key):
        return init_lm(key, cfg)

    def loss_fn(params, batch, sh: Sharder):
        x = _embed(params, batch, cfg, sh)
        h, aux, _ = lm_backbone(params, x, cfg, sh)
        logits = apply_unembed(params["embed"], h, cfg, sh)
        loss = _xent(logits, batch["labels"])
        return loss + aux, {"xent": loss, "aux": aux}

    def prefill_fn(params, batch, sh: Sharder):
        x = _embed(params, batch, cfg, sh)
        h, _, caches = lm_backbone(params, x, cfg, sh, collect_cache=True)
        logits = apply_unembed(params["embed"], h[:, -1:], cfg, sh)
        return logits[:, 0], caches, jnp.asarray(x.shape[1], jnp.int32)

    def decode_fn(params, tokens, caches, cache_index, sh: Sharder):
        x = apply_embedding(params["embed"], tokens, cfg, sh)
        if not cfg.use_rope:
            x = x + _sinusoid_at(cache_index, cfg.d_model).astype(x.dtype)[None, None]
        h, new_caches = lm_decode_backbone(params, x, caches, cache_index,
                                           cfg, sh)
        logits = apply_unembed(params["embed"], h, cfg, sh)
        return logits[:, 0], new_caches

    def init_caches(batch: int, seq_len: int):
        return init_lm_caches(cfg, batch, seq_len)

    return ModelBundle(cfg, init, loss_fn, prefill_fn, decode_fn, init_caches,
                       lambda: lm_cache_axes(cfg))


# ---------------------------------------------------------------------------
# Encoder-decoder family (whisper)
# ---------------------------------------------------------------------------
def _init_enc_stage(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    dt = dtype_of(cfg.param_dtype)
    return {
        "norm1": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(ks[0], cfg),
        "norm2": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(ks[1], cfg),
    }


def _init_dec_stage(key, cfg: ArchConfig):
    ks = jax.random.split(key, 3)
    dt = dtype_of(cfg.param_dtype)
    return {
        "norm1": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(ks[0], cfg),
        "norm_c": init_rmsnorm(cfg.d_model, dt),
        "cross": init_attention(ks[1], cfg, cross=True),
        "norm2": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(ks[2], cfg),
    }


def _build_enc_dec(cfg: ArchConfig) -> ModelBundle:
    n_enc, n_dec = cfg.num_encoder_layers, cfg.num_layers

    def init(key):
        ks = jax.random.split(key, 3)
        return {
            "embed": init_embedding(ks[0], cfg),
            "enc_stages": pp.stack_layer_inits(
                lambda k: _init_enc_stage(k, cfg), jax.random.split(ks[1], n_enc)),
            "dec_stages": pp.stack_layer_inits(
                lambda k: _init_dec_stage(k, cfg), jax.random.split(ks[2], n_dec)),
            "enc_norm": init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype)),
            "final_norm": init_rmsnorm(cfg.d_model, dtype_of(cfg.param_dtype)),
        }

    def encode(params, frames, sh: Sharder):
        x = frames.astype(dtype_of(cfg.compute_dtype))
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = sh.constrain(x, ("batch", "seq", None))

        def body(h, sp):
            a = apply_attention(sp["attn"], apply_rmsnorm(sp["norm1"], h), cfg,
                                sh, causal=False)
            h = h + a
            m = apply_mlp(sp["mlp"], apply_rmsnorm(sp["norm2"], h), cfg, sh)
            return h + m, None

        x, _ = maybe_scan(body, x, params["enc_stages"])
        return apply_rmsnorm(params["enc_norm"], x)

    def cross_kv_all(params, enc_out, sh: Sharder):
        return jax.vmap(
            lambda sp: precompute_cross_kv(sp["cross"], enc_out, cfg, sh)
        )(params["dec_stages"])

    def decode_full(params, tokens, enc_out, sh: Sharder,
                    collect_cache: bool = False):
        x = apply_embedding(params["embed"], tokens, cfg, sh)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        ckv = cross_kv_all(params, enc_out, sh)
        positions = jnp.arange(x.shape[1])

        def body(h, xs):
            sp, kv = xs
            a = apply_attention(sp["attn"], apply_rmsnorm(sp["norm1"], h), cfg,
                                sh, positions=positions,
                                return_kv=collect_cache)
            if collect_cache:
                a, (k, v) = a
            h = h + a
            c = apply_cross_attention(sp["cross"],
                                      apply_rmsnorm(sp["norm_c"], h), kv, cfg, sh)
            h = h + c
            m = apply_mlp(sp["mlp"], apply_rmsnorm(sp["norm2"], h), cfg, sh)
            h = h + m
            if collect_cache:
                pos = jnp.broadcast_to(positions[None, :],
                                       (k.shape[0], k.shape[1]))
                return h, {"k": k.astype(jnp.bfloat16),
                           "v": v.astype(jnp.bfloat16),
                           "pos": pos.astype(jnp.int32)}
            return h, None

        body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
        x, self_caches = maybe_scan(body_fn, x, (params["dec_stages"], ckv))
        x = apply_rmsnorm(params["final_norm"], x)
        return x, self_caches, ckv

    def loss_fn(params, batch, sh: Sharder):
        enc_out = encode(params, batch["frames"], sh)
        h, _, _ = decode_full(params, batch["tokens"], enc_out, sh)
        logits = apply_unembed(params["embed"], h, cfg, sh)
        loss = _xent(logits, batch["labels"])
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    def prefill_fn(params, batch, sh: Sharder):
        enc_out = encode(params, batch["frames"], sh)
        h, self_caches, ckv = decode_full(params, batch["tokens"], enc_out, sh,
                                          collect_cache=True)
        logits = apply_unembed(params["embed"], h[:, -1:], cfg, sh)
        caches = {"self": self_caches, "cross": {"k": ckv[0], "v": ckv[1]}}
        return logits[:, 0], caches, jnp.asarray(batch["tokens"].shape[1],
                                                 jnp.int32)

    def decode_fn(params, tokens, caches, cache_index, sh: Sharder):
        x = apply_embedding(params["embed"], tokens, cfg, sh)
        x = x + _sinusoid_at(cache_index, cfg.d_model).astype(x.dtype)[None, None]

        def body(h, xs):
            sp, sc, ck, cv = xs
            a, nc = apply_attention_decode(sp["attn"],
                                           apply_rmsnorm(sp["norm1"], h), sc,
                                           cfg, sh, cache_index)
            h = h + a
            c = apply_cross_attention(sp["cross"],
                                      apply_rmsnorm(sp["norm_c"], h),
                                      (ck.astype(h.dtype), cv.astype(h.dtype)),
                                      cfg, sh)
            h = h + c
            m = apply_mlp(sp["mlp"], apply_rmsnorm(sp["norm2"], h), cfg, sh)
            return h + m, nc

        x, new_self = maybe_scan(
            body, x, (params["dec_stages"], caches["self"],
                      caches["cross"]["k"], caches["cross"]["v"]))
        x = apply_rmsnorm(params["final_norm"], x)
        logits = apply_unembed(params["embed"], x, cfg, sh)
        return logits[:, 0], {"self": new_self, "cross": caches["cross"]}

    def init_caches(batch: int, seq_len: int):
        self_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_dec,) + a.shape),
            init_kv_cache(cfg, batch, seq_len))
        cross_shape = (n_dec, batch, cfg.encoder_seq_len, cfg.num_kv_heads,
                       cfg.head_dim)
        return {"self": self_c,
                "cross": {"k": jnp.zeros(cross_shape, jnp.bfloat16),
                          "v": jnp.zeros(cross_shape, jnp.bfloat16)}}

    def cache_axes():
        cross = ("layers", "batch", None, "kv", None)
        return {"self": dict(ATTN_CACHE_AXES),
                "cross": {"k": cross, "v": cross}}

    return ModelBundle(cfg, init, loss_fn, prefill_fn, decode_fn, init_caches,
                       cache_axes)


# ---------------------------------------------------------------------------
def build_model(cfg: ArchConfig) -> ModelBundle:
    if cfg.enc_dec:
        return _build_enc_dec(cfg)
    return _build_decoder_only(cfg)


# ---------------------------------------------------------------------------
# Input specs per (arch x shape) cell — ShapeDtypeStructs, no allocation
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token; caches are built separately
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.num_patches and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, VIS_WIDTH), bf16)
    if cfg.enc_dec and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), bf16)
    return specs
