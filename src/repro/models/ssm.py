"""Mamba-2 (SSD) mixer block — arXiv:2405.21060.

Block: in_proj -> [z | xBC | dt]; causal depthwise conv + SiLU on xBC;
SSD scan over (x, B, C) with per-head decay A*dt; +D skip; gated RMSNorm
(y * silu(z)); out_proj.  Decode keeps (ssm_state, conv_state).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.distributed.sharding import Sharder
from repro.kernels import ops as kops
from repro.models import params as pp
from repro.models.layers import dtype_of


def ssm_dims(cfg: ArchConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    n_heads = d_inner // sc.head_dim
    conv_dim = d_inner + 2 * sc.n_groups * sc.d_state
    d_in_proj = 2 * d_inner + 2 * sc.n_groups * sc.d_state + n_heads
    return d_inner, n_heads, conv_dim, d_in_proj


def init_ssm(key, cfg: ArchConfig) -> Dict[str, Any]:
    sc = cfg.ssm
    dt = dtype_of(cfg.param_dtype)
    d_inner, H, conv_dim, d_in_proj = ssm_dims(cfg)
    ks = jax.random.split(key, 6)
    s_in = 0.02
    s_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    lo, hi = cfg.ssm.a_init_range
    a_init = jnp.log(lo + (hi - lo) * jax.random.uniform(ks[2], (H,)))
    # dt bias: softplus^-1 of dt sampled log-uniform in [dt_min, dt_max]
    dts = jnp.exp(jax.random.uniform(ks[3], (H,)) *
                  (np.log(sc.dt_max) - np.log(sc.dt_min)) + np.log(sc.dt_min))
    dt_bias = dts + jnp.log(-jnp.expm1(-dts))
    return {
        "in_proj": pp.normal(ks[0], (cfg.d_model, d_in_proj), s_in, dt,
                             ("fsdp", "inner")),
        "conv_w": pp.normal(ks[1], (sc.d_conv, conv_dim), 0.2, dt,
                            (None, "inner")),
        "conv_b": pp.zeros((conv_dim,), dt, ("inner",)),
        "a_log": pp.constant(a_init, jnp.float32, ("inner",)),
        "dt_bias": pp.constant(dt_bias, jnp.float32, ("inner",)),
        "d_skip": pp.ones((H,), jnp.float32, ("inner",)),
        "norm_scale": pp.ones((d_inner,), dt, ("inner",)),
        "out_proj": pp.normal(ks[4], (d_inner, cfg.d_model), s_out, dt,
                              ("inner", "fsdp")),
    }


def _causal_conv(x, w, b):
    """x: (B, L, C); w: (W, C); depthwise causal conv + SiLU."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(y + b[None, None, :])


def _split_proj(zxbcdt, cfg: ArchConfig):
    sc = cfg.ssm
    d_inner, H, conv_dim, _ = ssm_dims(cfg)
    gn = sc.n_groups * sc.d_state
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt_raw


def _expand_groups(t, H: int, n_groups: int):
    """(B, L, G*N) -> (B, L, H, N) by repeating each group over its heads."""
    B, L = t.shape[0], t.shape[1]
    N = t.shape[-1] // n_groups
    t = t.reshape(B, L, n_groups, N)
    rep = H // n_groups
    return jnp.repeat(t, rep, axis=2)


def apply_ssm(p, x, cfg: ArchConfig, sh: Sharder, *, return_state: bool = False):
    """Full-sequence SSD mixer.  x: (B, L, d_model)."""
    sc = cfg.ssm
    cdt = dtype_of(cfg.compute_dtype)
    d_inner, H, conv_dim, _ = ssm_dims(cfg)
    B_, L, _ = x.shape
    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(cdt))
    zxbcdt = sh.constrain(zxbcdt, ("batch", None, "inner"))
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc_raw = xbc
    xbc = _causal_conv(xbc, p["conv_w"].astype(cdt), p["conv_b"].astype(cdt))
    xs = xbc[..., :d_inner]
    gn = sc.n_groups * sc.d_state
    Bm = _expand_groups(xbc[..., d_inner:d_inner + gn], H, sc.n_groups)
    Cm = _expand_groups(xbc[..., d_inner + gn:], H, sc.n_groups)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"][None, None, :])          # (B,L,H)
    A = -jnp.exp(p["a_log"])                                    # (H,)
    a = A[None, None, :] * dt
    xh = xs.reshape(B_, L, H, sc.head_dim)
    xh = sh.constrain(xh, ("batch", None, "inner", None))
    y, h_final = kops.ssd(xh, dt, a, Bm, Cm, chunk=min(sc.chunk_size, L))
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, L, d_inner).astype(cdt)
    # gated RMSNorm
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + 1e-6) *
         p["norm_scale"].astype(jnp.float32)).astype(cdt)
    out = jnp.einsum("blk,kd->bld", g, p["out_proj"].astype(cdt))
    out = sh.constrain(out, ("batch", "seq", None))
    if return_state:
        W = sc.d_conv
        conv_state = xbc_raw[:, L - (W - 1):, :].astype(jnp.float32)
        return out, {"ssm": h_final, "conv": conv_state}
    return out


def init_ssm_state(cfg: ArchConfig, batch: int) -> Dict[str, jnp.ndarray]:
    sc = cfg.ssm
    d_inner, H, conv_dim, _ = ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, H, sc.head_dim, sc.d_state), jnp.float32),
        "conv": jnp.zeros((batch, sc.d_conv - 1, conv_dim), jnp.float32),
    }


def checkpoint_slot_state(state, slot: int) -> Dict[str, np.ndarray]:
    """Snapshot one slot's SSM decode state as fixed-width host records.

    ``state`` is a stage-stacked decode-state pytree (leaves lead with
    ``(n_stages, capacity, ...)``); the returned numpy tree drops the
    capacity axis, so its shapes depend only on the arch — the restore jit
    traces once whatever slot a record came from or goes back to.  Reading
    a quiesced slot row is bitwise, so checkpoint -> restore round-trips
    exactly (the swap-preemption contract for SSM/hybrid rows)."""
    return jax.tree.map(lambda t: np.asarray(t[:, slot]), state)


def restore_slot_state(state, slot, record):
    """Scatter a :func:`checkpoint_slot_state` record back into ``slot``'s
    row of a stage-stacked decode-state pytree (jit-safe; ``slot`` may be a
    tracer).  Other rows are untouched."""
    return jax.tree.map(lambda t, v: t.at[:, slot].set(v), state, record)


def apply_ssm_decode(p, x, state: Dict[str, jnp.ndarray], cfg: ArchConfig,
                     sh: Sharder) -> Tuple[jax.Array, Dict[str, jnp.ndarray]]:
    """One-token decode.  x: (B, 1, d_model)."""
    sc = cfg.ssm
    cdt = dtype_of(cfg.compute_dtype)
    d_inner, H, conv_dim, _ = ssm_dims(cfg)
    B_ = x.shape[0]
    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(cdt))
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc_t = xbc[:, 0]                                          # (B, conv_dim)
    # rolling causal conv
    W = sc.d_conv
    conv_in = jnp.concatenate([state["conv"].astype(cdt),
                               xbc_t[:, None, :]], axis=1)     # (B, W, C)
    w = p["conv_w"].astype(cdt)
    y_conv = jnp.einsum("bwc,wc->bc", conv_in, w) + p["conv_b"].astype(cdt)
    xbc_t = jax.nn.silu(y_conv)
    new_conv = conv_in[:, 1:, :].astype(state["conv"].dtype)

    xs = xbc_t[..., :d_inner]
    gn = sc.n_groups * sc.d_state
    Bm = _expand_groups(xbc_t[:, None, d_inner:d_inner + gn], H, sc.n_groups)[:, 0]
    Cm = _expand_groups(xbc_t[:, None, d_inner + gn:], H, sc.n_groups)[:, 0]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["a_log"])
    a = A[None, :] * dt
    xh = xs.reshape(B_, H, sc.head_dim)
    y, new_ssm = kops.ssd_decode_step(state["ssm"], xh, dt, a, Bm, Cm)
    y = y + p["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, 1, d_inner).astype(cdt)
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    g = (gf * jax.lax.rsqrt(var + 1e-6) *
         p["norm_scale"].astype(jnp.float32)).astype(cdt)
    out = jnp.einsum("blk,kd->bld", g, p["out_proj"].astype(cdt))
    return out, {"ssm": new_ssm, "conv": new_conv}
