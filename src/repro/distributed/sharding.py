"""Logical-axis sharding rules → GSPMD shardings.

Model code annotates parameters and activations with *logical* axis names;
this module resolves them against whatever mesh is active (single CPU device,
the 256-chip pod, or the 2×16×16 two-pod mesh).  Resolution silently drops an
axis when the dimension is not divisible by the mesh-axis extent (e.g. 40
query heads on a 16-way "model" axis, or 8 KV heads) — the tensor is then
replicated along that mesh axis, which is always correct, and the roofline
harness reports the resulting collective traffic.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs.telemetry import get_telemetry

# logical name -> candidate mesh axes (in priority order; tuples mean "use all
# that exist, jointly")
DEFAULT_RULES = {
    None: None,
    "replicated": None,
    "layers": None,
    "batch": ("pod", "data"),          # data parallel axis (both pods)
    "seq": ("model",),                 # Megatron-SP sequence sharding
    "vocab": ("model",),
    "heads": ("model",),               # flattened (H*dh) or head axis
    "kv": ("model",),
    "ff": ("model",),
    "expert": ("model",),
    "inner": ("model",),               # mamba d_inner / ssd heads
    "fsdp": ("pod", "data"),           # ZeRO-3 style weight shard (big archs)
    "embed": None,                     # d_model of activations
    # decode KV-cache sequence axis: split-K over "model" (flash-decoding
    # analogue); falls through to "data" when batch=1 frees it (long_500k)
    "kvseq": ("model", "data"),
}


class Sharder:
    """Resolves logical axis tuples to NamedShardings for one mesh.

    ``fsdp=False`` maps the "fsdp" logical axis to None (weights replicated
    across data);  ``seq_shard=False`` disables activation sequence sharding.
    """

    def __init__(self, mesh: Optional[Mesh], *, fsdp: bool = False,
                 seq_shard: bool = False, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(rules or DEFAULT_RULES)
        if not fsdp:
            self.rules["fsdp"] = None
        if not seq_shard:
            self.rules["seq"] = None

    # ------------------------------------------------------------------
    def _axes_for(self, logical: Optional[str], dim: int,
                  used: frozenset = frozenset()) -> Optional[Tuple[str, ...]]:
        if self.mesh is None or logical is None:
            return None
        cand = self.rules.get(logical, None)
        if cand is None:
            return None
        if isinstance(cand, str):
            cand = (cand,)
        axes = tuple(a for a in cand
                     if a in self.mesh.axis_names and a not in used)
        if not axes:
            return None
        extent = math.prod(self.mesh.shape[a] for a in axes)
        if dim % extent != 0:
            # try progressively smaller suffixes (e.g. drop "pod", keep "data")
            for i in range(1, len(axes)):
                sub = axes[i:]
                if dim % math.prod(self.mesh.shape[a] for a in sub) == 0:
                    return sub
            return None
        return axes

    def spec(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> P:
        assert len(logical) == len(shape), (logical, shape)
        used: set = set()
        parts = []
        for name, dim in zip(logical, shape):
            axes = self._axes_for(name, dim, frozenset(used))
            if axes is None:
                parts.append(None)
                continue
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def named(self, logical: Sequence[Optional[str]], shape: Sequence[int]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def constrain(self, x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
        """with_sharding_constraint if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical, x.shape))
        )


    def extent(self, logical: Optional[str], dim: int) -> int:
        """Number of shards the rules would split a ``dim``-sized axis into."""
        axes = self._axes_for(logical, dim)
        if axes is None:
            return 1
        return math.prod(self.mesh.shape[a] for a in axes)

    def place(self, x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
        """device_put onto the mesh with the resolved sharding (identity off-mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(logical, x.shape)
        tel = get_telemetry(None)
        if tel.enabled:
            tel.count("shard.placements")
            tel.count("shard.placed_bytes", getattr(x, "nbytes", 0))
            tel.event("shard.place", spec=str(spec),
                      shape=tuple(int(d) for d in x.shape),
                      mesh=dict(self.mesh.shape))
        return jax.device_put(x, NamedSharding(self.mesh, spec))


def null_sharder() -> Sharder:
    return Sharder(None)


# Serving shards only head-like axes.  Everything else stays replicated so the
# only cross-shard merges are all-gathers (pure data movement) — never a psum
# whose float reassociation would break the bitwise token-exactness contract
# with the single-device engine.
SERVING_RULES = {
    "heads": ("model",),
    "kv": ("model",),
}


def parse_mesh(spec: Optional[str]) -> Optional[Mesh]:
    """Build a mesh from an ``AxB`` spec string ("1x8", "2x4", "1x1").

    Two extents map to ("data", "model"); three to ("pod", "data", "model");
    a bare integer to a 1×N ("data", "model") mesh.  ``None``/empty returns
    None (single-device path, no mesh).
    """
    if not spec:
        return None
    extents = tuple(int(p) for p in str(spec).lower().split("x"))
    names = {1: ("data", "model"), 2: ("data", "model"),
             3: ("pod", "data", "model")}[len(extents)]
    if len(extents) == 1:
        extents = (1,) + extents
    n_dev = math.prod(extents)
    if n_dev > len(jax.devices()):
        raise ValueError(
            f"mesh {spec} needs {n_dev} devices, have {len(jax.devices())}")
    return jax.make_mesh(extents, names)


def serving_sharder(mesh: Optional[Mesh]) -> Sharder:
    """Sharder for the serving stack: KV-head partitioning only."""
    return Sharder(mesh, rules=SERVING_RULES)


def param_shardings(sharder: Sharder, axes_tree, shapes_tree):
    """axes tree + eval_shape tree -> tree of NamedSharding (or None)."""
    return jax.tree.map(
        lambda axes, shp: sharder.named(axes, shp.shape),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )
