"""Fault tolerance & straggler mitigation for long-running training.

At thousand-node scale the dominant events are (a) device/host loss,
(b) stragglers, (c) data-feed stalls.  This module provides the control-plane
pieces; the data plane (checkpoint resharding, tenant re-staging order) lives
in distributed/checkpoint.py and core/transfer.py.

* HeartbeatMonitor — wall-clock watchdog around the step loop; a step
  exceeding ``timeout_s`` marks the worker suspect (on a real cluster this
  feeds the coordinator; here it triggers restart-from-checkpoint).  The
  serving scheduler (:mod:`repro.serving.multitenant`) beats it once per
  collected decode round, so a wedged round surfaces as a suspect count
  instead of a silent hang.
* StragglerDetector — per-tenant EWMA of step times; tenants slower than
  ``z_threshold`` sigma are flagged and re-ordered first in the next staging
  plan (paper's sequential staging makes order a free knob).
* FaultPlane / InjectedFault — deterministic fault injector for the serving
  overload tests and the trace-driven load harness: drop a decode round,
  stall an admission batch, or poison a swap-store read, each on a fixed
  every-k counter (no randomness — the same trace always injects the same
  faults).  Injection *raises* before any engine state mutates, so the
  caller's retry/limit policy decides whether the request survives
  (retried) or lands in a terminal state — the engine itself never crashes.
* run_with_recovery — supervised step loop: on failure, restore the latest
  checkpoint (possibly onto a smaller elastic mesh) and continue; gives up
  after ``max_failures``.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional

from repro.distributed import checkpoint as ckpt
from repro.obs.telemetry import get_telemetry


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 300.0
    last_beat: float = dataclasses.field(default_factory=time.monotonic)
    missed: int = 0

    def beat(self) -> None:
        self.last_beat = time.monotonic()

    def suspect(self) -> bool:
        """One watchdog verdict; True marks the worker suspect.  Verdicts
        are mirrored onto the telemetry plane (``heartbeat.verdicts`` /
        ``heartbeat.suspect`` — the serving scheduler additionally keeps
        the ``heartbeat.suspects`` gauge)."""
        tel = get_telemetry(None)
        tel.count("heartbeat.verdicts")
        if time.monotonic() - self.last_beat > self.timeout_s:
            self.missed += 1
            if tel.enabled:
                tel.event("heartbeat.suspect", missed=self.missed)
            return True
        return False


class InjectedFault(RuntimeError):
    """A fault raised by :class:`FaultPlane`.  Always transient from the
    injector's point of view — whether it becomes terminal is the caller's
    retry/limit policy, never the engine's."""


@dataclasses.dataclass
class FaultPlane:
    """Deterministic every-k fault injection for the serving stack.

    Each knob is a period: ``0`` disables that fault, ``k`` fires it on
    every k-th event of its kind (events counted from 1, so ``k=3`` fires
    on the 3rd, 6th, ... event).  The three planes map onto the serving
    engine's three state-mutation sites, and every injection raises
    *before* the mutation it guards:

    * ``drop_round_every`` — :meth:`round_fault` raises at the top of
      ``dispatch_round`` (before the copy-on-write scan), so a dropped
      round leaves the slot table exactly as it was and a bare re-dispatch
      is sound;
    * ``stall_admission_every`` — :meth:`admission_fault` raises at the top
      of ``try_admit_batch`` (before any prefill or page allocation), so a
      stalled admission batch simply stays queued;
    * ``poison_swap_every`` — :meth:`swap_read_fault` raises inside the
      swap store's read path, before the staged copy is handed to the
      restore jit; the host-side record is untouched, so a retry re-reads
      the intact copy.

    **Crash injection** (exact-once, not every-k): ``crash_at_round=k``
    SIGKILLs the process at the k-th dispatched round, ``crash_at_swap=k``
    at the k-th swap-store put (mid-preemption).  Unlike the transient
    faults above these never raise — ``os.kill(pid, SIGKILL)`` gives the
    process no chance to flush, unwind or atexit, which is exactly the
    failure the crash-recovery subsystem (``serving/journal.py`` +
    engine checkpoints) must survive: the subprocess kill-and-restart
    harness drives them at deterministic points and asserts token-exact
    recovery.  Counters are process-local, so the restarted process
    starts at zero and does not re-crash.
    """
    drop_round_every: int = 0
    stall_admission_every: int = 0
    poison_swap_every: int = 0
    crash_at_round: int = 0
    crash_at_swap: int = 0
    rounds: int = 0
    admissions: int = 0
    swap_reads: int = 0
    swap_puts: int = 0
    injected: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"round": 0, "admission": 0, "swap": 0})

    def _fire(self, every: int, count: int) -> bool:
        return every > 0 and count % every == 0

    def _record(self, kind: str) -> None:
        tel = get_telemetry(None)
        if tel.enabled:
            tel.count(f"fault.{kind}")
            tel.event("fault.injected", kind=kind,
                      n=self.injected[kind])

    def _maybe_crash(self, at: int, count: int) -> None:
        if at > 0 and count == at:
            import os
            import signal
            os.kill(os.getpid(), signal.SIGKILL)   # no unwind, no flush

    def round_fault(self) -> None:
        self.rounds += 1
        self._maybe_crash(self.crash_at_round, self.rounds)
        if self._fire(self.drop_round_every, self.rounds):
            self.injected["round"] += 1
            self._record("round")
            raise InjectedFault("injected fault: decode round dropped")

    def admission_fault(self) -> None:
        self.admissions += 1
        if self._fire(self.stall_admission_every, self.admissions):
            self.injected["admission"] += 1
            self._record("admission")
            raise InjectedFault("injected fault: admission stalled")

    def swap_read_fault(self) -> None:
        self.swap_reads += 1
        if self._fire(self.poison_swap_every, self.swap_reads):
            self.injected["swap"] += 1
            self._record("swap")
            raise InjectedFault("injected fault: swap read poisoned")

    def swap_put_crash(self) -> None:
        """Mid-preemption crash point (called from the swap store's put):
        SIGKILL between the victim's host gather and its journal/ledger
        bookkeeping — never raises, never returns when it fires."""
        self.swap_puts += 1
        self._maybe_crash(self.crash_at_swap, self.swap_puts)

    def total_injected(self) -> int:
        return sum(self.injected.values())


class StragglerDetector:
    """EWMA + variance tracking of per-tenant step times (DESIGN.md §7)."""

    def __init__(self, alpha: float = 0.2, z_threshold: float = 3.0):
        self.alpha = alpha
        self.z = z_threshold
        self.mean: Dict[int, float] = {}
        self.var: Dict[int, float] = {}

    def update(self, times: Dict[int, float]) -> List[int]:
        """Feed per-tenant step times; returns currently-flagged stragglers."""
        flagged = []
        for k, t in times.items():
            m = self.mean.get(k, t)
            v = self.var.get(k, 0.0)
            d = t - m
            m += self.alpha * d
            v = (1 - self.alpha) * (v + self.alpha * d * d)
            self.mean[k], self.var[k] = m, v
        pop = list(self.mean.values())
        if len(pop) >= 2:
            mu = sum(pop) / len(pop)
            sd = math.sqrt(sum((x - mu) ** 2 for x in pop) / len(pop)) or 1e-9
            flagged = [k for k, m in self.mean.items()
                       if (m - mu) / sd > self.z]
        return flagged

    def staging_priority(self) -> Dict[int, float]:
        """For core.transfer.reorder_for_stragglers: slowest staged first."""
        return dict(self.mean)


@dataclasses.dataclass
class RecoveryReport:
    steps_done: int
    failures: int
    restarts: List[int]


def run_with_recovery(step_fn: Callable[[Any, int], Any], state: Any,
                      num_steps: int, ckpt_dir,
                      save_every: int = 50, max_failures: int = 3,
                      state_template: Optional[Any] = None,
                      shardings: Optional[Any] = None,
                      monitor: Optional[HeartbeatMonitor] = None,
                      ) -> RecoveryReport:
    """Supervised loop: step_fn(state, i) -> state; checkpoint + restart."""
    template = state_template if state_template is not None else state
    failures = 0
    restarts: List[int] = []
    start = ckpt.latest_step(ckpt_dir)
    i = 0
    if start is not None:
        state = ckpt.restore(ckpt_dir, start, template, shardings)
        i = start
    while i < num_steps:
        try:
            state = step_fn(state, i)
            if monitor is not None:
                monitor.beat()
            i += 1
            if i % save_every == 0 or i == num_steps:
                ckpt.save(ckpt_dir, i, state)
        except Exception:
            failures += 1
            if failures > max_failures:
                raise
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                raise
            restarts.append(i)
            state = ckpt.restore(ckpt_dir, last, template, shardings)
            i = last
    return RecoveryReport(i, failures, restarts)
