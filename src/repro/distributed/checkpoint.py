"""Checkpointing: sharded training trees + serving engine snapshots.

This module owns every on-disk checkpoint format in the repo.

**Training trees** (the original format):

Layout:  <dir>/step_<N>/
             manifest.json        — tree structure, shapes, dtypes
             leaf_<i>.npy         — one file per leaf (host-gathered)
         <dir>/step_<N>.done      — atomic commit marker

Restore is *resharding-aware*: arrays are loaded on host and device_put with
whatever shardings the (possibly different) target mesh dictates — save on
512 chips, restore on 256 (pod loss) or on 1 CPU device (tests).  Writes are
atomic (marker written last), partial checkpoints are ignored, and
``keep_last`` garbage-collects old steps.

**Engine checkpoints** (crash-safe serving — the data plane to
``serving/journal.py``'s write-ahead control plane):

Layout:  <dir>/engine_<N>/
             engine.json          — json meta (slot records' scalars,
                                    chain keys, serialized scheduler
                                    queue state, array name index)
             arr_<i>.npy          — one file per named numpy array
                                    (SwapRecord page blocks / position
                                    rows / PRNG keys / SSM records)
         <dir>/engine_<N>.done    — atomic commit marker

The same atomicity discipline applies: the ``.done`` marker is written
last, so a SIGKILL mid-save leaves either the previous checkpoint intact
or both — never a half-written latest.  ``load_engine_checkpoint`` only
ever reads committed steps; recovery therefore always has a consistent
(journal, checkpoint) pair to rebuild from.  Arrays are stored unsharded
(host-gathered); the restore path re-commits them to whatever mesh the
recovering engine runs, through the ordinary swap-in staging lanes — a
1x8 crash can recover on 1x1 and vice versa.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory, step: int, tree, keep_last: Optional[int] = 3) -> pathlib.Path:
    """Host-gather every leaf and write one .npy per leaf, atomically."""
    directory = pathlib.Path(directory)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    marker = directory / f"step_{step}.done"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest: Dict[str, Any] = {"step": step, "num_leaves": len(leaves),
                                "treedef": str(treedef),
                                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    marker.write_text(str(step))          # commit marker last => atomic
    if keep_last:
        gc_old(directory, keep_last)
    return final


def save_async(directory, step: int, tree, keep_last: Optional[int] = 3,
               ) -> threading.Thread:
    """Snapshot to host synchronously, write to disk in a thread (training
    continues while the file I/O drains)."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    snap = jax.tree.unflatten(treedef, host)
    t = threading.Thread(target=save, args=(directory, step, snap, keep_last),
                         daemon=True)
    t.start()
    return t


def available_steps(directory) -> List[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    steps = []
    for m in directory.glob("step_*.done"):
        try:
            s = int(m.stem.split("_")[1])
        except (IndexError, ValueError):
            continue
        if (directory / f"step_{s}" / "manifest.json").exists():
            steps.append(s)
    return sorted(steps)


def latest_step(directory) -> Optional[int]:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory, step: int, target_tree,
            shardings: Optional[Any] = None):
    """Load leaves and place them per ``shardings`` (tree of NamedSharding or
    None).  ``target_tree`` provides the pytree structure (values ignored)."""
    directory = pathlib.Path(directory) / f"step_{step}"
    manifest = json.loads((directory / "manifest.json").read_text())
    leaves, treedef = _flatten(target_tree)
    assert manifest["num_leaves"] == len(leaves), \
        f"leaf count mismatch: ckpt {manifest['num_leaves']} vs {len(leaves)}"
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(directory / f"leaf_{i}.npy")
        want = tuple(getattr(ref, "shape", arr.shape))
        assert tuple(arr.shape) == want, \
            f"leaf {i}: ckpt shape {arr.shape} != target {want}"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype
                                         if hasattr(ref, "dtype") else None))
    return jax.tree.unflatten(treedef, out)


def gc_old(directory, keep_last: int) -> None:
    steps = available_steps(directory)
    directory = pathlib.Path(directory)
    for s in steps[:-keep_last]:
        shutil.rmtree(directory / f"step_{s}", ignore_errors=True)
        (directory / f"step_{s}.done").unlink(missing_ok=True)


# ----------------------------------------------------------------------
# Engine checkpoints (crash-safe serving)
# ----------------------------------------------------------------------
def save_engine_checkpoint(directory, step: int, meta: Dict[str, Any],
                           arrays: Dict[str, np.ndarray],
                           keep_last: Optional[int] = 3) -> pathlib.Path:
    """Atomically write one serving-engine checkpoint: json-able ``meta``
    plus a flat dict of named numpy ``arrays`` (names are free-form, e.g.
    ``live/0/kv/layers.0.attn/k``); the name->file index rides the meta."""
    directory = pathlib.Path(directory)
    tmp = directory / f"engine_{step}.tmp"
    final = directory / f"engine_{step}"
    marker = directory / f"engine_{step}.done"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names = sorted(arrays)
    dtypes = {}
    for i, name in enumerate(names):
        arr = np.ascontiguousarray(arrays[name])
        dtypes[name] = str(arr.dtype)
        if arr.dtype.kind not in "biufc":
            # extension dtypes (bfloat16 via ml_dtypes) round-trip through
            # np.save as raw void bytes — store the uint8 view and re-view
            # on load from the recorded dtype string
            arr = arr.view(np.uint8)
        np.save(tmp / f"arr_{i}.npy", arr)
    doc = {"step": step, "version": 1, "array_names": names,
           "array_dtypes": dtypes, "meta": meta}
    (tmp / "engine.json").write_text(json.dumps(doc))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    marker.write_text(str(step))          # commit marker last => atomic
    if keep_last:
        for s in engine_checkpoint_steps(directory)[:-keep_last]:
            shutil.rmtree(directory / f"engine_{s}", ignore_errors=True)
            (directory / f"engine_{s}.done").unlink(missing_ok=True)
    return final


def engine_checkpoint_steps(directory) -> List[int]:
    """Committed (``.done``-marked, manifest present) engine steps."""
    directory = pathlib.Path(directory)
    if not directory.exists():
        return []
    steps = []
    for m in directory.glob("engine_*.done"):
        try:
            s = int(m.stem.split("_")[1])
        except (IndexError, ValueError):
            continue
        if (directory / f"engine_{s}" / "engine.json").exists():
            steps.append(s)
    return sorted(steps)


def latest_engine_step(directory) -> Optional[int]:
    steps = engine_checkpoint_steps(directory)
    return steps[-1] if steps else None


def load_engine_checkpoint(directory, step: Optional[int] = None):
    """Load a committed engine checkpoint; ``step=None`` means latest.
    Returns ``(meta, arrays)`` — the inverse of
    :func:`save_engine_checkpoint` — or ``(None, None)`` when the
    directory holds no committed step."""
    if step is None:
        step = latest_engine_step(directory)
        if step is None:
            return None, None
    directory = pathlib.Path(directory) / f"engine_{step}"
    doc = json.loads((directory / "engine.json").read_text())
    dtypes = doc.get("array_dtypes", {})
    arrays = {}
    for i, name in enumerate(doc["array_names"]):
        arr = np.load(directory / f"arr_{i}.npy")
        want = dtypes.get(name, str(arr.dtype))
        if str(arr.dtype) != want:       # stored as a raw uint8 view
            arr = arr.view(np.dtype(want))
        arrays[name] = arr
    return doc["meta"], arrays
