"""Paged KV-cache for continuous batching (vLLM-style, JAX-functional).

The slot-based serving paths keep one dense KV cache per padded batch; a
batch's cache lives and dies with its dispatch, so short requests pay for the
longest row and the device idles while a finished batch's tail rows pad out.
:class:`PagedKVCache` breaks the cache into fixed-size *pages* drawn from a
shared pool so a persistent slot table (see :mod:`repro.serving.continuous`)
can admit and retire requests independently:

* **fixed-size pages** — the K/V pool per attention sublayer is
  ``(n_stages, num_pages, page_size, Hkv, D)``; a shared position pool
  ``(num_pages, page_size)`` carries the absolute token position of every
  cache entry (the validity source for the attention mask, exactly like the
  dense cache's ``pos`` plane).
* **per-sequence page tables** — ``(capacity, max_blocks)`` int32 mapping a
  slot's logical cache blocks to physical pages.  Unused blocks point at the
  reserved ``SENTINEL`` page whose positions stay at ``POS_SENTINEL`` so
  gathered padding is always masked out.
* **free-list allocation / eviction** — a host-side LIFO free list; admission
  takes ``blocks_for(ring_len)`` pages, retirement returns them.  LIFO makes
  page reuse immediate, which the eviction tests exploit.  Allocation
  failure (pool pressure) is a soft "not now": the request stays queued.
* **gather/scatter attention reads** — :func:`paged_attention_decode` writes
  the new token's K/V at ``(page, offset)`` per row and gathers the full
  logical window via the page table, so the decode step has a single static
  shape regardless of the prompt-length mix (shape-stable: one compile).

Masked (inactive) rows redirect their writes to the reserved ``TRASH`` page,
which no active row's page table ever references — a retired slot's stale
page table can therefore neither corrupt pages reallocated to newer requests
nor resurrect stale positions.

Exactness contract: the dense decode path (:func:`repro.models.layers.
apply_attention_decode`) treats a prefix cache of length ``s_c`` as a ring —
token ``pos`` lands in slot ``pos % s_c`` — and masks validity with
``kpos <= pos`` (plus the sliding window).  The paged read/write replicates
that ring slot-for-slot (logical slot ``j`` holds exactly what dense slot
``j`` holds, in the same order after the gather's reshape), with the same
bf16 storage casts, einsum equations and mask constants, so greedy decode
through the paged path is token-exact with ``ServingEngine.generate`` on the
same padded prompt (``tests/test_continuous.py`` locks this in, including
after pages have been freed and reused).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ATTN, ArchConfig
from repro.distributed.sharding import Sharder
from repro.models.layers import _project_qkv, apply_rope

POS_SENTINEL = 2 ** 30     # matches init_kv_cache's "empty slot" position


def attn_subs(cfg: ArchConfig) -> List[str]:
    """Names of the attention sublayers in one stage (``sub{i}``)."""
    sched = cfg.block_schedule()[:cfg.stage_period]
    return [f"sub{i}" for i, (mixer, _) in enumerate(sched) if mixer == ATTN]


class PagedKVCache:
    """Page pool + per-slot page tables + host free list.

    Device state (pools / position pool / page tables) is *built* here but
    owned functionally by the engine's state pytree — every jitted update
    returns new arrays.  This class keeps the host-side truth: which pages
    are free, which slot owns which pages, and the allocation/reuse counters
    the eviction tests assert on.
    """

    SENTINEL = 0           # page-table padding: never written, never valid
    TRASH = 1              # masked rows' write target: never read as valid
    RESERVED = 2

    def __init__(self, cfg: ArchConfig, capacity: int, page_size: int,
                 max_blocks: int, num_pages: Optional[int] = None):
        self.cfg = cfg
        self.capacity = capacity
        self.page_size = page_size
        self.max_blocks = max(max_blocks, 1)
        self.attn_subs = attn_subs(cfg)
        if num_pages is None:
            num_pages = self.RESERVED + capacity * self.max_blocks
        if num_pages < self.RESERVED + self.max_blocks:
            raise ValueError("num_pages cannot hold even one full sequence")
        self.num_pages = num_pages
        # LIFO free list: freshly freed pages are reallocated first
        self._free: List[int] = list(range(num_pages - 1, self.RESERVED - 1,
                                           -1))
        self._owned: Dict[int, List[int]] = {}
        self._ever_used: set = set()
        self.pages_allocated = 0
        self.pages_reused = 0

    # ------------------------------------------------------------------
    # host-side allocator
    # ------------------------------------------------------------------
    def blocks_for(self, ring_len: int) -> int:
        return -(-ring_len // self.page_size)        # ceil div

    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, slot: int, n_blocks: int) -> Optional[np.ndarray]:
        """Take ``n_blocks`` pages for ``slot``; None if the pool is short
        (the caller leaves the request queued and retries after eviction)."""
        if n_blocks > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n_blocks)]
        self._owned[slot] = pages
        self.pages_allocated += n_blocks
        self.pages_reused += sum(p in self._ever_used for p in pages)
        self._ever_used.update(pages)
        return np.asarray(pages, np.int32)

    def free(self, slot: int) -> int:
        """Evict a retired slot: its pages go back on the free list."""
        pages = self._owned.pop(slot, [])
        self._free.extend(pages)
        return len(pages)

    # ------------------------------------------------------------------
    # device-state constructors (engine holds the results in its pytree)
    # ------------------------------------------------------------------
    def make_page_table(self) -> jax.Array:
        return jnp.full((self.capacity, self.max_blocks), self.SENTINEL,
                        jnp.int32)

    def make_pos_pool(self) -> jax.Array:
        return jnp.full((self.num_pages, self.page_size), POS_SENTINEL,
                        jnp.int32)

    def make_pools(self, n_stages: int) -> Dict[str, Dict[str, jax.Array]]:
        cfg = self.cfg
        shape = (n_stages, self.num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.head_dim)
        return {name: {"k": jnp.zeros(shape, jnp.bfloat16),
                       "v": jnp.zeros(shape, jnp.bfloat16)}
                for name in self.attn_subs}


# ---------------------------------------------------------------------------
# pure gather/scatter primitives (used inside the jitted decode step)
# ---------------------------------------------------------------------------
def paged_read(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather a pool ``(NP, P, ...)`` through ``page_table (C, NB)`` into the
    logical view ``(C, NB*P, ...)``: block b, offset o -> logical slot
    ``b*P + o``, the exact layout of the dense ring cache."""
    g = pool[page_table]                       # (C, NB, P, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_write(pool: jax.Array, pages: jax.Array, offsets: jax.Array,
                values: jax.Array) -> jax.Array:
    """Scatter one entry per row: ``pool[pages[c], offsets[c]] = values[c]``.
    Masked rows all target the TRASH page; their collisions are benign
    because TRASH is never read as valid."""
    return pool.at[pages, offsets].set(values)


def paged_attention_decode(p, x, pool: Dict[str, jax.Array],
                           page_table: jax.Array, kpos: jax.Array,
                           write_page: jax.Array, write_off: jax.Array,
                           positions: jax.Array, cfg: ArchConfig,
                           sh: Sharder):
    """Single-token GQA decode against a paged cache (per-row positions).

    Mirrors :func:`repro.models.layers.apply_attention_decode` operation for
    operation (same projections, rope at the row's absolute position, bf16
    cache casts, validity mask ``kpos <= pos`` with optional sliding window,
    identical einsum contractions) — only the cache storage is paged.  The
    gathered logical view may be longer than a row's ring (page-table padding
    points at the SENTINEL page), but padded entries carry ``POS_SENTINEL``
    so their bias is -1e30 and their softmax weight underflows to exactly 0.

    x: (C, 1, d); kpos: (C, L) gathered positions (already includes this
    step's write); positions: (C,) absolute position of the new token.
    Returns (out (C, 1, d), new pool dict).
    """
    cdt_x = x.dtype
    H, Hkv, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    C = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, x, cfg, sh)
    if cfg.use_rope:
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, positions[:, None], cfg.rope_theta)
    k_pool = paged_write(pool["k"], write_page, write_off,
                         k_new[:, 0].astype(pool["k"].dtype))
    v_pool = paged_write(pool["v"], write_page, write_off,
                         v_new[:, 0].astype(pool["v"].dtype))
    k = paged_read(k_pool, page_table)                     # (C, L, Hkv, D)
    v = paged_read(v_pool, page_table)
    valid = kpos <= positions[:, None]
    if cfg.sliding_window is not None:
        valid &= kpos > positions[:, None] - cfg.sliding_window
    bias_pos = jnp.where(valid, 0.0, -1e30)                # (C, L)
    rep = H // Hkv
    qr = q.reshape(C, 1, Hkv, rep, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhrd,bkhd->bqhrk", qr, k.astype(qr.dtype),
                   preferred_element_type=jnp.float32) * scale
    s = s + bias_pos[:, None, None, None, :]
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhrk,bkhd->bqhrd", pattn, v.astype(qr.dtype),
                   preferred_element_type=jnp.float32)
    o = o.reshape(C, 1, H * D).astype(cdt_x)
    from repro.models.layers import dtype_of
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(dtype_of(
        cfg.compute_dtype)))
    return out, {"k": k_pool, "v": v_pool}
