"""Paged KV-cache for continuous batching (vLLM-style, JAX-functional).

The slot-based serving paths keep one dense KV cache per padded batch; a
batch's cache lives and dies with its dispatch, so short requests pay for the
longest row and the device idles while a finished batch's tail rows pad out.
:class:`PagedKVCache` breaks the cache into fixed-size *pages* drawn from a
shared pool so a persistent slot table (see :mod:`repro.serving.continuous`)
can admit and retire requests independently:

* **fixed-size pages** — the K/V pool per attention sublayer is
  ``(n_stages, num_pages, page_size, Hkv, D)``; a shared position pool
  ``(num_pages, page_size)`` carries the absolute token position of every
  cache entry (the validity source for the attention mask, exactly like the
  dense cache's ``pos`` plane).
* **per-sequence page tables** — ``(capacity, max_blocks)`` int32 mapping a
  slot's logical cache blocks to physical pages.  Unused blocks point at the
  reserved ``SENTINEL`` page whose positions stay at ``POS_SENTINEL`` so
  gathered padding is always masked out.
* **free-list allocation / eviction** — a host-side LIFO free list; admission
  takes ``blocks_for(ring_len)`` pages, retirement returns them.  LIFO makes
  page reuse immediate, which the eviction tests exploit.  Allocation
  failure (pool pressure) is a soft "not now": the request stays queued.
  Re-allocating a slot that still owns pages raises (it would silently leak
  the old pages off both the free list and the owned map).
* **refcounted prefix sharing (vLLM-style block sharing)** — every page
  carries a reference count; a host-side prefix trie maps the *chain key* of
  each page-aligned token block (the bytes of the whole padded prompt up to
  and including that block — KV at position ``j`` depends on every token
  ``<= j``, so block identity requires full-prefix identity) to the physical
  page holding its KV.  Admission looks up the longest full-block prefix of
  the new request's padded prompt and maps those blocks onto the existing
  pages (:meth:`PagedKVCache.alloc_shared`), allocating fresh pages only for
  the unshared suffix.  Because prefill is deterministic and row-independent,
  a shared page is bitwise what the new request's own prefill would have
  written, so greedy decode stays token-exact.
* **copy-on-write** — the decode ring writes back into prompt blocks
  (logical slot ``pos % ring``), so the first write into a block whose page
  is shared (refcount > 1) forks it: a fresh page is allocated, the engine
  copies the old page's K/V + positions device-side and remaps only the
  writer's page-table slot (:meth:`PagedKVCache.note_write`).  A sole-owner
  write into a trie-registered (pristine) page optionally *preserves* the
  pristine copy the same way — the old page stays in the trie as a cached,
  refcount-0 page that later identical prefixes can re-share, and that the
  allocator evicts (leaf-most chain entry first) when the free list runs
  dry.  Preservation is *reuse-aware* by default: a pristine page is only
  worth a copy once its chain has recorded at least one sharing hit
  (``_hits``), so share-nothing traffic registers its blocks but never pays
  the one-page-copy-per-admission churn (``require_hit=False`` restores the
  PR-4 always-preserve behaviour for A/B).  Forks can never deadlock:
  ``cow_reserve`` counts the *mandatory* forks outstanding — pending
  first-writes whose page is currently multi-referenced (refcount > 1) —
  and every allocation keeps ``available() >= cow_reserve``.  The reserve
  is derived from actual sharer counts, not one page per to-be-written
  block (the PR-4 coarse charge), so admission no longer rejects requests
  whose writes target exclusively owned pages the pool can in fact hold;
  an admission that *shares* pages picks up the reserve its new sharers
  impose (both on its own pending writes and on other slots' pending
  writes into the pages it is joining).
* **gather/scatter attention reads, two backends** — :func:`paged_attention_
  decode` writes the new token's K/V at ``(page, offset)`` per row and reads
  the logical window through :func:`paged_attend`, which dispatches on
  ``backend``: ``"jnp"`` gathers the window into a dense ``[C, NB*P, Hkv,
  D]`` view (the PR-3 path, kept as the A/B baseline and numerics oracle —
  O(bucket) bytes per emitted token), ``"pallas"`` streams page-sized KV
  blocks directly from the pool inside a fused kernel (page-table indexing
  in the kernel grid's index maps, online softmax across pages — O(live
  pages) bytes, no dense KV ever materialised; see :mod:`repro.kernels.
  paged_attention`).  Admission's KV writes go through :func:`paged_scatter`
  with the same switch (dense ``at[].set`` vs an aliased page-granular
  scatter kernel).  Either way the decode step has a single static shape
  regardless of the prompt-length mix (shape-stable: one compile).

State kinds (PR 9): the pool is no longer attention-only.  Each arch
registers a tuple of :class:`StateKind` descriptors (:func:`state_kinds`):

* ``attn`` — the refcounted/CoW/prefix-shared page space above, bitwise
  unchanged for pure-attention archs;
* ``cross`` — encoder-decoder cross-attention KV, paged into a *separate*
  page space (``cross_blocks`` per slot, written once at admission, read-only
  thereafter: no refcounts, no CoW, no trie — every admission takes a fresh
  private row and retirement returns it).  Pool dtype is the compute dtype,
  matching the blocking engine's prefill output bitwise;
* ``ssm`` — SSM/hybrid slot state is *not* paged (it lives dense in the slot
  table) but is checkpointable as fixed-width per-slot records
  (:func:`repro.models.ssm.checkpoint_slot_state`), so SSM rows participate
  in swap-preemption through the same per-kind host ledger.

The two-tier conservation audit extends per kind: ``assert_conserved``
accepts either the historical int (attention blocks only) or a
``{"attn": n, "cross": n, "ssm": n}`` dict audited against the per-kind
``swapped_by_kind()`` ledger and the swap store's ``pages_by_kind()``.

Sliding-window archs share prefixes through *window-phase* chain keys:
``chain_keys(padded, ring=...)`` emits one key per ring block tagged with
``(ring, window base, block)`` — prefill clips the cache to the last
``ring`` positions, so ring block ``b`` holds absolute positions ``base +
b*P .. base + (b+1)*P - 1`` (``base = bucket - ring``) and two requests may
share it only when bucket, ring and every token through the block's last
position agree.  Non-windowed archs keep the historical untagged keys
byte-for-byte.

Masked (inactive) rows redirect their writes to the reserved ``TRASH`` page,
which no active row's page table ever references — a retired slot's stale
page table can therefore neither corrupt pages reallocated to newer requests
nor resurrect stale positions.

Conservation contract (the allocator's audit, asserted by the property
tests): every non-reserved page is exactly one of *free* (on the free list),
*cached* (refcount 0 but trie-registered, reusable and evictable) or *live*
(refcount > 0), with ``free + cached + live == num_pages - RESERVED``; each
page's refcount equals the number of (slot, block) page-table references to
it; and ``available() = free + cached >= cow_reserve`` so every mandatory
copy-on-write fork is guaranteed a page.  Without sharing (no registration)
this degenerates to the PR-3 contract ``free + sum(owned) == num_pages -
RESERVED``.  Preemption (see :mod:`repro.serving.swap`) adds a *host tier*
on top without disturbing the device invariant: a swap-out frees the
victim's device pages through the ordinary accounting and records its
private blocks in the ``swapped_pages`` ledger, which
``assert_conserved(host_pages=...)`` audits against the swap store.

Exactness contract: the dense decode path (:func:`repro.models.layers.
apply_attention_decode`) treats a prefix cache of length ``s_c`` as a ring —
token ``pos`` lands in slot ``pos % s_c`` — and masks validity with
``kpos <= pos`` (plus the sliding window).  The paged read/write replicates
that ring slot-for-slot (logical slot ``j`` holds exactly what dense slot
``j`` holds, in the same order after the gather's reshape), with the same
bf16 storage casts, einsum equations and mask constants, so greedy decode
through the paged path is token-exact with ``ServingEngine.generate`` on the
same padded prompt (``tests/test_continuous.py`` locks this in, including
after pages have been freed and reused).
"""
from __future__ import annotations

from typing import (Dict, Iterable, List, NamedTuple, Optional, Set, Tuple,
                    Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ATTN, ArchConfig
from repro.distributed.sharding import Sharder
from repro.models.layers import _project_qkv, apply_rope
from repro.obs.telemetry import get_telemetry

POS_SENTINEL = 2 ** 30     # matches init_kv_cache's "empty slot" position


def attn_subs(cfg: ArchConfig) -> List[str]:
    """Names of the attention sublayers in one stage (``sub{i}``)."""
    sched = cfg.block_schedule()[:cfg.stage_period]
    return [f"sub{i}" for i, (mixer, _) in enumerate(sched) if mixer == ATTN]


def ssm_subs(cfg: ArchConfig) -> List[str]:
    """Names of the SSM sublayers in one stage (``sub{i}``)."""
    sched = cfg.block_schedule()[:cfg.stage_period]
    return [f"sub{i}" for i, (mixer, _) in enumerate(sched) if mixer != ATTN]


class StateKind(NamedTuple):
    """One kind of per-request serving state the pool accounts for.

    ``paged`` — lives in a shared device page space (attention KV in the
    refcounted/CoW space, cross-attention KV in its private space);
    ``swappable`` — has a fixed-width host snapshot representation, so rows
    carrying it can be preemption victims.
    """
    name: str
    paged: bool
    swappable: bool


def state_kinds(cfg: ArchConfig) -> Tuple[StateKind, ...]:
    """The state kinds an arch's slot rows carry, in canonical order.

    Every kind is currently swappable — attention/cross pages snapshot as
    page blocks, SSM states as fixed-width per-slot records — which is what
    lifts the old "SSM rows are never victims" restriction.
    """
    kinds: List[StateKind] = []
    if attn_subs(cfg):
        kinds.append(StateKind("attn", paged=True, swappable=True))
    if cfg.enc_dec:
        kinds.append(StateKind("cross", paged=True, swappable=True))
    if ssm_subs(cfg):
        kinds.append(StateKind("ssm", paged=False, swappable=True))
    return tuple(kinds)


class PagedKVCache:
    """Page pool + per-slot page tables + host free list / refcounts / trie.

    Device state (pools / position pool / page tables) is *built* here but
    owned functionally by the engine's state pytree — every jitted update
    returns new arrays.  This class keeps the host-side truth: which pages
    are free, cached or live, each page's refcount, the prefix trie, the
    copy-on-write reserve, and the allocation/sharing/reuse counters the
    eviction and sharing tests assert on.  It never touches device arrays:
    the engine applies the device-side half of every fork/remap this class
    decides (see :meth:`note_write`).
    """

    SENTINEL = 0           # page-table padding: never written, never valid
    TRASH = 1              # masked rows' write target: never read as valid
    RESERVED = 2

    def __init__(self, cfg: ArchConfig, capacity: int, page_size: int,
                 max_blocks: int, num_pages: Optional[int] = None,
                 cross_blocks: int = 0):
        self.cfg = cfg
        self.capacity = capacity
        self.page_size = page_size
        self.max_blocks = max(max_blocks, 1)
        self.attn_subs = attn_subs(cfg)
        self.state_kinds = state_kinds(cfg)
        # cross-attention page space: per-request, written once at admission,
        # read-only thereafter — so it needs no refcounts, trie or CoW, just
        # its own free list.  Sized to hold every slot's row plus the two
        # reserved pages (SENTINEL for vacated page-table rows).
        self.cross_blocks = int(cross_blocks)
        self.num_cross_pages = (self.RESERVED + capacity * self.cross_blocks
                                if self.cross_blocks else 0)
        self._cross_free: List[int] = list(
            range(self.num_cross_pages - 1, self.RESERVED - 1, -1))
        self._cross_owned: Dict[int, List[int]] = {}
        if num_pages is None:
            num_pages = self.RESERVED + capacity * self.max_blocks
        if num_pages < self.RESERVED + self.max_blocks:
            raise ValueError("num_pages cannot hold even one full sequence")
        self.num_pages = num_pages
        # LIFO free list: freshly freed pages are reallocated first
        self._free: List[int] = list(range(num_pages - 1, self.RESERVED - 1,
                                           -1))
        self._owned: Dict[int, List[int]] = {}
        self._ref: Dict[int, int] = {}          # page -> live slot references
        self._prefix: Dict[bytes, int] = {}     # block chain key -> page
        self._page_key: Dict[int, bytes] = {}   # inverse of _prefix
        # refcount-0 but trie-registered pages: page -> (chain depth, age)
        self._cached: Dict[int, Tuple[int, int]] = {}
        self._cache_seq = 0
        # slot -> block indices not yet first-written (each may need a fork)
        self._pending: Dict[int, Set[int]] = {}
        # page -> sharing hits recorded while trie-registered (cleared on
        # unregister): the evidence the reuse-aware preserve policy needs
        self._hits: Dict[int, int] = {}
        self._ever_used: set = set()
        self.pages_allocated = 0
        self.pages_reused = 0
        self.pages_shared = 0
        self.cross_pages_allocated = 0
        self.cow_forks = 0
        self.pristine_forks = 0
        # host tier (preemption swap): page blocks whose only copy lives in
        # the host-side swap store right now.  Device conservation is
        # untouched by swapping — a victim's device pages go through the
        # ordinary free() accounting — but the *two-tier* audit
        # (assert_conserved(host_pages=...)) checks this ledger against the
        # store, so a swap record can neither leak nor double-count blocks.
        # Per state kind: ``swapped_pages`` keeps its historical meaning
        # (attention blocks), cross pages and SSM records get their own
        # ledgers — audited per kind by assert_conserved(host_pages=dict).
        self.swapped_pages = 0
        self.swapped_cross = 0
        self.swapped_state = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swap_drops = 0
        # telemetry plane: every host counter above is mirrored as a
        # ``kv.*`` metric.  The owning engine re-points this at its own
        # plane; standalone pools report to the global one.
        self.tel = get_telemetry(None)

    # ------------------------------------------------------------------
    # host-side allocator
    # ------------------------------------------------------------------
    def blocks_for(self, ring_len: int) -> int:
        return -(-ring_len // self.page_size)        # ceil div

    def free_pages(self) -> int:
        return len(self._free)

    def cached_pages(self) -> int:
        return len(self._cached)

    def available(self) -> int:
        """Pages an allocation can draw on: free plus evictable cached."""
        return len(self._free) + len(self._cached)

    def ref(self, page: int) -> int:
        return self._ref.get(page, 0)

    def hits(self, page: int) -> int:
        """Sharing hits recorded against ``page`` while trie-registered."""
        return self._hits.get(page, 0)

    @property
    def cow_reserve(self) -> int:
        """Headroom the allocator must keep for *mandatory* copy-on-write
        forks: pending first-writes whose page is multi-referenced right
        now.  Derived from actual sharer counts (a pending write into an
        exclusively owned page costs nothing — if it is registered, the
        write merely unregisters or optionally preserves it, and
        preservation moves a page from free to cached without shrinking
        ``available()``)."""
        need = 0
        for slot, blks in self._pending.items():
            pages = self._owned.get(slot, ())
            for b in blks:
                if self._ref.get(pages[b], 0) > 1:
                    need += 1
        return need

    def chain_keys(self, padded: np.ndarray, ring: Optional[int] = None,
                   salt: bytes = b"") -> List[bytes]:
        """Chain key per page block of a padded prompt: the bytes of the
        whole prompt up to and including the block's last cached position,
        so two requests share a block only when every earlier token (padding
        included) agrees — exactly the condition under which the block's KV
        is bitwise equal.

        ``ring`` (sliding-window archs) keys by *(content chain, window
        phase)*: prefill clips the cache to the last ``ring`` positions, so
        ring block ``b`` holds absolute positions ``base + b*P`` onward
        (``base = bucket - ring``) and its key is the prompt bytes through
        the block's last cached position plus a ``(ring, base, block)`` tag
        — requests with a different bucket or window hold different
        positions in the "same" ring block and must never collide.  When
        the ring covers the whole bucket (``ring is None`` or ``ring >=
        bucket``) keys are byte-identical to the historical untagged form.

        ``salt`` prefixes every key (non-token prefill inputs — encoder
        frames, vision patch embeds — change the KV a block holds, so they
        must be part of block identity)."""
        t = np.ascontiguousarray(np.asarray(padded, np.int32).reshape(-1))
        p = self.page_size
        bucket = t.size
        if ring is None or ring >= bucket:
            return [salt + t[:(b + 1) * p].tobytes()
                    for b in range(bucket // p)]
        base = bucket - ring
        return [salt + t[:min(base + (b + 1) * p, bucket)].tobytes()
                + b"|w%d:%d:%d" % (ring, base, b)
                for b in range(-(-ring // p))]

    def lookup_chain(self, keys: Iterable[bytes]) -> List[int]:
        """Pages of the longest registered full-block prefix of ``keys``."""
        pages: List[int] = []
        for key in keys:
            page = self._prefix.get(key)
            if page is None:
                break
            pages.append(page)
        return pages

    def alloc(self, slot: int, n_blocks: int) -> Optional[np.ndarray]:
        """Take ``n_blocks`` fresh pages for ``slot``; None if the pool is
        short (the caller leaves the request queued and retries after
        eviction)."""
        return self.alloc_shared(slot, [], n_blocks, ())

    def alloc_shared(self, slot: int, shared: List[int], n_fresh: int,
                     will_write: Iterable[int]) -> Optional[np.ndarray]:
        """Build ``slot``'s page row: ``shared`` (a prefix of existing pages,
        refcounts incremented) followed by ``n_fresh`` fresh pages.

        ``will_write`` are the block indices the request will write during
        its decode; ``cow_reserve`` headroom is charged only for those that
        land on *shared* pages (refcount > 1 once this admission joins) —
        the mandatory forks — plus any pending writes of other slots whose
        page this admission newly makes shared.  Writes into exclusively
        owned pages are free: the PR-4 coarse one-page-per-block charge
        rejected admissions the pool could in fact hold.  Returns None
        (nothing changed) when the pool cannot cover ``n_fresh`` plus the
        post-admission reserve — the request stays queued.
        """
        if slot in self._owned:
            # silently overwriting would leak the old pages off both the
            # free list and the owned map (PR-3 bug); the engine retires a
            # slot before reusing it, so this is always a caller bug
            raise ValueError(
                f"slot {slot} already owns pages; free() it before "
                f"re-allocating")
        will_write = set(will_write)
        # reviving a cached shared page takes it out of the evictable set,
        # so it costs availability exactly like a fresh page does
        revived = sum(self._ref.get(p, 0) == 0 for p in shared)
        # post-admission reserve: every pending write (existing slots' and
        # this one's) whose page will be multi-referenced after the shared
        # refcounts are bumped needs a guaranteed fork page
        shared_set = set(shared)
        reserve = 0
        for s2, blks in self._pending.items():
            pages2 = self._owned.get(s2, ())
            for b in blks:
                p = pages2[b]
                if self._ref.get(p, 0) + (p in shared_set) > 1:
                    reserve += 1
        for b in will_write:
            if b < len(shared) and self._ref.get(shared[b], 0) + 1 > 1:
                reserve += 1
        if self.available() - n_fresh - revived < reserve:
            self.tel.count("kv.alloc_blocked")
            return None
        for p in shared:
            if self._ref.get(p, 0) == 0:        # revive a cached page
                self._cached.pop(p, None)
            self._ref[p] = self._ref.get(p, 0) + 1
            self._hits[p] = self._hits.get(p, 0) + 1
        fresh = [self._take_page() for _ in range(n_fresh)]
        for p in fresh:
            self._ref[p] = 1
        self._owned[slot] = list(shared) + fresh
        self._pending[slot] = will_write
        self.pages_allocated += n_fresh
        self.pages_shared += len(shared)
        tel = self.tel
        if tel.enabled:
            tel.count("kv.pages_allocated", n_fresh)
            if shared:
                tel.count("kv.pages_shared", len(shared))
                tel.count("kv.prefix_hits")
            tel.gauge("kv.free_pages", len(self._free))
            # zero-length span so the pool's activity lands on the trace
            # timeline (parents under the enclosing admission span)
            tel.event("kv.alloc", slot=slot, fresh=n_fresh,
                      shared=len(shared))
        return np.asarray(self._owned[slot], np.int32)

    def alloc_cross(self, slot: int) -> Optional[np.ndarray]:
        """Take ``slot``'s row of ``cross_blocks`` pages from the cross page
        space; None when the space is short (the request stays queued).
        Cross pages are private and written once, so there is nothing to
        share and no reserve to keep."""
        if slot in self._cross_owned:
            raise ValueError(
                f"slot {slot} already owns cross pages; free() it before "
                f"re-allocating")
        if len(self._cross_free) < self.cross_blocks:
            self.tel.count("kv.alloc_blocked")
            return None
        pages = [self._cross_free.pop() for _ in range(self.cross_blocks)]
        self._cross_owned[slot] = pages
        self.cross_pages_allocated += len(pages)
        if self.tel.enabled:
            self.tel.count("kv.cross.pages_allocated", len(pages))
        return np.asarray(pages, np.int32)

    def cross_pages_of(self, slot: int) -> List[int]:
        """The slot's cross page row (block order), read-only."""
        return list(self._cross_owned.get(slot, ()))

    def _free_cross(self, slot: int) -> int:
        pages = self._cross_owned.pop(slot, None)
        if not pages:
            return 0
        self._cross_free.extend(pages)
        if self.tel.enabled:
            self.tel.count("kv.cross.pages_freed", len(pages))
        return len(pages)

    def register(self, slot: int, keys: List[bytes]) -> None:
        """Enter ``slot``'s pages into the prefix trie under their chain
        keys.  First registration wins (duplicate-content pages from one
        admission batch stay private); already-shared prefix pages are
        naturally skipped because their key is present."""
        pages = self._owned.get(slot, [])
        for blk, key in enumerate(keys):
            if blk >= len(pages):
                break
            page = pages[blk]
            if key in self._prefix or page in self._page_key:
                continue
            self._prefix[key] = page
            self._page_key[page] = key

    def note_write(self, slot: int, blk: int, preserve: bool = True,
                   require_hit: bool = True) -> Optional[Tuple[int, int]]:
        """Resolve ``slot``'s upcoming decode write into block ``blk``.

        Returns ``(src, dst)`` when the engine must copy page ``src`` to the
        freshly mapped page ``dst`` (device-side) before the round runs:

        * refcount > 1 — mandatory copy-on-write fork (other requests, or
          the trie's cached readers, still read ``src``);
        * sole owner of a trie-registered page with ``preserve``, a free
          page at hand, and — under the default reuse-aware policy
          (``require_hit``) — at least one sharing hit recorded against the
          page: pristine-preserving fork, ``src`` stays in the trie as a
          cached page so later identical prefixes can re-share it.  Without
          a recorded hit there is no evidence the chain is ever re-used, so
          the copy is skipped (the share-nothing fast path);
          ``require_hit=False`` restores the PR-4 preserve-always policy.

        Otherwise returns None; a sole-owner write into a registered page
        that is not preserved simply unregisters it (its content is about
        to diverge from its chain key).  Idempotent per block: after the
        first resolution the slot owns the page exclusively and
        unregistered, so later ring wraps fall through.
        """
        pages = self._owned.get(slot)
        if pages is None:
            return None
        page = pages[blk]
        pending = self._pending.get(slot)
        if pending is not None:
            pending.discard(blk)
        if self._ref.get(page, 0) > 1:
            dst = self._take_page()
            self._ref[page] -= 1
            self._ref[dst] = 1
            pages[blk] = dst
            self.cow_forks += 1
            self.pages_allocated += 1
            self.tel.count("kv.cow_forks")
            return page, dst
        if page in self._page_key:
            if (preserve and self._free
                    and (not require_hit or self._hits.get(page, 0) > 0)):
                # moves a page free -> live and a page live -> cached, so
                # available() (free + cached) is unchanged: preservation
                # can never eat into the mandatory-fork reserve
                dst = self._free.pop()
                self.pages_reused += dst in self._ever_used
                self._ever_used.add(dst)
                self._ref[dst] = 1
                pages[blk] = dst
                self._ref[page] = 0
                self._cached[page] = (blk, self._cache_seq)
                self._cache_seq += 1
                self.pristine_forks += 1
                self.pages_allocated += 1
                self.tel.count("kv.pristine_forks")
                return page, dst
            self._unregister(page)
        return None

    def owned_pages(self, slot: int) -> List[int]:
        """The slot's current page row (block order), read-only."""
        return list(self._owned.get(slot, ()))

    def private_blocks(self, slot: int) -> List[int]:
        """Block indices whose page content exists nowhere but this slot's
        row: refcount 1 and not trie-registered.  These are a preemption
        victim's *private suffix* — the only blocks a swap-out actually
        moves to the host tier.  Every other block's content stays
        device-resident after the victim's refcounts drop: shared pages
        keep serving their other readers, and registered pristine pages
        linger as evictable cache."""
        return [b for b, p in enumerate(self._owned.get(slot, ()))
                if self._ref.get(p, 0) == 1 and p not in self._page_key]

    def trie_keys(self) -> List[bytes]:
        """Every chain key currently registered in the prefix trie, sorted
        — recorded in engine checkpoints so recovery can audit that the
        rebuilt pool re-registered each restored slot's live chains (the
        refcount-0 cached tail is a cache and is deliberately *not* part
        of the recovery contract)."""
        return sorted(self._prefix)

    def swapped_by_kind(self) -> Dict[str, int]:
        """Host-tier ledger per state kind: attention page blocks, cross
        page blocks, SSM state records (one per SSM sublayer per victim)."""
        return {"attn": self.swapped_pages, "cross": self.swapped_cross,
                "ssm": self.swapped_state}

    def swap_out(self, slot: int, host_blocks: int, cross_blocks: int = 0,
                 state_records: int = 0) -> int:
        """Preemption swap-out: retire a victim slot's page references —
        exactly :meth:`free` (cross row included), shared prefix pages are
        never pulled out from under their other sequences — and account the
        snapshot against the per-kind host ledger: ``host_blocks`` attention
        page blocks (the victim's private suffix, see
        :meth:`private_blocks`), ``cross_blocks`` cross pages and
        ``state_records`` SSM state records.  The engine snapshots content
        *before* calling this; the allocator only moves the ledger.
        Returns the attention pages whose refcount dropped to 0."""
        released = self.free(slot)
        self.swapped_pages += host_blocks
        self.swapped_cross += cross_blocks
        self.swapped_state += state_records
        self.swap_outs += 1
        self.tel.count("kv.swap_out_blocks", host_blocks)
        if cross_blocks:
            self.tel.count("kv.cross.swap_out_blocks", cross_blocks)
        if state_records:
            self.tel.count("kv.ssm.swap_out_records", state_records)
        self.tel.gauge("kv.swapped_pages", self.swapped_pages)
        return released

    def adopt_swapped(self, host_blocks: int, cross_blocks: int = 0,
                      state_records: int = 0) -> None:
        """Crash recovery: seed the host-tier ledger of a *fresh* pool for
        a checkpointed swap record re-parked in the store without ever
        having been swapped out of this pool instance.  The two-tier
        conservation audit (:meth:`assert_conserved` with ``host_pages``)
        holds from the first post-recovery drain, not only after the
        record's eventual restore."""
        self.swapped_pages += host_blocks
        self.swapped_cross += cross_blocks
        self.swapped_state += state_records
        self.tel.gauge("kv.swapped_pages", self.swapped_pages)

    def swap_in(self, host_blocks: int, restored: bool = True,
                cross_blocks: int = 0, state_records: int = 0) -> None:
        """Account a record's blocks leaving the host tier — either restored
        into fresh device pages / slot rows (``restored``) or dropped with a
        terminally failed swap record."""
        assert self.swapped_pages >= host_blocks, \
            (self.swapped_pages, host_blocks)
        assert self.swapped_cross >= cross_blocks, \
            (self.swapped_cross, cross_blocks)
        assert self.swapped_state >= state_records, \
            (self.swapped_state, state_records)
        self.swapped_pages -= host_blocks
        self.swapped_cross -= cross_blocks
        self.swapped_state -= state_records
        if restored:
            self.swap_ins += 1
            self.tel.count("kv.swap_in_blocks", host_blocks)
        else:
            self.swap_drops += 1
            self.tel.count("kv.swap_drop_blocks", host_blocks)
        self.tel.gauge("kv.swapped_pages", self.swapped_pages)

    def free(self, slot: int) -> int:
        """Retire a slot: decrement its pages' refcounts and return its
        cross row (if any) to the cross free list.  Attention pages
        reaching refcount 0 return to the free list — or stay behind as
        cached (evictable) pristine pages when still trie-registered, so a
        later identical prefix can re-share them.  Returns the number of
        attention pages whose refcount dropped to 0."""
        self._free_cross(slot)
        released = 0
        for blk, page in enumerate(self._owned.pop(slot, [])):
            self._ref[page] -= 1
            if self._ref[page] == 0:
                released += 1
                if page in self._page_key:
                    self._cached[page] = (blk, self._cache_seq)
                    self._cache_seq += 1
                else:
                    self._free.append(page)
        self._pending.pop(slot, None)
        if self.tel.enabled and released:
            self.tel.count("kv.pages_freed", released)
            self.tel.gauge("kv.free_pages", len(self._free))
        return released

    # ------------------------------------------------------------------
    def _unregister(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            self._prefix.pop(key, None)
        self._cached.pop(page, None)
        self._hits.pop(page, None)

    def _take_page(self) -> int:
        """Pop a free page; when the free list is dry, evict a cached
        pristine page — leaf-most chain entry first (deepest block, then
        oldest), so short shared prefixes survive longest."""
        if self._free:
            page = self._free.pop()
        else:
            page = max(self._cached,
                       key=lambda q: (self._cached[q][0],
                                      -self._cached[q][1]))
            self._unregister(page)
            self.tel.count("kv.evictions")
        self.pages_reused += page in self._ever_used
        self._ever_used.add(page)
        return page

    # ------------------------------------------------------------------
    def assert_conserved(
            self, host_pages: Optional[Union[int, Dict[str, int]]] = None
    ) -> None:
        """Audit the allocator (tests): page conservation, refcount
        integrity, trie consistency, fork-reserve headroom, and — when a
        cross page space exists — cross-row conservation.

        With ``host_pages`` (the swap store's current block count) the audit
        extends to the host tier: the device invariant must hold unchanged
        — a swapped victim's pages went through the ordinary free/realloc
        accounting — *and* every block the allocator believes is
        host-resident is in the store exactly once, so a swap round-trip
        conserves pages across both tiers.  An int audits the attention
        ledger only (``swapped_pages == host_pages``, the historical form);
        a dict (``store.pages_by_kind()``) audits every kind's ledger."""
        usable = self.num_pages - self.RESERVED
        live = {p for p, r in self._ref.items() if r > 0}
        free_set = set(self._free)
        cached_set = set(self._cached)
        assert len(self._free) == len(free_set), "free list has duplicates"
        assert not (free_set & live), "free page still referenced"
        assert not (free_set & cached_set), "page both free and cached"
        assert not (cached_set & live), "cached page still referenced"
        assert all(p in self._page_key for p in cached_set), \
            "cached page not trie-registered"
        assert len(free_set) + len(cached_set) + len(live) == usable, \
            (len(free_set), len(cached_set), len(live), usable)
        counts: Dict[int, int] = {}
        for pages in self._owned.values():
            for p in pages:
                counts[p] = counts.get(p, 0) + 1
        for p in live | set(counts):
            assert self._ref.get(p, 0) == counts.get(p, 0), \
                (p, self._ref.get(p, 0), counts.get(p, 0))
        for key, p in self._prefix.items():
            assert self._page_key.get(p) == key, "trie inverse out of sync"
        for p in self._hits:
            assert p in self._page_key, "hit count on an unregistered page"
        for slot, blks in self._pending.items():
            assert slot in self._owned, "pending writes on a retired slot"
            assert all(b < len(self._owned[slot]) for b in blks)
        # the refcount-derived reserve (mandatory forks outstanding) must
        # always be coverable, so a copy-on-write fork can never fail
        assert self.available() >= self.cow_reserve, \
            (self.available(), self.cow_reserve)
        if self.cross_blocks:
            cross_live = [p for pages in self._cross_owned.values()
                          for p in pages]
            cross_free = set(self._cross_free)
            assert len(cross_free) == len(self._cross_free), \
                "cross free list has duplicates"
            assert len(set(cross_live)) == len(cross_live), \
                "cross page owned twice"
            assert not (cross_free & set(cross_live)), \
                "cross page both free and owned"
            assert len(cross_free) + len(cross_live) == \
                self.num_cross_pages - self.RESERVED, \
                (len(cross_free), len(cross_live), self.num_cross_pages)
        ledger = self.swapped_by_kind()
        assert all(v >= 0 for v in ledger.values()), ledger
        if host_pages is not None:
            if isinstance(host_pages, dict):
                want = {k: 0 for k in ledger}
                want.update(host_pages)
                assert ledger == want, (ledger, want)
            else:
                assert self.swapped_pages == host_pages, \
                    (self.swapped_pages, host_pages)
        self.tel.count("kv.conservation_checks")

    # ------------------------------------------------------------------
    # device-state constructors (engine holds the results in its pytree)
    # ------------------------------------------------------------------
    def make_page_table(self) -> jax.Array:
        return jnp.full((self.capacity, self.max_blocks), self.SENTINEL,
                        jnp.int32)

    def make_pos_pool(self) -> jax.Array:
        return jnp.full((self.num_pages, self.page_size), POS_SENTINEL,
                        jnp.int32)

    def make_pools(self, n_stages: int) -> Dict[str, Dict[str, jax.Array]]:
        cfg = self.cfg
        shape = (n_stages, self.num_pages, self.page_size,
                 cfg.num_kv_heads, cfg.head_dim)
        return {name: {"k": jnp.zeros(shape, jnp.bfloat16),
                       "v": jnp.zeros(shape, jnp.bfloat16)}
                for name in self.attn_subs}

    def make_cross_page_table(self) -> jax.Array:
        return jnp.full((self.capacity, self.cross_blocks), self.SENTINEL,
                        jnp.int32)

    def make_cross_pools(self, n_stages: int) -> Dict[str, jax.Array]:
        """Cross-attention page pool, in the *compute* dtype: the blocking
        engine decodes straight from prefill's cross KV (compute dtype), so
        storing bf16 here would break bitwise parity with it."""
        from repro.models.layers import dtype_of
        cfg = self.cfg
        shape = (n_stages, self.num_cross_pages, self.page_size,
                 cfg.num_kv_heads, cfg.head_dim)
        dt = dtype_of(cfg.compute_dtype)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ---------------------------------------------------------------------------
# pure gather/scatter primitives (used inside the jitted decode step)
# ---------------------------------------------------------------------------
def paged_write(pool: jax.Array, pages: jax.Array, offsets: jax.Array,
                values: jax.Array) -> jax.Array:
    """Scatter one entry per row: ``pool[pages[c], offsets[c]] = values[c]``.
    Masked rows all target the TRASH page; their collisions are benign
    because TRASH is never read as valid."""
    return pool.at[pages, offsets].set(values)


BACKENDS = ("jnp", "pallas")


def paged_attend(q: jax.Array, pool: Dict[str, jax.Array],
                 page_table: jax.Array, positions: jax.Array,
                 cfg: ArchConfig, *, kpos: Optional[jax.Array] = None,
                 pos_pool: Optional[jax.Array] = None,
                 backend: str = "jnp", interpret: bool = True,
                 sh: Optional[Sharder] = None) -> jax.Array:
    """Paged attention read, backend-switched.

    q: (C, H, D) already-roped queries; pool: {"k","v"} (NP, P, Hkv, D);
    page_table: (C, NB); positions: (C,).  Returns (C, H, D) float32.

    * ``backend="jnp"`` — gather the logical window dense through the page
      table and run the PR-3 reference math (:func:`repro.kernels.ref.
      paged_attention_decode_ref`): bitwise the historical path, O(C * NB *
      P) pool bytes touched per call.  Needs ``kpos`` (the decode step
      pre-gathers it once and shares it across sublayers).
    * ``backend="pallas"`` — the fused kernel (:func:`repro.kernels.
      paged_attention.paged_attention_decode_pallas`): pages stream through
      the grid's index maps, online softmax across pages, no dense KV.
      Needs ``pos_pool`` (positions are read per page, in place, so the
      dense kpos gather is skipped too).  Token-exact with jnp for greedy
      decode; logits agree to f32 rounding (see the kernel module).

    ``sh`` routes the pallas backend through the shard_map dispatch when a
    mesh is active (pallas_call has no GSPMD partitioning rules); the jnp
    backend partitions under plain GSPMD and ignores it.
    """
    if backend == "pallas":
        from repro.kernels.paged_attention import paged_attention_decode_sharded
        return paged_attention_decode_sharded(
            q, pool["k"], pool["v"], pos_pool, page_table, positions, sh,
            window=cfg.sliding_window, interpret=interpret)
    if backend != "jnp":
        raise ValueError(f"backend {backend!r}: must be one of {BACKENDS}")
    from repro.kernels.ref import paged_attention_decode_ref
    return paged_attention_decode_ref(
        q, pool["k"], pool["v"], page_table, positions, kpos=kpos,
        pos_pool=pos_pool, window=cfg.sliding_window)


def paged_scatter(pool: jax.Array, pages: jax.Array, values: jax.Array, *,
                  backend: str = "jnp", interpret: bool = True,
                  sh: Optional[Sharder] = None) -> jax.Array:
    """Admission-time KV scatter, backend-switched: write ``values``
    (S, nb, P, Hkv, D) into ``pool`` (S, NP, P, Hkv, D) at ``pages`` (nb,).

    ``"jnp"`` is the dense ``at[].set`` hop; ``"pallas"`` the aliased
    page-granular scatter kernel that writes prefill KV straight into its
    allocated pages (:func:`repro.kernels.paged_attention.
    paged_prefill_scatter_pallas`).  Both cast to the pool dtype and are
    bit-exact with each other."""
    if backend == "pallas":
        from repro.kernels.paged_attention import paged_prefill_scatter_sharded
        return paged_prefill_scatter_sharded(pool, pages, values, sh,
                                             interpret=interpret)
    if backend != "jnp":
        raise ValueError(f"backend {backend!r}: must be one of {BACKENDS}")
    from repro.kernels.ref import paged_scatter_ref
    return paged_scatter_ref(pool, pages, values)


def paged_attention_decode(p, x, pool: Dict[str, jax.Array],
                           page_table: jax.Array, kpos: Optional[jax.Array],
                           write_page: jax.Array, write_off: jax.Array,
                           positions: jax.Array, cfg: ArchConfig,
                           sh: Sharder, *,
                           pos_pool: Optional[jax.Array] = None,
                           backend: str = "jnp", interpret: bool = True):
    """Single-token GQA decode against a paged cache (per-row positions).

    Mirrors :func:`repro.models.layers.apply_attention_decode` operation for
    operation (same projections, rope at the row's absolute position, bf16
    cache casts, validity mask ``kpos <= pos`` with optional sliding window,
    identical einsum contractions) — only the cache storage is paged and the
    window read goes through :func:`paged_attend` (``backend`` selects the
    dense gather or the fused page-streaming kernel).  The logical view may
    be longer than a row's ring (page-table padding points at the SENTINEL
    page), but padded entries carry ``POS_SENTINEL`` so their bias is -1e30
    and their softmax weight underflows to exactly 0.

    x: (C, 1, d); kpos: (C, L) gathered positions including this step's
    write (jnp backend; pallas reads positions per page from ``pos_pool``
    instead); positions: (C,) absolute position of the new token.
    Returns (out (C, 1, d), new pool dict).
    """
    cdt_x = x.dtype
    H, D = cfg.num_heads, cfg.head_dim
    C = x.shape[0]
    q, k_new, v_new = _project_qkv(p, x, x, cfg, sh)
    if cfg.use_rope:
        q = apply_rope(q, positions[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, positions[:, None], cfg.rope_theta)
    k_pool = paged_write(pool["k"], write_page, write_off,
                         k_new[:, 0].astype(pool["k"].dtype))
    v_pool = paged_write(pool["v"], write_page, write_off,
                         v_new[:, 0].astype(pool["v"].dtype))
    o = paged_attend(q[:, 0], {"k": k_pool, "v": v_pool}, page_table,
                     positions, cfg, kpos=kpos, pos_pool=pos_pool,
                     backend=backend, interpret=interpret, sh=sh)
    # merge the head-sharded attention output with an all-gather (pure data
    # movement, bitwise-safe) before the replicated wo contraction
    o = sh.constrain(o, (None, None, None))
    o = o.reshape(C, 1, H * D).astype(cdt_x)
    from repro.models.layers import dtype_of
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(dtype_of(
        cfg.compute_dtype)))
    return out, {"k": k_pool, "v": v_pool}
