"""Durable write-ahead request journal: the crash-safety control plane.

The serving stack's recovery contract (see ``distributed/checkpoint.py``
for the data plane) is built on two facts the stack already guarantees:

* every slot's decode state is snapshottable token-exactly per state kind
  (:class:`repro.serving.swap.SwapRecord` — the preemption machinery), and
* decode is deterministic under seeded sampling (``fold_in(key, lstep)``
  per emitted token), so replaying rounds past a snapshot regenerates
  bitwise-identical tokens for non-MoE archs.

What is *not* reconstructible from a snapshot alone is the request
history: which requests ever entered the scheduler, which finished (and
with which tokens), and which were in flight or queued at the instant of
the crash.  This module is that history — an append-only JSONL journal,
fsync'd per record, written *ahead of* the state mutation it describes so
a crash between the two is always recoverable (the record without the
mutation re-queues the request; the mutation without the record cannot
happen).

Record kinds (the golden-pinned schema — ``RECORD_FIELDS`` below is the
contract, ``tests/golden/journal_schema.json`` the pin):

* ``SUBMIT`` — full :class:`~repro.serving.multitenant.Request` (prompt
  tokens, sampling seed, priority, deadline, serialized extra inputs plus
  their sha256) keyed by a stable monotone ``rid``.  A rid with a SUBMIT
  but no terminal record and no checkpointed state is *re-queued* on
  recovery, never lost.
* ``ADMIT`` — the rid entered a slot (bucket/ring recorded for audit).
* ``ROUND_COMMIT`` — one collected decode micro-round: cumulative emitted
  token counts per live rid.  Recovery uses the counts past the last
  checkpoint to report rounds/tokens replayed (the tokens themselves are
  regenerated deterministically, so they are *not* journaled per round).
* ``RETIRE`` — terminal completion, with the full token list: a request
  that retired before the crash is surfaced from the journal without
  re-decoding, and one that retired *after* the last checkpoint is
  replayed and cross-checked bitwise against this record.
* ``REJECT`` / ``FAIL`` — terminal non-completions (admission retry
  budget / shed, fault-injection limit).
* ``PREEMPT`` / ``RESTORE`` — the rid moved to / returned from the host
  swap tier (ticket recorded; the record itself rides the checkpoint).
* ``CHECKPOINT`` — an engine checkpoint of this step landed on disk (the
  recovery baseline: everything before it is in the snapshot, everything
  after it is replayed).
* ``RECOVER`` — a recovery ran: the journal stays append-only across
  process generations, so a second crash during replay recovers too.

Torn tails: a crash can truncate the final record mid-line.  The reader
drops an unparseable *last* line silently (the WAL discipline means the
corresponding mutation never happened) but raises on corruption anywhere
else — silent mid-file damage is not a state we recover through.  The
writer enforces the same invariant on reopen: :class:`JournalWriter`
truncates a torn tail before its first append (so the next generation's
records never concatenate onto a partial line) and seeds its sequence
counter past the surviving records.
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

from repro.obs.telemetry import get_telemetry

JOURNAL_VERSION = 1

# The journal schema contract: record kind -> exact payload field set
# (envelope fields ``v``/``seq``/``kind`` ride on every record).  append()
# enforces it, tests/golden/journal_schema.json pins it — widening or
# renaming a field is an explicit golden-file update, never silent drift.
RECORD_FIELDS: Dict[str, List[str]] = {
    "SUBMIT": ["arrival_s", "deadline_s", "extras", "extras_hash",
               "max_new_tokens", "priority", "prompt", "rid", "seed",
               "temperature", "tenant", "top_k"],
    "ADMIT": ["bucket", "rid", "ring", "slot"],
    "ROUND_COMMIT": ["emitted", "rnd"],
    "RETIRE": ["rid", "tokens"],
    "REJECT": ["rid", "shed"],
    "FAIL": ["preemptions", "rid"],
    "PREEMPT": ["rid", "ticket"],
    "RESTORE": ["rid", "ticket"],
    "CHECKPOINT": ["rnd", "step"],
    "RECOVER": ["requeued", "restored_live", "restored_swapped",
                "rounds_replayed", "step"],
}


# ----------------------------------------------------------------------
# Request <-> record
# ----------------------------------------------------------------------
def extras_hash(extra_inputs: Optional[Dict[str, Any]]) -> str:
    """sha256 over the request's non-token inputs (sorted name + bytes) —
    the same salt material the prefix-sharing chain keys fold in, so two
    requests share pages only when this hash matches."""
    if not extra_inputs:
        return ""
    h = hashlib.sha256()
    for name in sorted(extra_inputs):
        arr = np.ascontiguousarray(np.asarray(extra_inputs[name]))
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _encode_extras(extra_inputs: Optional[Dict[str, Any]]
                   ) -> Optional[Dict[str, Dict[str, Any]]]:
    if not extra_inputs:
        return None
    out = {}
    for name in sorted(extra_inputs):
        arr = np.ascontiguousarray(np.asarray(extra_inputs[name]))
        out[name] = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                     "b64": base64.b64encode(arr.tobytes()).decode("ascii")}
    return out


def _decode_extras(enc: Optional[Dict[str, Dict[str, Any]]]
                   ) -> Optional[Dict[str, np.ndarray]]:
    if not enc:
        return None
    return {name: np.frombuffer(
        base64.b64decode(spec["b64"]), dtype=np.dtype(spec["dtype"])
    ).reshape(spec["shape"]).copy() for name, spec in enc.items()}


def request_to_record(rid: int, req: Any) -> Dict[str, Any]:
    """Serialize a Request to the SUBMIT payload (json-able, lossless:
    :func:`request_from_record` rebuilds an equivalent Request, extra
    inputs included)."""
    extras = getattr(req, "extra_inputs", None)
    temp = getattr(req, "temperature", None)
    dl = getattr(req, "deadline_s", None)
    return {
        "rid": int(rid),
        "tenant": str(req.tenant),
        "prompt": [int(t) for t in np.asarray(req.prompt).reshape(-1)],
        "max_new_tokens": int(req.max_new_tokens),
        "temperature": None if temp is None else float(temp),
        "top_k": int(getattr(req, "top_k", 0)),
        "seed": int(getattr(req, "seed", 0) or 0),
        "priority": int(getattr(req, "priority", 1)),
        "deadline_s": None if dl is None else float(dl),
        "arrival_s": float(req.arrival_s),
        "extras": _encode_extras(extras),
        "extras_hash": extras_hash(extras),
    }


def request_from_record(rec: Dict[str, Any]) -> Any:
    """Rebuild a Request from a SUBMIT payload (inverse of
    :func:`request_to_record`)."""
    from repro.serving.multitenant import Request  # circular at module load
    return Request(
        tenant=rec["tenant"],
        prompt=np.asarray(rec["prompt"], np.int32),
        max_new_tokens=rec["max_new_tokens"],
        temperature=rec["temperature"],
        top_k=rec["top_k"],
        seed=rec["seed"],
        arrival_s=rec["arrival_s"],
        priority=rec["priority"],
        deadline_s=rec["deadline_s"],
        extra_inputs=_decode_extras(rec["extras"]),
    )


# ----------------------------------------------------------------------
# Writer
# ----------------------------------------------------------------------
class JournalWriter:
    """Append-only JSONL journal with per-record fsync.

    Durability discipline: ``append`` returns only after the record's
    bytes are flushed and fsync'd, so any state mutation sequenced after
    an append is guaranteed to be *at or behind* the journal on disk —
    SIGKILL at any instruction leaves a journal whose replay is a safe
    over-approximation of what the process had done."""

    def __init__(self, path: str, fsync: bool = True,
                 telemetry: Optional[Any] = None):
        self.path = path
        self.fsync = fsync
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self._seq = self._repair_and_seed(path)
        self._f = open(path, "ab")
        self.appends = 0
        self.bytes_written = 0
        self.tel = get_telemetry(telemetry)

    @staticmethod
    def _repair_and_seed(path: str) -> int:
        """Reopen discipline. A prior generation SIGKILLed mid-append
        leaves a torn final line (no trailing newline); truncate it away
        *before* this generation appends, or its first record would be
        concatenated onto the partial one — turning a recoverable torn
        tail into the mid-file corruption :func:`read_journal` refuses.
        Dropping the partial record is safe by the WAL ordering: its
        mutation never happened.  Returns the next sequence number,
        seeded past the surviving tail so seqs stay monotone across
        process generations instead of restarting at 0."""
        if not os.path.exists(path) or os.path.getsize(path) == 0:
            return 0
        with open(path, "rb") as f:
            raw = f.read()
        if not raw.endswith(b"\n"):
            keep = raw.rfind(b"\n") + 1        # 0 when no newline at all
            os.truncate(path, keep)
            raw = raw[:keep]
        lines = raw.split(b"\n")[:-1]
        if not lines:
            return 0
        try:
            return int(json.loads(lines[-1])["seq"]) + 1
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            return len(lines)   # mid-file damage: read_journal will raise

    def append(self, kind: str, **fields: Any) -> int:
        """Durably append one record; returns its sequence number."""
        want = RECORD_FIELDS.get(kind)
        if want is None:
            raise ValueError(f"unknown journal record kind {kind!r}")
        if sorted(fields) != want:
            raise ValueError(
                f"journal {kind} payload {sorted(fields)} != schema {want}")
        seq = self._seq
        self._seq += 1
        rec = {"v": JOURNAL_VERSION, "seq": seq, "kind": kind, **fields}
        line = (json.dumps(rec, sort_keys=True, separators=(",", ":"))
                + "\n").encode()
        self._f.write(line)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self.appends += 1
        self.bytes_written += len(line)
        if self.tel.enabled:
            self.tel.count("journal.appends")
            self.tel.count("journal.bytes", len(line))
        return seq

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
            self._f.close()


# ----------------------------------------------------------------------
# Reader / replay
# ----------------------------------------------------------------------
def read_journal(path: str) -> List[Dict[str, Any]]:
    """Read every record; a torn *final* line (crash mid-append) is
    dropped, corruption anywhere else raises."""
    records: List[Dict[str, Any]] = []
    if not os.path.exists(path):
        return records
    with open(path, "rb") as f:
        raw = f.read()
    lines = raw.split(b"\n")
    # a well-formed file ends with newline -> last split element is empty
    tail_open = lines and lines[-1] != b""
    body = lines[:-1]
    for i, line in enumerate(body):
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            raise ValueError(
                f"journal {path}: corrupt record at line {i} "
                f"(not the torn tail)")
    if tail_open:
        try:
            records.append(json.loads(lines[-1]))
        except json.JSONDecodeError:
            pass                       # torn tail: mutation never happened
    return records


@dataclasses.dataclass
class JournalState:
    """Replay of a journal: everything recovery needs to decide each
    rid's fate (requeue / restore / surface-from-journal)."""
    submitted: Dict[int, Dict[str, Any]]     # rid -> SUBMIT payload
    terminal: Dict[int, str]                 # rid -> RETIRE|REJECT|FAIL
    retired_tokens: Dict[int, List[int]]     # rid -> final tokens
    emitted: Dict[int, int]                  # rid -> last cumulative count
    admitted: set                            # rids that ever held a slot
    preemptions: Dict[int, int]              # rid -> PREEMPT count
    last_checkpoint: Optional[Dict[str, Any]]   # last CHECKPOINT record
    rounds_after_checkpoint: int
    tokens_after_checkpoint: int
    next_rid: int
    last_round: int = 0                      # highest committed round seen

    def pending(self) -> List[int]:
        """Rids with a SUBMIT but no terminal outcome, in rid order."""
        return sorted(r for r in self.submitted if r not in self.terminal)


def replay(records: List[Dict[str, Any]]) -> JournalState:
    """Fold the journal into a :class:`JournalState`.  Records from
    *before* the latest RECOVER marker are still folded — rids are stable
    across process generations — but checkpoint bookkeeping restarts at
    each CHECKPOINT *and* each RECOVER record: a recovery re-commits the
    replayed rounds under fresh rnd numbers, so counting generation N's
    post-checkpoint rounds alongside generation N+1's re-commits would
    double-count the same logical rounds after a second crash."""
    st = JournalState(submitted={}, terminal={}, retired_tokens={},
                      emitted={}, admitted=set(), preemptions={},
                      last_checkpoint=None, rounds_after_checkpoint=0,
                      tokens_after_checkpoint=0, next_rid=0)
    emitted_at_ckpt: Dict[int, int] = {}
    for rec in records:
        kind = rec.get("kind")
        if kind == "SUBMIT":
            st.submitted[rec["rid"]] = rec
            st.next_rid = max(st.next_rid, rec["rid"] + 1)
        elif kind == "ADMIT":
            st.admitted.add(rec["rid"])
        elif kind == "ROUND_COMMIT":
            st.rounds_after_checkpoint += 1
            st.last_round = max(st.last_round, int(rec["rnd"]))
            for rid, n in rec["emitted"].items():
                st.emitted[int(rid)] = int(n)
        elif kind == "RETIRE":
            st.terminal[rec["rid"]] = kind
            st.retired_tokens[rec["rid"]] = list(rec["tokens"])
        elif kind in ("REJECT", "FAIL"):
            st.terminal[rec["rid"]] = kind
        elif kind == "PREEMPT":
            st.preemptions[rec["rid"]] = (
                st.preemptions.get(rec["rid"], 0) + 1)
        elif kind == "CHECKPOINT":
            st.last_checkpoint = rec
            st.rounds_after_checkpoint = 0
            emitted_at_ckpt = dict(st.emitted)
        elif kind == "RECOVER":
            # the new generation replays from the checkpoint and
            # re-commits those rounds; only its own commits count as
            # replay work from here on (the emitted-token baseline stays
            # at the checkpoint — counts are cumulative, so the re-
            # committed rounds overwrite rather than add)
            st.rounds_after_checkpoint = 0
    st.tokens_after_checkpoint = sum(
        n - emitted_at_ckpt.get(rid, 0) for rid, n in st.emitted.items()
        if n > emitted_at_ckpt.get(rid, 0))
    return st


@dataclasses.dataclass
class RecoverySummary:
    """What a :meth:`MultiTenantScheduler.recover` call did."""
    checkpoint_step: Optional[int]
    restored_live: int               # slots rebuilt into the fresh pool
    restored_swapped: int            # host-tier records re-parked
    requeued: int                    # journaled-never-recovered rids
    already_complete: Dict[int, List[int]]   # retired pre-checkpoint
    replay_check: Dict[int, List[int]]   # retired post-ckpt: replay oracle
    rounds_replayed: int             # committed rounds past the checkpoint
    tokens_preserved: int            # tokens carried by restored records
    tokens_replayed: int             # emitted post-checkpoint, re-decoded
