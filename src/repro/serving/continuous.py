"""Continuous batching over a persistent slot table (paged KV-cache decode).

The slot-based scheduler serves one tenant batch at a time: the device runs
that batch's scanned decode to completion, padded rows and all, before the
next tenant's batch starts.  :class:`ContinuousBatchingEngine` instead keeps
a fixed-capacity *slot table* resident on the device and interleaves three
events per outer step, the serving analogue of the paper's fine-grained
multi-tenant sharing:

* **admission** — queued requests are prefilled at their (page-aligned)
  prompt bucket, their KV written into :class:`repro.serving.kvcache.
  PagedKVCache` pages, and their sampling state (per-request temperature /
  top-k / PRNG key, last logits, position, remaining budget) scattered into
  free slot rows.  Same-bucket admissions are *batched* into one prefill
  call (width padded to a power of two, so admission compiles once per
  (bucket, width tier) instead of once per request), and with
  ``prefix_sharing`` each request's longest chain of already-registered
  full-prefix blocks is mapped onto existing pages instead of fresh ones —
  a request whose whole padded prompt is registered (and whose prefill
  logits are still cached) skips its prefill call entirely;
* **one decode micro-round** — a single jitted ``lax.scan`` of
  ``inner_steps`` masked decode steps over *all* capacity rows.  The step is
  shape-stable (paged gather/scatter, fixed capacity), so ragged
  ``max_new_tokens`` mixes and mixed prompt buckets never retrace it: one
  compile per batch capacity, plus one prefill/admission compile per prompt
  bucket (``decode_traces`` / ``admit_traces`` count them for the tests);
* **retirement** — rows whose token budget ran out are collected on the
  host, their pages' refcounts dropped (a page returns to the free list only
  when its last reader retires; trie-registered pristine pages linger as
  evictable cache), their slots freed for the next admission.

Rows are masked, not compacted: an inactive row samples into the void (its
emission is dropped), writes its K/V to the reserved TRASH page and keeps
its SSM state frozen, so retirement costs no reshape or recompile — that is
the "masked fixed-step scan with early-exit accounting" deferred from PR 2.

Copy-on-write rides the dispatch path: decode writes land at ``pos % ring``,
so the blocks a round will write are known on the host before the round's
jit runs.  :meth:`ContinuousBatchingEngine.dispatch_round` resolves each of
them through :meth:`repro.serving.kvcache.PagedKVCache.note_write` — a
shared page is forked (one jitted page-copy + page-table remap per fork)
before any row can write into it, so the round's scan itself never needs
refcounts and stays one compile per (capacity, sampling tier).

The paged-pool state pytree is *donated* to the round / admission / CoW
jits (``donate_argnums``): XLA updates the pools in place instead of copying
the whole pool every micro-round, and the tests pin that down by checking
the old state buffers are deleted after a round.

``backend`` selects how the round's jit reads the paged pool: ``"jnp"``
gathers each row's full logical window into a dense ``[C, bucket, Hkv, D]``
tensor per decode step (the PR-3 path, kept as the A/B baseline and
numerics oracle), ``"pallas"`` streams page-sized KV blocks in place
through the fused paged-attention kernel (page-table indexing inside the
kernel grid, online softmax across pages — O(live pages) bytes per round
instead of O(capacity x bucket)) and scatters admission KV page-granularly
(see :mod:`repro.kernels.paged_attention`).  Both backends share every
other part of the engine — allocator, CoW, donation, compile-count
contract — and greedy decode is token-exact across them
(``tests/test_paged_attention.py``).

Compile-count contract: one decode-round trace per (capacity, sampling
tier); one admission-scatter trace per (prompt bucket, ring); one prefill
trace per (prompt bucket, power-of-two admission width); one trace each for
the CoW page-copy and the skip-prefill admission variant (per page-table
width).  ``decode_traces`` / ``admit_traces`` / ``prefill_traces`` /
``admit_skip_traces`` count them for the tests.

Greedy token-exactness: an admitted request decodes through exactly the same
prefill (same left-padded bucket prompt; batched prefill rows are
bitwise row-independent) and per-token math (see
:func:`repro.serving.kvcache.paged_attention_decode`) as
``ServingEngine.generate`` on that padded prompt, with the same
``PRNGKey(seed)`` / ``fold_in(key, local_step)`` schedule — so each row's
tokens match the blocking engine row-for-row, independent of what its
neighbours in the slot table are doing (``tests/test_continuous.py``).
Prefix sharing preserves this bit-for-bit: a block is shared only when the
whole padded prompt up to its end is byte-identical (so the page already
holds exactly what this request's prefill would have written), forks copy
pages before the first divergent write, and cached admission logits are the
stored output of the identical earlier prefill.

Preemption (overload survival, PR 6): a live row can be *swapped out* —
its page blocks and entire per-slot decode state snapshotted to a
:class:`repro.serving.swap.HostSwapStore`, its pages freed through the
ordinary allocator accounting (shared prefix pages stay under their other
readers; only the victim's private suffix is uniquely host-held), and its
slot vacated for a higher-priority admission.  :meth:`ContinuousBatching
Engine.try_restore` later re-admits it: still-registered unwritten prefix
blocks are re-shared straight from the trie, everything else stages back
through the swap store's sequential :class:`repro.core.transfer.
StagingEngine` (prefetched ahead of re-admission), and the slot's scalars
(pos / remaining / lstep / PRNG key / last logits) are rebuilt bitwise — so
the resumed decode is token-exact with an uninterrupted run.  Preemption
requires a quiesced engine (no round in flight) — the scheduler
force-collects first.

State kinds (PR 9): every arch in ``configs/`` serves continuously.  The
slot table's per-request state decomposes into the kinds registered by
:func:`repro.serving.kvcache.state_kinds` — ``attn`` (the paged KV above,
bitwise-unchanged for pure-attention archs), ``cross`` (encoder-decoder
cross-attention KV, paged into the pool's separate per-request cross space:
written once at admission from the batched prefill, gathered read-only by
every decode step, snapshot/restored verbatim on preemption) and ``ssm``
(slot-table SSM state, checkpointed as fixed-width records by
:func:`repro.models.ssm.checkpoint_slot_state` on swap-out and scattered
back on restore).  ``can_preempt`` derives from the kinds — every kind is
swappable, so any arch with swap enabled preempts, SSM/hybrid rows
included.  Sliding-window archs participate in prefix sharing through
window-phase chain keys (see :meth:`repro.serving.kvcache.PagedKVCache.
chain_keys`).  The skip-prefill fast path stays gated to pure-attention
archs: it is the one admission variant that must rebuild *every* per-slot
state from pages plus cached logits alone.  :meth:`ContinuousBatchingEngine.
supported_modes` is the public capability probe per arch
(``launch/serve.py --list-archs``).

MoE routing couples rows through expert capacity, so MoE archs run
continuously but are only *statistically* exchangeable with the blocking
engine, not bitwise — batched admission prefill and prefix sharing sit
inside the same caveat (expert-capacity routing couples prefill rows, so a
shared page holds *a* valid prefill of its chain, not necessarily the one a
solo prefill of this request would produce).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ATTN, MOE, NONE, ArchConfig
from repro.distributed.fault import InjectedFault
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_cross_attention, apply_embedding,
                                 apply_mlp, apply_rmsnorm, apply_unembed,
                                 pad_vocab)
from repro.obs.telemetry import Telemetry, get_telemetry
from repro.serving.engine import (ServingEngine, resolve_extra_inputs,
                                  sample_rows)
from repro.serving.kvcache import (BACKENDS, POS_SENTINEL, PagedKVCache,
                                   paged_attention_decode, paged_scatter,
                                   ssm_subs, state_kinds)
from repro.serving.swap import HostSwapStore, SwapRecord


@dataclasses.dataclass
class _Slot:
    """Host-side record of one occupied slot-table row."""
    req: Any                       # duck-typed: .prompt/.max_new_tokens/...
    target: int
    temp: float                    # resolved sampling params, mirrored on
    top_k: int                     # the host so dispatch_round can pick the
    bucket: int = 0                # static sampling tier
    ring: int = 0
    planned: int = 0               # decode steps already dispatched (the
    tokens: List[int] = dataclasses.field(  # CoW write scan runs at dispatch)
        default_factory=list)
    priority: int = 1              # 0 = highest; victims are picked among
    preemptions: int = 0           # strictly lower tiers only
    chain_keys: List[bytes] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None  # wall stamp of the first collected token


@dataclasses.dataclass
class RoundHandle:
    """One dispatched (not yet collected) decode micro-round."""
    emitted: jax.Array             # (steps, C) int32, -1 where row inactive
    act: jax.Array                 # (steps, C) bool
    steps: int
    t_start: float
    t_dispatched: float
    rnd: int = -1                  # round ordinal, for the round-span event

    def ready(self) -> bool:
        """Non-blocking probe: has the round's device work finished?
        Conservative (False) for duck-typed stand-ins without a probe."""
        is_ready = getattr(self.emitted, "is_ready", None)
        return bool(is_ready()) if is_ready is not None else False


@dataclasses.dataclass
class CollectResult:
    finished: List[Tuple[Any, np.ndarray, int]]   # (request, tokens, slot)
    active_steps: np.ndarray       # (C,) decode steps each row was live for
    slot_reqs: List[Optional[Any]]  # slot -> request, pre-retirement snapshot
    # retired slot records aligned with `finished` (TTFT stamp, preemption
    # count); a separate list so `finished` keeps its 3-tuple shape
    retired: List[Any] = dataclasses.field(default_factory=list)


class ContinuousBatchingEngine:
    """Masked fixed-step scan decode over a persistent slot table.

    Drive it either through :class:`repro.serving.multitenant.
    MultiTenantScheduler` (``mode="continuous"``) or directly::

        eng = ContinuousBatchingEngine(engine, capacity=4)
        for req, tokens in eng.run_all(requests): ...
    """

    def __init__(self, engine: ServingEngine, capacity: int = 8,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 inner_steps: int = 4, max_prompt_len: int = 128,
                 prefix_sharing: bool = True,
                 preserve_pristine: Any = True,
                 batch_admission: bool = True,
                 logits_cache_size: int = 32,
                 backend: Optional[str] = None,
                 pallas_interpret: bool = True,
                 swap: bool = True,
                 swap_store: Optional[HostSwapStore] = None,
                 fault_plane: Optional[Any] = None,
                 admission_retry_limit: int = 8,
                 telemetry: Optional[Telemetry] = None):
        cfg = engine.cfg
        self.engine = engine
        self.cfg = cfg
        self.sh = engine.sh
        self.params = engine.params
        self.bundle = engine.bundle
        self.capacity = capacity
        self.inner_steps = inner_steps
        self.max_prompt_len = max_prompt_len
        self.n_stages = cfg.num_layers // cfg.stage_period
        self.sched = cfg.block_schedule()[:cfg.stage_period]
        self.page_size = page_size
        # the per-request state kinds this arch's rows carry (attn / cross /
        # ssm) — capability flags below all derive from this tuple
        self.state_kinds = state_kinds(cfg)
        self.ssm_subs = ssm_subs(cfg)
        # enc-dec: the whole encoder output's cross KV pages per request,
        # written once at admission into the pool's separate cross space
        self.cross_blocks = (-(-cfg.encoder_seq_len // page_size)
                             if cfg.enc_dec else 0)
        max_ring = self._ring_len(self.bucket_len(max_prompt_len))
        self.kv = PagedKVCache(cfg, capacity, page_size,
                               -(-max_ring // page_size), num_pages,
                               cross_blocks=self.cross_blocks)
        # prefix sharing needs a refcounted attention page space; sliding-
        # window archs share through window-phase chain keys (the ring
        # layout is part of block identity, see PagedKVCache.chain_keys)
        self.prefix_sharing = bool(prefix_sharing and self.kv.attn_subs)
        # pristine-preserve policy: False = never copy; True (default) =
        # reuse-aware (preserve a sole-owner registered page only once its
        # chain has recorded a sharing hit); "always" = PR-4 behaviour
        # (one page copy per admission even on share-nothing traffic)
        self.preserve_pristine = preserve_pristine
        self.batch_admission = batch_admission
        # paged-attention backend: "jnp" gathers the dense logical window
        # per decode step (A/B baseline), "pallas" streams pages in place
        # through the fused kernels; inherited from the engine when unset
        if backend is None:
            backend = getattr(engine, "kernel_backend", "jnp")
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r}: must be one of "
                             f"{BACKENDS}")
        self.backend = backend
        self.pallas_interpret = pallas_interpret
        # skip-prefill full hits need every per-slot state to be
        # reconstructable from shared pages + cached logits alone: cross
        # pages are per-request and SSM states are neither paged nor
        # cached, so only pure-attention archs ever skip a prefill
        self._pure_attn = {k.name for k in self.state_kinds} == {"attn"}
        self.logits_cache_size = int(logits_cache_size)
        self._logits_cache: "collections.OrderedDict[bytes, jax.Array]" = \
            collections.OrderedDict()
        self.state = self._init_state()
        self._slots: List[Optional[_Slot]] = [None] * capacity
        self._free_slots: List[int] = list(range(capacity - 1, -1, -1))
        # preemption (KV tiering): derived from the registered state kinds
        # — every kind is swappable (attn/cross pages snapshot as blocks,
        # SSM states as fixed-width records), so any arch preempts when
        # swap is enabled
        self.fault_plane = fault_plane
        self.can_preempt = bool(swap) and all(
            k.swappable for k in self.state_kinds)
        self.swap_store = (swap_store if swap_store is not None
                           else (HostSwapStore(fault_plane=fault_plane,
                                               sharder=self.sh)
                                 if self.can_preempt else None))
        # lane/shard ordinal for telemetry: the mesh slice this engine's
        # slot table is committed to (0 on the single-device path)
        self.pdev = (min(d.id for d in self.sh.mesh.devices.reshape(-1))
                     if self.sh.mesh is not None else 0)
        self.admission_retry_limit = int(admission_retry_limit)
        self.rejected: List[Any] = []   # run_all's terminal REJECTED requests
        # trace counters: python side effects run only while jit traces
        self.decode_traces = 0
        self.admit_traces = 0
        self.admit_skip_traces = 0
        self.prefill_traces = 0
        self.restore_traces = 0
        self.prefill_calls = 0     # host-side prefill invocations (batched)
        self.prefill_skips = 0     # admissions served from the logits cache
        self.rounds = 0
        self.row_steps = 0         # sum over rounds of live rows per step
        self.preemptions = 0
        self.restores = 0
        # telemetry plane (the global one unless injected); the per-round
        # (steps, capacity, live_steps) log mirrors the ``round.device``
        # span events and is what occupancy() is derived from — it is
        # engine accounting, kept even when the plane is disabled
        self.tel = get_telemetry(telemetry)
        self._round_log: List[Tuple[int, int, int]] = []
        # pool + swap store report onto the same plane
        self.kv.tel = self.tel
        if self.swap_store is not None and swap_store is None:
            self.swap_store.retarget_telemetry(self.tel)
        self._build_jits()

    # ------------------------------------------------------------------
    def bucket_len(self, prompt_len: int) -> int:
        """Prompts are left-padded to a page-aligned bucket so admission
        (prefill + KV scatter) compiles once per bucket, not per length."""
        p = self.page_size
        return max(p, -(-prompt_len // p) * p)

    def _ring_len(self, bucket: int) -> int:
        w = self.cfg.sliding_window
        return min(bucket, w) if w is not None else bucket

    def active_count(self) -> int:
        return sum(s is not None for s in self._slots)

    def live_after(self, steps: int) -> bool:
        """Will any current row still be live after ``steps`` more decode
        steps?  Host-side: a row's collected tokens exclude any in-flight
        round, so with one round of ``steps`` in flight this answers "is a
        follow-up round worth dispatching" — False means pipelining another
        round would decode an all-masked slot table."""
        return any(s is not None and s.target - len(s.tokens) > steps
                   for s in self._slots)

    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def live_priorities(self) -> List[int]:
        """Priorities of every live row, in no particular order.  Public
        accessor so schedulers never depend on the slot-table layout (which
        the mesh-sharded engine is free to rearrange)."""
        return [s.priority for s in self._slots if s is not None]

    def occupancy(self) -> float:
        """Fraction of row-steps that decoded a live row, over *collected*
        micro-rounds.

        Derived from the per-round span events recorded at collect time
        (``round.device``: steps, capacity, live row-steps), not from the
        ``rounds`` counter — ``rounds`` increments at dispatch while
        ``row_steps`` lags until collect, so the old
        ``row_steps / (rounds * inner_steps * capacity)`` quotient counted
        a dispatched-but-uncollected round's masked rows in the
        denominator and deflated occupancy whenever it was read with a
        round in flight (exactly the retire-before-dispatch fast path's
        steady state, and any periodic stats line).  On a drained engine
        the two agree (tests/test_obs.py pins old == new on an all-live
        round)."""
        total = sum(steps * cap for steps, cap, _ in self._round_log)
        if not total:
            return 0.0
        return sum(live for _, _, live in self._round_log) / total

    @classmethod
    def supported_modes(cls, cfg: ArchConfig) -> Dict[str, Dict[str, Any]]:
        """Capability probe: what each serving mode offers for ``cfg``.

        Every arch in ``configs/`` serves under every mode (PR 9) — the
        probe's job is the *qualifiers*: which state kinds the slot table
        carries, whether rows can be swap-preempted, whether prefix sharing
        applies (and through window-phase keys on sliding-window archs),
        and whether continuous decode is bitwise or only statistically
        exchangeable with the blocking reference (MoE capacity routing
        couples rows).  ``launch/serve.py --list-archs`` renders this table
        without instantiating any engine."""
        kinds = state_kinds(cfg)
        names = [k.name for k in kinds]
        moe = any(mlp == MOE for _, mlp in cfg.block_schedule())
        cont = {
            "supported": True,
            "state_kinds": names,
            "preemptable": all(k.swappable for k in kinds),
            "prefix_sharing": "attn" in names,
            "window_phase_keys": ("attn" in names
                                  and cfg.sliding_window is not None),
            "exactness": "statistical" if moe else "bitwise",
        }
        return {"blocking": {"supported": True, "exactness": "reference"},
                "overlapped": {"supported": True,
                               "exactness": cont["exactness"]},
                "continuous": cont}

    # ------------------------------------------------------------------
    def _init_state(self) -> Dict[str, Any]:
        cfg, c = self.cfg, self.capacity
        caches: Dict[str, Any] = dict(self.kv.make_pools(self.n_stages))
        for i, (mixer, _) in enumerate(self.sched):
            if mixer != ATTN:
                st = ssm_mod.init_ssm_state(cfg, c)
                caches[f"sub{i}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self.n_stages,) + a.shape), st)
        if self.cross_blocks:
            caches["cross"] = self.kv.make_cross_pools(self.n_stages)
        st = {
            "caches": caches,
            "page_table": self.kv.make_page_table(),
            "pos_pool": self.kv.make_pos_pool(),
            "logits": jnp.zeros((c, pad_vocab(cfg.vocab_size)), jnp.float32),
            "pos": jnp.zeros((c,), jnp.int32),
            "ring": jnp.ones((c,), jnp.int32),
            "remaining": jnp.zeros((c,), jnp.int32),
            "temps": jnp.zeros((c,), jnp.float32),
            "topks": jnp.zeros((c,), jnp.int32),
            "keys": jnp.zeros((c, 2), jnp.uint32),
            "lstep": jnp.zeros((c,), jnp.int32),
        }
        if self.cross_blocks:
            # per-slot cross page rows (the cross space's page table)
            st["cross_pt"] = jnp.full((c, self.cross_blocks),
                                      PagedKVCache.SENTINEL, jnp.int32)
        if self.sh.mesh is not None:
            # commit the slot-table pytree onto the mesh up front: the KV
            # pools (self- and cross-attention) partition along KV heads,
            # everything else replicates.  Donation then keeps every
            # round's output on the same layout, so nothing reshards
            # mid-serve and jit never sees mixed-device committed inputs.
            st = jax.tree.map(
                lambda a: self.sh.place(a, (None,) * a.ndim), st)
            for name in self.kv.attn_subs:
                st["caches"][name] = {
                    k: self.sh.place(v, (None, None, None, "kv", None))
                    for k, v in st["caches"][name].items()}
            if self.cross_blocks:
                st["caches"]["cross"] = {
                    k: self.sh.place(v, (None, None, None, "kv", None))
                    for k, v in st["caches"]["cross"].items()}
        return st

    # ------------------------------------------------------------------
    def _build_jits(self) -> None:
        cfg, sh = self.cfg, self.sh
        sched = self.sched
        p_sz = self.kv.page_size
        trash = PagedKVCache.TRASH
        has_attn = bool(self.kv.attn_subs)
        enc_dec = bool(self.cross_blocks)
        backend, interp = self.backend, self.pallas_interpret

        def decode_step(params, st, all_greedy, any_topk):
            active = st["remaining"] > 0
            tok = sample_rows(st["logits"], st["temps"], st["topks"],
                              st["keys"], all_greedy=all_greedy,
                              any_topk=any_topk)
            pos, ring, pt = st["pos"], st["ring"], st["page_table"]
            if has_attn:
                slot_log = jnp.mod(pos, ring)
                blk = slot_log // p_sz
                off = jnp.mod(slot_log, p_sz)
                page = jnp.take_along_axis(pt, blk[:, None], axis=1)[:, 0]
                page = jnp.where(active, page, trash)
                pos_pool = st["pos_pool"].at[page, off].set(
                    jnp.where(active, pos, POS_SENTINEL))
                # the fused kernel reads positions per page in place; only
                # the dense-gather backend materialises the (C, L) view
                kpos = (None if backend == "pallas"
                        else pos_pool[pt].reshape(pt.shape[0], -1))
            else:
                page = off = kpos = None
                pos_pool = st["pos_pool"]

            x = apply_embedding(params["embed"], tok[:, None], cfg, sh)
            if not cfg.use_rope:
                # _sinusoid_at broadcasts (C, 1, 1) positions to (C, 1, d)
                # — the per-row twin of decode_fn's scalar call
                from repro.models.model import _sinusoid_at
                x = x + _sinusoid_at(pos[:, None, None],
                                     cfg.d_model).astype(x.dtype)

            if enc_dec:
                # encoder-decoder body: paged self-attention, then a
                # read-only gather of the slot's cross KV pages — the
                # per-row twin of decode_fn's (dec_stages, self, cross)
                # scan, same residual structure operation for operation
                S_enc = cfg.encoder_seq_len
                nbc = self.cross_blocks
                aname = self.kv.attn_subs[0]
                cpt = st["cross_pt"]

                def body(h, xs):
                    sp, self_cache, ck_pool, cv_pool = xs
                    a, nci = paged_attention_decode(
                        sp["attn"], apply_rmsnorm(sp["norm1"], h),
                        self_cache, pt, kpos, page, off, pos, cfg, sh,
                        pos_pool=pos_pool, backend=backend, interpret=interp)
                    h = h + a
                    # (C, nbc, P, Hkv, D) -> (C, S_enc, Hkv, D): the pool
                    # pads past S_enc with zeros the static slice drops, so
                    # the gathered view is bitwise the prefill's cross KV
                    ck = ck_pool[cpt].reshape(
                        cpt.shape[0], nbc * p_sz,
                        *ck_pool.shape[-2:])[:, :S_enc]
                    cv = cv_pool[cpt].reshape(
                        cpt.shape[0], nbc * p_sz,
                        *cv_pool.shape[-2:])[:, :S_enc]
                    c_out = apply_cross_attention(
                        sp["cross"], apply_rmsnorm(sp["norm_c"], h),
                        (ck.astype(h.dtype), cv.astype(h.dtype)), cfg, sh)
                    h = h + c_out
                    m = apply_mlp(sp["mlp"], apply_rmsnorm(sp["norm2"], h),
                                  cfg, sh)
                    return h + m, nci

                cross = st["caches"]["cross"]
                h, new_self = jax.lax.scan(
                    body, x, (params["dec_stages"], st["caches"][aname],
                              cross["k"], cross["v"]))
                new_caches = {aname: new_self, "cross": cross}
            else:
                def body(h, xs):
                    stage_params, stage_cache = xs
                    nc = {}
                    for i, (mixer, mlp) in enumerate(sched):
                        sub = stage_params[f"sub{i}"]
                        hin = apply_rmsnorm(sub["norm1"], h)
                        if mixer == ATTN:
                            hout, nci = paged_attention_decode(
                                sub["attn"], hin, stage_cache[f"sub{i}"], pt,
                                kpos, page, off, pos, cfg, sh,
                                pos_pool=pos_pool, backend=backend,
                                interpret=interp)
                        else:
                            hout, nci = ssm_mod.apply_ssm_decode(
                                sub["mamba"], hin, stage_cache[f"sub{i}"],
                                cfg, sh)
                            # frozen state for masked rows (attention rows
                            # are masked by redirecting their write to
                            # TRASH instead)
                            nci = jax.tree.map(
                                lambda new, old: jnp.where(
                                    active.reshape(
                                        (-1,) + (1,) * (new.ndim - 1)),
                                    new, old),
                                nci, stage_cache[f"sub{i}"])
                        nc[f"sub{i}"] = nci
                        h = h + hout
                        if mlp != NONE:
                            hin = apply_rmsnorm(sub["norm2"], h)
                            if mlp == MOE:
                                hout, _ = moe_mod.apply_moe(sub["moe"], hin,
                                                            cfg, sh)
                            else:
                                hout = apply_mlp(sub["mlp"], hin, cfg, sh)
                            h = h + hout
                    return h, nc

                h, new_caches = jax.lax.scan(
                    body, x, (params["stages"], st["caches"]))
            h = apply_rmsnorm(params["final_norm"], h)
            new_logits = apply_unembed(params["embed"], h, cfg, sh)[:, 0]

            if all_greedy:               # keys unused by every live row
                keys = st["keys"]
            else:
                keys_next = jax.vmap(jax.random.fold_in)(st["keys"],
                                                         st["lstep"])
                keys = jnp.where(active[:, None], keys_next, st["keys"])
            new_st = {
                **st,
                "caches": new_caches,
                "pos_pool": pos_pool,
                "logits": jnp.where(active[:, None], new_logits,
                                    st["logits"]),
                "pos": pos + active,
                "remaining": st["remaining"] - active,
                "keys": keys,
                "lstep": st["lstep"] + active,
            }
            return new_st, (jnp.where(active, tok, -1), active)

        def round_fn(params, st, *, steps: int, all_greedy: bool,
                     any_topk: bool):
            self.decode_traces += 1          # incremented at trace time only
            self.tel.count("trace.decode")
            st, (emitted, act) = jax.lax.scan(
                lambda c, _: decode_step(params, c, all_greedy, any_topk),
                st, None, length=steps)
            return st, emitted, act

        # the slot-table state pytree is donated everywhere it is threaded
        # through a jit: XLA aliases the page pools input->output and
        # updates them in place instead of copying the whole pool per call
        # (the donation test asserts the old buffers die)
        self._round_jit = jax.jit(
            round_fn, static_argnames=("steps", "all_greedy", "any_topk"),
            donate_argnums=(1,))

        def prefill_fn(params, batch):
            self.prefill_traces += 1
            self.tel.count("trace.prefill")
            return self.bundle.prefill_fn(params, batch, sh)

        self._prefill_jit = jax.jit(prefill_fn)

        def cow_fn(st, src, dst, slot, blk):
            """Copy-on-write fork: copy page ``src`` -> ``dst`` in every
            attention pool and the position pool, and repoint the writer's
            page-table entry.  All operands dynamic: compiles once."""
            new = dict(st)
            nc = dict(st["caches"])
            for name in self.kv.attn_subs:
                pool = st["caches"][name]
                nc[name] = {"k": pool["k"].at[:, dst].set(pool["k"][:, src]),
                            "v": pool["v"].at[:, dst].set(pool["v"][:, src])}
            new["caches"] = nc
            new["pos_pool"] = st["pos_pool"].at[dst].set(st["pos_pool"][src])
            new["page_table"] = st["page_table"].at[slot, blk].set(dst)
            return new

        self._cow_jit = jax.jit(cow_fn, donate_argnums=(0,))

        def admit_skip_fn(st, logits0, slot, pages, remaining, temp, topk,
                          key, bucket, ring):
            """Skip-prefill admission (full prefix hit): every KV block is
            already resident in shared pages and the first-token logits come
            from the cache, so only the page-table row and the slot's
            sampling state are written.  bucket/ring are dynamic: one trace
            per page-row width."""
            self.admit_skip_traces += 1
            self.tel.count("trace.admit_skip")
            new = dict(st)
            row = jnp.full((self.kv.max_blocks,), PagedKVCache.SENTINEL,
                           jnp.int32).at[:pages.shape[0]].set(pages)
            new["page_table"] = st["page_table"].at[slot].set(row)
            new["logits"] = st["logits"].at[slot].set(logits0)
            new["pos"] = st["pos"].at[slot].set(bucket)
            new["ring"] = st["ring"].at[slot].set(ring)
            new["remaining"] = st["remaining"].at[slot].set(remaining)
            new["temps"] = st["temps"].at[slot].set(temp)
            new["topks"] = st["topks"].at[slot].set(topk)
            new["keys"] = st["keys"].at[slot].set(key)
            new["lstep"] = st["lstep"].at[slot].set(0)
            return new

        self._admit_skip_jit = jax.jit(admit_skip_fn, donate_argnums=(0,))

        def admit_fn(st, caches_p, logits0, slot, pages, cross_pages,
                     remaining, temp, topk, key, *, bucket: int, ring: int):
            self.admit_traces += 1
            self.tel.count("trace.admit")
            new = dict(st)
            # enc-dec prefill returns {"self": ..., "cross": ...}; remap the
            # self caches onto the (single) attention sublayer so the page
            # scatter below is kind-agnostic
            caches_attn = ({self.kv.attn_subs[0]: caches_p["self"]}
                           if enc_dec else caches_p)
            nb = pages.shape[0] if pages is not None else 0
            if nb:
                row = jnp.full((self.kv.max_blocks,), PagedKVCache.SENTINEL,
                               jnp.int32).at[:nb].set(pages)
                new["page_table"] = st["page_table"].at[slot].set(row)
                name = self.kv.attn_subs[0]
                pos_src = caches_attn[name]["pos"][0, 0]         # (ring,)
                pos_vals = jnp.full((nb * p_sz,), POS_SENTINEL,
                                    jnp.int32).at[:ring].set(pos_src)
                new["pos_pool"] = st["pos_pool"].at[pages].set(
                    pos_vals.reshape(nb, p_sz))
            nc = {}
            for i, (mixer, _) in enumerate(sched):
                sname = f"sub{i}"
                cur = st["caches"][sname]
                if mixer == ATTN:
                    def to_pages(leaf, pool_leaf):
                        # fused compute-then-scatter: the bucket's freshly
                        # prefilled KV goes straight into its allocated
                        # pages (page-granular on the pallas backend)
                        pad = nb * p_sz - ring
                        v = jnp.pad(leaf[:, 0],
                                    ((0, 0), (0, pad), (0, 0), (0, 0)))
                        v = v.reshape(self.n_stages, nb, p_sz,
                                      *leaf.shape[3:])
                        return paged_scatter(pool_leaf, pages, v,
                                             backend=backend,
                                             interpret=interp, sh=sh)
                    nc[sname] = {"k": to_pages(caches_attn[sname]["k"],
                                               cur["k"]),
                                 "v": to_pages(caches_attn[sname]["v"],
                                               cur["v"])}
                else:
                    nc[sname] = jax.tree.map(
                        lambda t, cp: t.at[:, slot].set(cp[:, 0]),
                        cur, caches_attn[sname])
            if enc_dec:
                # write-once cross KV scatter into the slot's private cross
                # pages (pool dtype == compute dtype: bitwise the prefill's
                # cross KV; the tail of the last page pads with zeros the
                # decode gather's static slice drops)
                S_enc = cfg.encoder_seq_len
                nbc = self.cross_blocks

                def cross_to_pages(leaf, pool_leaf):
                    v = jnp.pad(leaf[:, 0], ((0, 0), (0, nbc * p_sz - S_enc),
                                             (0, 0), (0, 0)))
                    v = v.reshape(self.n_stages, nbc, p_sz, *leaf.shape[3:])
                    return pool_leaf.at[:, cross_pages].set(
                        v.astype(pool_leaf.dtype))

                cross = st["caches"]["cross"]
                nc["cross"] = {
                    "k": cross_to_pages(caches_p["cross"]["k"], cross["k"]),
                    "v": cross_to_pages(caches_p["cross"]["v"], cross["v"])}
                new["cross_pt"] = st["cross_pt"].at[slot].set(cross_pages)
            new["caches"] = nc
            new["logits"] = st["logits"].at[slot].set(logits0[0])
            new["pos"] = st["pos"].at[slot].set(bucket)
            new["ring"] = st["ring"].at[slot].set(ring)
            new["remaining"] = st["remaining"].at[slot].set(remaining)
            new["temps"] = st["temps"].at[slot].set(temp)
            new["topks"] = st["topks"].at[slot].set(topk)
            new["keys"] = st["keys"].at[slot].set(key)
            new["lstep"] = st["lstep"].at[slot].set(0)
            return new

        self._admit_jit = jax.jit(admit_fn,
                                  static_argnames=("bucket", "ring"),
                                  donate_argnums=(0,))

        def evict_fn(st, slot):
            """Vacate a preempted (or terminally failed) row: zero its
            remaining budget and point its whole page-table row at SENTINEL,
            so the stale table can neither decode garbage nor address pages
            reallocated to newer requests.  All operands dynamic: one
            trace."""
            new = dict(st)
            new["remaining"] = st["remaining"].at[slot].set(0)
            new["page_table"] = st["page_table"].at[slot].set(
                jnp.full((self.kv.max_blocks,), PagedKVCache.SENTINEL,
                         jnp.int32))
            if enc_dec:
                new["cross_pt"] = st["cross_pt"].at[slot].set(
                    jnp.full((self.cross_blocks,), PagedKVCache.SENTINEL,
                             jnp.int32))
            return new

        self._evict_jit = jax.jit(evict_fn, donate_argnums=(0,))

        def restore_fn(st, kv_blocks, pos_rows, logits, slot, pages,
                       scatter_pages, pos, remaining, temp, topk, key,
                       lstep, ring, cross, state):
            """Swap-in: scatter a preempted request's snapshot blocks into
            freshly allocated pages and rebuild its slot row bitwise.
            ``pages`` is the full SENTINEL-padded page-table row and the
            snapshot is padded to the same width, so this traces ONCE
            whatever the victim's ring; ``scatter_pages`` redirects both
            the padding's and the re-shared blocks' writes to TRASH —
            re-shared device pages already hold the identical pristine
            content, and TRASH is never read as valid, exactly like
            masked-row writes.  ``cross`` / ``state`` are the per-kind
            halves of the record — ``{"kv", "pages"}`` for an enc-dec
            victim's cross pages, a sub->record tree for SSM slot state —
            and are None (empty pytrees, so still one trace) on archs
            without that kind."""
            self.restore_traces += 1
            self.tel.count("trace.restore")
            new = dict(st)
            new["page_table"] = st["page_table"].at[slot].set(pages)
            new["pos_pool"] = st["pos_pool"].at[scatter_pages].set(pos_rows)
            nc = dict(st["caches"])
            for name in self.kv.attn_subs:
                cur = st["caches"][name]
                nc[name] = {
                    "k": cur["k"].at[:, scatter_pages].set(
                        kv_blocks[name]["k"].astype(cur["k"].dtype)),
                    "v": cur["v"].at[:, scatter_pages].set(
                        kv_blocks[name]["v"].astype(cur["v"].dtype))}
            if cross is not None:
                cp = st["caches"]["cross"]
                nc["cross"] = {
                    n: cp[n].at[:, cross["pages"]].set(
                        cross["kv"][n].astype(cp[n].dtype))
                    for n in ("k", "v")}
                new["cross_pt"] = st["cross_pt"].at[slot].set(cross["pages"])
            if state is not None:
                for sname, leaves in state.items():
                    nc[sname] = ssm_mod.restore_slot_state(
                        st["caches"][sname], slot, leaves)
            new["caches"] = nc
            new["logits"] = st["logits"].at[slot].set(logits)
            new["pos"] = st["pos"].at[slot].set(pos)
            new["ring"] = st["ring"].at[slot].set(ring)
            new["remaining"] = st["remaining"].at[slot].set(remaining)
            new["temps"] = st["temps"].at[slot].set(temp)
            new["topks"] = st["topks"].at[slot].set(topk)
            new["keys"] = st["keys"].at[slot].set(key)
            new["lstep"] = st["lstep"].at[slot].set(lstep)
            return new

        self._restore_jit = jax.jit(restore_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def try_admit(self, req: Any) -> bool:
        """Admit one request into a free slot; False when no slot or no
        pages are available right now (caller keeps it queued)."""
        return self.try_admit_batch([req])[0]

    def try_admit_batch(self, reqs: List[Any]) -> List[bool]:
        """Admit up to ``len(self._free_slots)`` requests in one go.

        Three phases:

        1. *plan* — per request: bucket/ring, padded prompt, chain keys and
           a provisional full-prefix-hit probe (can this admission reuse
           cached prefill logits?);
        2. *prefill* — one batched prefill call per prompt bucket for every
           plan that cannot skip it, width padded to the next power of two
           (``batch_admission=False`` keeps the PR-3 one-call-per-request
           baseline); rows are sliced back out per request — batched prefill
           is bitwise row-independent, so this changes nothing downstream;
        3. *admit* — sequential per request: re-probe the trie (earlier
           members of this very batch have registered by now, so same-batch
           prefix sharing works), allocate shared+fresh pages, scatter KV /
           sampling state, register the new chain blocks.

        Returns one admitted-flag per request; rejected requests (slot or
        page pressure) are untouched and stay with the caller.  An injected
        admission stall (fault plane) raises before any prefill or page
        allocation, so the whole batch stays with the caller too.
        """
        if self.fault_plane is not None and reqs:
            self.fault_plane.admission_fault()
        with self.tel.span("admit.batch", n=len(reqs)) as admit_span:
            flags = self._try_admit_batch_inner(reqs)
            admit_span.note(admitted=sum(flags))
        return flags

    def _try_admit_batch_inner(self, reqs: List[Any]) -> List[bool]:
        flags = [False] * len(reqs)
        plans: List[Dict[str, Any]] = []
        for i, req in enumerate(reqs):
            if len(plans) >= len(self._free_slots):
                break
            prompt = np.asarray(req.prompt, np.int32).reshape(-1)
            if prompt.size > self.max_prompt_len:
                raise ValueError(
                    f"prompt of {prompt.size} tokens exceeds max_prompt_len="
                    f"{self.max_prompt_len}")
            bucket = self.bucket_len(prompt.size)
            ring = self._ring_len(bucket)
            padded = np.zeros((bucket,), np.int32)
            padded[bucket - prompt.size:] = prompt
            extra = resolve_extra_inputs(self.cfg, req)
            salt = b""
            if extra:
                # non-token prefill inputs (merged patch embeddings, encoder
                # frames) feed the prefilled KV, so they are part of block
                # identity: requests share pages only under identical extras
                dg = hashlib.sha256()
                for name in sorted(extra):
                    arr = np.ascontiguousarray(np.asarray(extra[name]))
                    dg.update(name.encode())
                    dg.update(arr.tobytes())
                salt = dg.digest()
            keys = (self.kv.chain_keys(padded, ring=ring, salt=salt)
                    if self.prefix_sharing else [])
            # provisional only — the authoritative share decision re-probes
            # at admit time; this just decides whether to prefill
            skip = bool(keys and self._pure_attn
                        and len(self.kv.lookup_chain(keys)) == len(keys)
                        and keys[-1] in self._logits_cache)
            plans.append(dict(i=i, req=req, bucket=bucket,
                              ring=ring, padded=padded, extra=extra,
                              keys=keys, skip=skip, logits=None,
                              caches=None))
        if not plans:
            return flags
        groups: Dict[Any, List[Dict[str, Any]]] = {}
        for pl in plans:
            if not pl["skip"]:
                gk = (pl["bucket"], tuple(sorted(pl["extra"])))
                groups.setdefault(gk, []).append(pl)
        for (bucket, extra_names), grp in groups.items():
            chunks = [grp] if self.batch_admission else [[pl] for pl in grp]
            for chunk in chunks:
                width = 1 << (len(chunk) - 1).bit_length()
                tokens = np.zeros((width, bucket), np.int32)
                for j, pl in enumerate(chunk):
                    tokens[j] = pl["padded"]
                batch = {"tokens": jnp.asarray(tokens)}
                for name in extra_names:
                    # stack the chunk's extras; padding rows are zeros (row-
                    # independent prefill: the pad rows are sliced away)
                    first = np.asarray(chunk[0]["extra"][name])
                    rows = ([np.asarray(pl["extra"][name]) for pl in chunk]
                            + [np.zeros_like(first)] * (width - len(chunk)))
                    batch[name] = jnp.asarray(np.stack(rows))
                with self.tel.span("admit.prefill", bucket=bucket,
                                   width=width, n=len(chunk)):
                    logits, caches, _ = self._prefill_jit(
                        self.params, batch)
                self.prefill_calls += 1
                self.tel.count("admit.prefill_calls")
                for j, pl in enumerate(chunk):
                    pl["logits"] = logits[j:j + 1]
                    pl["caches"] = jax.tree.map(lambda a, j=j: a[:, j:j + 1],
                                                caches)
        for pl in plans:
            flags[pl["i"]] = self._admit_planned(pl)
        return flags

    def _admit_planned(self, pl: Dict[str, Any]) -> bool:
        """Phase 3 of :meth:`try_admit_batch`: page mapping + state scatter
        for one planned request.  False leaves the allocator untouched."""
        req, bucket, ring = pl["req"], pl["bucket"], pl["ring"]
        kv = self.kv
        nb = kv.blocks_for(ring) if kv.attn_subs else 0
        shared: List[int] = []
        will_write: Any = ()
        target = int(req.max_new_tokens)
        if nb and self.prefix_sharing:
            shared = kv.lookup_chain(pl["keys"])[:nb]
            # blocks this request's decode ring-writes will touch: each is
            # charged one page of fork headroom at allocation time
            will_write = {((bucket + t) % ring) // self.page_size
                          for t in range(min(target, ring))}
        cached_logits = None
        if pl["skip"]:
            cached_logits = self._logits_cache.get(pl["keys"][-1])
            if len(shared) < nb or cached_logits is None:
                # the chain (or its logits) was evicted between planning and
                # admission: no prefill result to fall back on — requeue
                return False
            self._logits_cache.move_to_end(pl["keys"][-1])
        slot = self._free_slots[-1]
        pages = None
        if nb:
            pages = kv.alloc_shared(slot, shared, nb - len(shared),
                                    will_write)
            if pages is None:
                return False                 # pool pressure: retry later
        cross_pages = None
        if self.cross_blocks:
            cross_pages = kv.alloc_cross(slot)
            if cross_pages is None:
                if pages is not None:
                    kv.free(slot)            # undo the attn half
                return False                 # cross-space pressure
        self._free_slots.pop()
        temp = getattr(req, "temperature", None)
        if temp is None:
            temp = self.engine.temperature
        topk = int(getattr(req, "top_k", 0) or 0)
        key = jax.random.PRNGKey(int(getattr(req, "seed", 0) or 0))
        if pl["skip"]:
            self.state = self._admit_skip_jit(
                self.state, cached_logits, np.int32(slot),
                jnp.asarray(pages), np.int32(target), np.float32(temp),
                np.int32(topk), key, np.int32(bucket), np.int32(ring))
            self.prefill_skips += 1
            self.tel.count("admit.prefill_skips")
        else:
            self.state = self._admit_jit(
                self.state, pl["caches"], pl["logits"], slot,
                None if pages is None else jnp.asarray(pages),
                None if cross_pages is None else jnp.asarray(cross_pages),
                target, float(temp), topk, key, bucket=bucket, ring=ring)
            if self.prefix_sharing and self._pure_attn and pl["keys"]:
                self._logits_cache_put(pl["keys"][-1], pl["logits"][0])
        if self.prefix_sharing and pl["keys"]:
            kv.register(slot, pl["keys"][:nb])
        self._slots[slot] = _Slot(req, target, float(temp), topk,
                                  bucket=bucket, ring=ring,
                                  priority=int(getattr(req, "priority", 1)),
                                  chain_keys=list(pl["keys"][:nb]))
        return True

    def _logits_cache_put(self, key: bytes, row: jax.Array) -> None:
        cache = self._logits_cache
        cache[key] = row
        cache.move_to_end(key)
        while len(cache) > self.logits_cache_size:
            cache.popitem(last=False)

    # ------------------------------------------------------------------
    # decode micro-rounds
    # ------------------------------------------------------------------
    def _resolve_round_writes(self) -> None:
        """Pre-dispatch copy-on-write scan: the blocks each live row will
        write in the coming round are known on the host (``pos % ring``), so
        every shared or pristine-registered page among them is forked — page
        copied device-side, writer's table remapped — *before* the round's
        jit can touch it.  Without sharing, every page is exclusively owned
        and the scan is skipped entirely (PR-3 semantics)."""
        if not (self.prefix_sharing and self.kv.attn_subs):
            return
        preserve = bool(self.preserve_pristine)
        require_hit = self.preserve_pristine != "always"
        for c, s in enumerate(self._slots):
            if s is None:
                continue
            n = min(self.inner_steps, s.target - s.planned)
            if n <= 0:
                continue
            blks = sorted({((s.bucket + s.planned + t) % s.ring)
                           // self.page_size for t in range(n)})
            for blk in blks:
                fork = self.kv.note_write(c, blk, preserve=preserve,
                                          require_hit=require_hit)
                if fork is not None:
                    src, dst = fork
                    self.state = self._cow_jit(
                        self.state, np.int32(src), np.int32(dst),
                        np.int32(c), np.int32(blk))
            s.planned += n

    def dispatch_round(self) -> RoundHandle:
        """Enqueue one masked micro-round (non-blocking); the caller may
        admit the next requests while it runs on the device.  An injected
        round drop (fault plane) raises before the copy-on-write scan — the
        slot table is untouched, so a bare re-dispatch is sound."""
        if self.fault_plane is not None:
            self.fault_plane.round_fault()
        rnd = self.rounds
        with self.tel.span("round.dispatch", round=rnd, pdev=self.pdev):
            t0 = time.perf_counter()
            with self.tel.span("round.cow"):
                self._resolve_round_writes()
            # static sampling tier from the live rows (an all-greedy round
            # is a bare argmax; at most 3 round variants ever compile)
            live = [s for s in self._slots if s is not None]
            all_greedy = all(s.temp <= 0 for s in live)
            any_topk = any(s.top_k > 0 for s in live)
            with self.tel.span("round.jit", steps=self.inner_steps,
                               all_greedy=all_greedy):
                self.state, emitted, act = self._round_jit(
                    self.params, self.state, steps=self.inner_steps,
                    all_greedy=all_greedy, any_topk=any_topk)
            self.rounds += 1
        return RoundHandle(emitted, act, self.inner_steps, t0,
                           time.perf_counter(), rnd=rnd)

    def collect(self, handle: RoundHandle) -> CollectResult:
        """Materialise a round's emissions, append tokens to their rows and
        retire rows that hit their budget (pages evicted to the free list)."""
        emitted = np.asarray(handle.emitted)
        act = np.asarray(handle.act)
        slot_reqs = [s.req if s is not None else None for s in self._slots]
        active_steps = act.sum(axis=0).astype(np.int64)
        live_steps = int(active_steps.sum())
        self.row_steps += live_steps
        # the round-span event: the dispatch->materialised device window
        # with its live/total row-step split.  occupancy() and the
        # scheduler's busy split derive from this log, not from the
        # dispatch-time ``rounds`` counter
        self._round_log.append((handle.steps, self.capacity, live_steps))
        self.tel.record_span("round.device", handle.t_start,
                             time.perf_counter(), round=handle.rnd,
                             steps=handle.steps, capacity=self.capacity,
                             live_steps=live_steps, pdev=self.pdev)
        finished: List[Tuple[Any, np.ndarray, int]] = []
        retired: List[_Slot] = []
        for c, s in enumerate(self._slots):
            if s is None:
                continue
            row = emitted[act[:, c], c]
            if row.size and s.t_first is None:
                # first token materialised on the host: the TTFT stamp
                # (survives preemption — a restored slot keeps its stamp)
                s.t_first = time.perf_counter()
            s.tokens.extend(int(t) for t in row)
            if len(s.tokens) >= s.target:
                finished.append((s.req,
                                 np.asarray(s.tokens[:s.target], np.int32),
                                 c))
                retired.append(s)
                self.kv.free(c)
                self._slots[c] = None
                self._free_slots.append(c)
        return CollectResult(finished, active_steps, slot_reqs, retired)

    # ------------------------------------------------------------------
    # preemption: swap-out / swap-in (KV tiering)
    # ------------------------------------------------------------------
    def _snapshot_slot(self, slot: int, preempting: bool = False
                       ) -> SwapRecord:
        """Host-gather one live slot as a :class:`SwapRecord` — a pure
        read (sharers, allocator and device state untouched), shared by
        :meth:`preempt` (which then vacates the slot) and
        :meth:`snapshot_live` (engine checkpoints, which don't).

        Caller contract: no decode round may be in flight, so the slot's
        collected tokens are caught up with its dispatched steps.
        ``preempting`` bumps the record's preemption count — a checkpoint
        snapshot is not a preemption."""
        s = self._slots[slot]
        if s is None:
            raise ValueError(f"slot {slot} is empty")
        if not self.can_preempt:
            raise RuntimeError(
                "engine cannot preempt: swap disabled or the arch "
                "registered an unswappable state kind")
        if self.prefix_sharing:
            assert s.planned == len(s.tokens), \
                "slot snapshot with a decode round in flight"
        kv, st = self.kv, self.state
        pages = np.asarray(kv.owned_pages(slot), np.int32)
        # snapshots are padded to the page-table width so the restore jit
        # sees one shape whatever the victim's ring (padding scatters to
        # TRASH and is never read back) — and the snapshot *gathers* here
        # index with the same fixed width, else each distinct victim page
        # count compiles its own device gather (a mid-trace stall the
        # first time a 1-page victim is preempted after a 2-page warm-up);
        # the pad gathers SENTINEL's content and is zeroed host-side
        mb, nb = kv.max_blocks, len(pages)
        padded = np.zeros(mb, np.int32)
        padded[:nb] = pages

        def grab(pool):
            arr = np.array(pool[:, padded])
            arr[:, nb:] = 0
            return arr

        host_kv = {name: {"k": grab(st["caches"][name]["k"]),
                          "v": grab(st["caches"][name]["v"])}
                   for name in kv.attn_subs}
        host_pos = np.array(st["pos_pool"][padded])
        host_pos[nb:] = POS_SENTINEL
        # per-kind halves of the snapshot: the cross row is always full
        # width (one gather shape per arch) and SSM slot state checkpoints
        # as fixed-width records — both pure reads, like the page gather
        host_cross = None
        n_cross = 0
        if self.cross_blocks:
            cpages = np.asarray(kv.cross_pages_of(slot), np.int32)
            host_cross = {n: np.array(st["caches"]["cross"][n][:, cpages])
                          for n in ("k", "v")}
            n_cross = len(cpages)
        host_state = None
        if self.ssm_subs:
            host_state = {sname: ssm_mod.checkpoint_slot_state(
                              st["caches"][sname], slot)
                          for sname in self.ssm_subs}
        n_state = len(self.ssm_subs)
        written = {((s.bucket + t) % s.ring) // self.page_size
                   for t in range(min(len(s.tokens), s.ring))}
        private = kv.private_blocks(slot)
        return SwapRecord(
            req=s.req, priority=s.priority, target=s.target, temp=s.temp,
            top_k=s.top_k, bucket=s.bucket, ring=s.ring,
            tokens=list(s.tokens), chain_keys=list(s.chain_keys),
            written=written, pos=int(st["pos"][slot]),
            remaining=int(st["remaining"][slot]),
            lstep=int(st["lstep"][slot]), key=np.asarray(st["keys"][slot]),
            logits=np.asarray(st["logits"][slot]), host_kv=host_kv,
            host_pos=host_pos, n_private=len(private),
            preemptions=s.preemptions + (1 if preempting else 0),
            t_first=s.t_first, host_cross=host_cross, n_cross=n_cross,
            host_state=host_state, n_state=n_state)

    def preempt(self, slot: int) -> int:
        """Swap a live row out to the host tier and vacate its slot.

        Snapshots *every* page block of the victim (K/V per attention
        sublayer + position rows — a pure read, so sharers are untouched)
        plus the complete per-slot decode state, parks it in the swap
        store, then drops the page references through the ordinary
        allocator accounting: shared prefix pages keep serving their other
        readers, registered pristine pages linger as cache, and only the
        victim's private suffix is uniquely host-held (the ledger count).

        Caller contract: no decode round may be in flight (the scheduler
        force-collects first), so the slot's collected tokens are caught up
        with its dispatched steps.  Returns the swap-store ticket.
        """
        rec = self._snapshot_slot(slot, preempting=True)
        nb = len(self.kv.owned_pages(slot))
        with self.tel.span("swap.out", slot=slot, pages=nb,
                           private=rec.n_private, pdev=self.pdev):
            ticket = self.swap_store.put(rec)
            self.kv.swap_out(slot, rec.n_private, cross_blocks=rec.n_cross,
                             state_records=rec.n_state)
            self.state = self._evict_jit(self.state, np.int32(slot))
        self._slots[slot] = None
        self._free_slots.append(slot)
        self.preemptions += 1
        self.tel.count("swap.preemptions")
        return ticket

    def snapshot_live(self) -> List[Tuple[int, SwapRecord]]:
        """Engine-checkpoint gather: every live slot as a
        :class:`SwapRecord`, in slot order, without vacating anything —
        the same per-kind host snapshot preemption takes, reused as the
        checkpoint format.  Caller contract: no round in flight."""
        return [(c, self._snapshot_slot(c))
                for c, s in enumerate(self._slots) if s is not None]

    def restore_from(self, live: List[SwapRecord],
                     swapped: Dict[int, SwapRecord]) -> int:
        """Rebuild a *fresh* engine from a checkpoint: re-park the host
        tier's ``swapped`` records under their original tickets (seeding
        the two-tier ledger of the empty pool), then re-admit every
        checkpointed-``live`` record through the ordinary restore jit —
        pages re-allocate, prefix chains re-register and re-share, and
        each slot resumes with bitwise the scalars/pages it was
        checkpointed with.  Returns the number of live slots rebuilt."""
        if not live and not swapped:
            return 0
        assert self.swap_store is not None, "restore_from needs a swap store"
        assert self.active_count() == 0, "restore_from on a non-empty engine"
        with self.tel.span("recovery.restore", live=len(live),
                           swapped=len(swapped), pdev=self.pdev):
            self.swap_store.restore_records(swapped)
            for rec in swapped.values():
                self.kv.adopt_swapped(rec.n_private,
                                      cross_blocks=rec.n_cross,
                                      state_records=rec.n_state)
            for rec in live:
                # the fresh pool's two-tier ledger must cover this record
                # before try_restore's swap_in debits it (a checkpointed
                # live slot was never swap_out'd, so nothing credited it)
                self.kv.adopt_swapped(rec.n_private,
                                      cross_blocks=rec.n_cross,
                                      state_records=rec.n_state)
                ticket = self.swap_store.put(rec)
                if not self.try_restore(ticket):
                    # the checkpointed working set fit the pool when it was
                    # taken; a fresh pool of the same geometry must re-fit
                    raise RuntimeError(
                        "recovery: pool/slot pressure rebuilding a "
                        "checkpointed live slot")
        self.tel.count("recovery.slots_restored", len(live))
        return len(live)

    def try_restore(self, ticket: int) -> bool:
        """Swap a preempted request back into a free slot, token-exactly.

        Blocks the victim never wrote whose chain is *still* registered are
        re-shared straight from the trie (their pages hold bitwise the
        snapshot content); every other block gets a fresh page and receives
        the staged host copy (re-shared blocks' scatter is redirected to
        TRASH).  The slot scalars are restored bitwise, so the remaining
        decode — same ``fold_in(key, lstep)`` schedule, same positions,
        same page content — is indistinguishable from an uninterrupted run.

        Returns False (allocator untouched, record kept) on slot or page
        pressure; raises :class:`InjectedFault` on a poisoned swap read
        (record kept intact for the retry).
        """
        if not self._free_slots:
            return False
        kv = self.kv
        rec = self.swap_store.record(ticket)
        # SSM-only archs have no attention page space: nothing to allocate
        # (or scatter) on the attn side, the record is all slot state
        nb = kv.blocks_for(rec.ring) if kv.attn_subs else 0
        # pristine prefix: contiguous blocks the decode ring never wrote
        pristine = 0
        while pristine < nb and pristine not in rec.written:
            pristine += 1
        shared: List[int] = []
        if self.prefix_sharing and rec.chain_keys:
            shared = kv.lookup_chain(rec.chain_keys)[:pristine]
        will_write = ({((rec.pos + t) % rec.ring) // self.page_size
                       for t in range(min(rec.remaining, rec.ring))}
                      if nb else ())
        slot = self._free_slots[-1]
        pages = (kv.alloc_shared(slot, shared, nb - len(shared), will_write)
                 if nb else np.zeros((0,), np.int32))
        if pages is None:
            return False
        cross_pages = None
        if self.cross_blocks:
            cross_pages = kv.alloc_cross(slot)
            if cross_pages is None:
                if nb:
                    kv.free(slot)    # undo the attn half; retry later
                return False
        try:
            arrays = self.swap_store.fetch(ticket)
        except InjectedFault:
            kv.free(slot)            # undo both kinds; record intact
            raise
        self._free_slots.pop()
        # pad the page row to the table width (SENTINEL) and redirect both
        # the padding's and the re-shared blocks' scatter to TRASH: the
        # snapshot was padded the same way, so the restore jit traces once
        mb = kv.max_blocks
        row = np.full((mb,), PagedKVCache.SENTINEL, np.int32)
        row[:nb] = pages
        scatter = np.full((mb,), PagedKVCache.TRASH, np.int32)
        scatter[len(shared):nb] = np.asarray(pages)[len(shared):nb]
        cross_arg = None
        if self.cross_blocks:
            cross_arg = {"kv": arrays["cross"],
                         "pages": jnp.asarray(cross_pages)}
        state_arg = arrays.get("state")
        with self.tel.span("swap.restore", slot=slot, pages=nb,
                           reshared=len(shared), pdev=self.pdev):
            self.state = self._restore_jit(
                self.state, arrays["kv"], arrays["pos"],
                jnp.asarray(rec.logits), np.int32(slot), jnp.asarray(row),
                jnp.asarray(scatter), np.int32(rec.pos),
                np.int32(rec.remaining), np.float32(rec.temp),
                np.int32(rec.top_k), jnp.asarray(rec.key),
                np.int32(rec.lstep), np.int32(rec.ring),
                cross_arg, state_arg)
            kv.swap_in(rec.n_private, cross_blocks=rec.n_cross,
                       state_records=rec.n_state)
        self.swap_store.pop(ticket)
        if self.prefix_sharing and rec.chain_keys:
            # unwritten restored blocks hold bitwise their chains' prefill
            # content: re-register them so later identical prefixes (or a
            # second preemption of this request) can re-share
            kv.register(slot, rec.chain_keys[:pristine])
        self._slots[slot] = _Slot(
            rec.req, rec.target, rec.temp, rec.top_k, bucket=rec.bucket,
            ring=rec.ring, planned=len(rec.tokens), tokens=list(rec.tokens),
            priority=rec.priority, preemptions=rec.preemptions,
            chain_keys=list(rec.chain_keys), t_first=rec.t_first)
        self.restores += 1
        self.tel.count("swap.restores")
        return True

    def drop_swapped(self, ticket: int) -> SwapRecord:
        """Abandon a swapped-out record (terminal failure after the restore
        retry budget): its host blocks leave the ledger without a restore.
        Returns the record so the caller can surface the request."""
        rec = self.swap_store.pop(ticket)
        self.kv.swap_in(rec.n_private, restored=False,
                        cross_blocks=rec.n_cross, state_records=rec.n_state)
        return rec

    def fail_live(self) -> List[Any]:
        """Terminal failure path (round-fault limit exceeded): vacate every
        live row — pages freed through the ordinary accounting, rows
        evicted device-side — and return the abandoned requests so the
        caller can surface them as FAILED.  Caller contract: no round in
        flight."""
        failed: List[Any] = []
        for c, s in enumerate(self._slots):
            if s is None:
                continue
            failed.append(s.req)
            self.kv.free(c)
            self.state = self._evict_jit(self.state, np.int32(c))
            self._slots[c] = None
            self._free_slots.append(c)
        return failed

    # ------------------------------------------------------------------
    def run_all(self, requests) -> List[Tuple[Any, np.ndarray]]:
        """FIFO-drain a request list without a scheduler: admit as slots and
        pages free up (same-bucket admissions batched into one prefill), one
        micro-round per iteration.  Returns (request, tokens) in completion
        order.

        Overload contract (PR 6): a head request the pool cannot admit no
        longer raises.  When nothing is in flight (so no retirement can
        ever free pages) admission is retried up to
        ``admission_retry_limit`` times — injected admission stalls are
        transient, pool-too-small is not — after which the head request is
        dropped into ``self.rejected`` (terminal REJECTED) and the drain
        continues, so a 2x oversubscribed burst finishes without an
        exception and without a hang.
        """
        queue: Deque[Any] = collections.deque(requests)
        done: List[Tuple[Any, np.ndarray]] = []
        stall = 0
        while queue or self.active_count():
            progress = False
            while queue and self._free_slots:
                take = [queue.popleft() for _ in
                        range(min(len(queue), len(self._free_slots)))]
                try:
                    flags = self.try_admit_batch(take)
                except InjectedFault:
                    for req in reversed(take):
                        queue.appendleft(req)
                    break
                for req, ok in reversed(list(zip(take, flags))):
                    if not ok:
                        queue.appendleft(req)
                progress = progress or any(flags)
                if not all(flags):
                    break              # pool pressure: decode frees pages
            if queue and not self.active_count() and not progress:
                stall += 1
                if stall > self.admission_retry_limit:
                    self.rejected.append(queue.popleft())
                    stall = 0
                continue               # nothing live: a round would be
            stall = 0                  # all-masked, retry admission instead
            try:
                res = self.collect(self.dispatch_round())
            except InjectedFault:
                continue               # dropped round: state untouched
            done.extend((req, toks) for req, toks, _ in res.finished)
        return done
