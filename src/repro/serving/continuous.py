"""Continuous batching over a persistent slot table (paged KV-cache decode).

The slot-based scheduler serves one tenant batch at a time: the device runs
that batch's scanned decode to completion, padded rows and all, before the
next tenant's batch starts.  :class:`ContinuousBatchingEngine` instead keeps
a fixed-capacity *slot table* resident on the device and interleaves three
events per outer step, the serving analogue of the paper's fine-grained
multi-tenant sharing:

* **admission** — a queued request is prefilled at its (page-aligned) prompt
  bucket, its KV written into freshly allocated :class:`repro.serving.
  kvcache.PagedKVCache` pages, and its sampling state (per-request
  temperature / top-k / PRNG key, last logits, position, remaining budget)
  scattered into a free slot row;
* **one decode micro-round** — a single jitted ``lax.scan`` of
  ``inner_steps`` masked decode steps over *all* capacity rows.  The step is
  shape-stable (paged gather/scatter, fixed capacity), so ragged
  ``max_new_tokens`` mixes and mixed prompt buckets never retrace it: one
  compile per batch capacity, plus one prefill/admission compile per prompt
  bucket (``decode_traces`` / ``admit_traces`` count them for the tests);
* **retirement** — rows whose token budget ran out are collected on the
  host, their pages evicted back to the free list, their slots freed for the
  next admission.

Rows are masked, not compacted: an inactive row samples into the void (its
emission is dropped), writes its K/V to the reserved TRASH page and keeps
its SSM state frozen, so retirement costs no reshape or recompile — that is
the "masked fixed-step scan with early-exit accounting" deferred from PR 2.

Greedy token-exactness: an admitted request decodes through exactly the same
prefill (same left-padded bucket prompt) and per-token math (see
:func:`repro.serving.kvcache.paged_attention_decode`) as
``ServingEngine.generate`` on that padded prompt, with the same
``PRNGKey(seed)`` / ``fold_in(key, local_step)`` schedule — so each row's
tokens match the blocking engine row-for-row, independent of what its
neighbours in the slot table are doing (``tests/test_continuous.py``).

Encoder-decoder configs are rejected: their cross-attention caches are
per-request device tensors with no paged representation here (the slot-based
paths still serve them).  MoE routing couples rows through expert capacity,
so MoE archs run continuously but are only *statistically* exchangeable with
the blocking engine, not bitwise.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ATTN, MOE, NONE, ArchConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_embedding, apply_mlp, apply_rmsnorm,
                                 apply_unembed, pad_vocab)
from repro.serving.engine import ServingEngine, sample_rows
from repro.serving.kvcache import (POS_SENTINEL, PagedKVCache,
                                   paged_attention_decode)


@dataclasses.dataclass
class _Slot:
    """Host-side record of one occupied slot-table row."""
    req: Any                       # duck-typed: .prompt/.max_new_tokens/...
    target: int
    temp: float                    # resolved sampling params, mirrored on
    top_k: int                     # the host so dispatch_round can pick the
    tokens: List[int] = dataclasses.field(default_factory=list)   # static sampling tier


@dataclasses.dataclass
class RoundHandle:
    """One dispatched (not yet collected) decode micro-round."""
    emitted: jax.Array             # (steps, C) int32, -1 where row inactive
    act: jax.Array                 # (steps, C) bool
    steps: int
    t_start: float
    t_dispatched: float


@dataclasses.dataclass
class CollectResult:
    finished: List[Tuple[Any, np.ndarray, int]]   # (request, tokens, slot)
    active_steps: np.ndarray       # (C,) decode steps each row was live for
    slot_reqs: List[Optional[Any]]  # slot -> request, pre-retirement snapshot


class ContinuousBatchingEngine:
    """Masked fixed-step scan decode over a persistent slot table.

    Drive it either through :class:`repro.serving.multitenant.
    MultiTenantScheduler` (``mode="continuous"``) or directly::

        eng = ContinuousBatchingEngine(engine, capacity=4)
        for req, tokens in eng.run_all(requests): ...
    """

    def __init__(self, engine: ServingEngine, capacity: int = 8,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 inner_steps: int = 4, max_prompt_len: int = 128):
        cfg = engine.cfg
        if cfg.enc_dec:
            raise ValueError(
                "continuous batching needs a paged self-attention cache; "
                "encoder-decoder cross-attention is not paged — use the "
                "slot-based scheduler modes for enc-dec archs")
        self.engine = engine
        self.cfg = cfg
        self.sh = engine.sh
        self.params = engine.params
        self.bundle = engine.bundle
        self.capacity = capacity
        self.inner_steps = inner_steps
        self.max_prompt_len = max_prompt_len
        self.n_stages = cfg.num_layers // cfg.stage_period
        self.sched = cfg.block_schedule()[:cfg.stage_period]
        self.page_size = page_size
        max_ring = self._ring_len(self.bucket_len(max_prompt_len))
        self.kv = PagedKVCache(cfg, capacity, page_size,
                               -(-max_ring // page_size), num_pages)
        self.state = self._init_state()
        self._slots: List[Optional[_Slot]] = [None] * capacity
        self._free_slots: List[int] = list(range(capacity - 1, -1, -1))
        # trace counters: python side effects run only while jit traces
        self.decode_traces = 0
        self.admit_traces = 0
        self.prefill_traces = 0
        self.rounds = 0
        self.row_steps = 0         # sum over rounds of live rows per step
        self._build_jits()

    # ------------------------------------------------------------------
    def bucket_len(self, prompt_len: int) -> int:
        """Prompts are left-padded to a page-aligned bucket so admission
        (prefill + KV scatter) compiles once per bucket, not per length."""
        p = self.page_size
        return max(p, -(-prompt_len // p) * p)

    def _ring_len(self, bucket: int) -> int:
        w = self.cfg.sliding_window
        return min(bucket, w) if w is not None else bucket

    def active_count(self) -> int:
        return sum(s is not None for s in self._slots)

    def live_after(self, steps: int) -> bool:
        """Will any current row still be live after ``steps`` more decode
        steps?  Host-side: a row's collected tokens exclude any in-flight
        round, so with one round of ``steps`` in flight this answers "is a
        follow-up round worth dispatching" — False means pipelining another
        round would decode an all-masked slot table."""
        return any(s is not None and s.target - len(s.tokens) > steps
                   for s in self._slots)

    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def occupancy(self) -> float:
        total = self.rounds * self.inner_steps * self.capacity
        return self.row_steps / total if total else 0.0

    # ------------------------------------------------------------------
    def _init_state(self) -> Dict[str, Any]:
        cfg, c = self.cfg, self.capacity
        caches: Dict[str, Any] = dict(self.kv.make_pools(self.n_stages))
        for i, (mixer, _) in enumerate(self.sched):
            if mixer != ATTN:
                st = ssm_mod.init_ssm_state(cfg, c)
                caches[f"sub{i}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self.n_stages,) + a.shape), st)
        return {
            "caches": caches,
            "page_table": self.kv.make_page_table(),
            "pos_pool": self.kv.make_pos_pool(),
            "logits": jnp.zeros((c, pad_vocab(cfg.vocab_size)), jnp.float32),
            "pos": jnp.zeros((c,), jnp.int32),
            "ring": jnp.ones((c,), jnp.int32),
            "remaining": jnp.zeros((c,), jnp.int32),
            "temps": jnp.zeros((c,), jnp.float32),
            "topks": jnp.zeros((c,), jnp.int32),
            "keys": jnp.zeros((c, 2), jnp.uint32),
            "lstep": jnp.zeros((c,), jnp.int32),
        }

    # ------------------------------------------------------------------
    def _build_jits(self) -> None:
        cfg, sh = self.cfg, self.sh
        sched = self.sched
        p_sz = self.kv.page_size
        trash = PagedKVCache.TRASH
        has_attn = bool(self.kv.attn_subs)

        def decode_step(params, st, all_greedy, any_topk):
            active = st["remaining"] > 0
            tok = sample_rows(st["logits"], st["temps"], st["topks"],
                              st["keys"], all_greedy=all_greedy,
                              any_topk=any_topk)
            pos, ring, pt = st["pos"], st["ring"], st["page_table"]
            if has_attn:
                slot_log = jnp.mod(pos, ring)
                blk = slot_log // p_sz
                off = jnp.mod(slot_log, p_sz)
                page = jnp.take_along_axis(pt, blk[:, None], axis=1)[:, 0]
                page = jnp.where(active, page, trash)
                pos_pool = st["pos_pool"].at[page, off].set(
                    jnp.where(active, pos, POS_SENTINEL))
                kpos = pos_pool[pt].reshape(pt.shape[0], -1)
            else:
                page = off = kpos = None
                pos_pool = st["pos_pool"]

            x = apply_embedding(params["embed"], tok[:, None], cfg, sh)
            if not cfg.use_rope:
                # _sinusoid_at broadcasts (C, 1, 1) positions to (C, 1, d)
                # — the per-row twin of decode_fn's scalar call
                from repro.models.model import _sinusoid_at
                x = x + _sinusoid_at(pos[:, None, None],
                                     cfg.d_model).astype(x.dtype)

            def body(h, xs):
                stage_params, stage_cache = xs
                nc = {}
                for i, (mixer, mlp) in enumerate(sched):
                    sub = stage_params[f"sub{i}"]
                    hin = apply_rmsnorm(sub["norm1"], h)
                    if mixer == ATTN:
                        hout, nci = paged_attention_decode(
                            sub["attn"], hin, stage_cache[f"sub{i}"], pt,
                            kpos, page, off, pos, cfg, sh)
                    else:
                        hout, nci = ssm_mod.apply_ssm_decode(
                            sub["mamba"], hin, stage_cache[f"sub{i}"],
                            cfg, sh)
                        # frozen state for masked rows (attention rows are
                        # masked by redirecting their write to TRASH instead)
                        nci = jax.tree.map(
                            lambda new, old: jnp.where(
                                active.reshape((-1,) + (1,) * (new.ndim - 1)),
                                new, old),
                            nci, stage_cache[f"sub{i}"])
                    nc[f"sub{i}"] = nci
                    h = h + hout
                    if mlp != NONE:
                        hin = apply_rmsnorm(sub["norm2"], h)
                        if mlp == MOE:
                            hout, _ = moe_mod.apply_moe(sub["moe"], hin,
                                                        cfg, sh)
                        else:
                            hout = apply_mlp(sub["mlp"], hin, cfg, sh)
                        h = h + hout
                return h, nc

            h, new_caches = jax.lax.scan(body, x,
                                         (params["stages"], st["caches"]))
            h = apply_rmsnorm(params["final_norm"], h)
            new_logits = apply_unembed(params["embed"], h, cfg, sh)[:, 0]

            if all_greedy:               # keys unused by every live row
                keys = st["keys"]
            else:
                keys_next = jax.vmap(jax.random.fold_in)(st["keys"],
                                                         st["lstep"])
                keys = jnp.where(active[:, None], keys_next, st["keys"])
            new_st = {
                **st,
                "caches": new_caches,
                "pos_pool": pos_pool,
                "logits": jnp.where(active[:, None], new_logits,
                                    st["logits"]),
                "pos": pos + active,
                "remaining": st["remaining"] - active,
                "keys": keys,
                "lstep": st["lstep"] + active,
            }
            return new_st, (jnp.where(active, tok, -1), active)

        def round_fn(params, st, *, steps: int, all_greedy: bool,
                     any_topk: bool):
            self.decode_traces += 1          # incremented at trace time only
            st, (emitted, act) = jax.lax.scan(
                lambda c, _: decode_step(params, c, all_greedy, any_topk),
                st, None, length=steps)
            return st, emitted, act

        self._round_jit = jax.jit(
            round_fn, static_argnames=("steps", "all_greedy", "any_topk"))

        def prefill_fn(params, batch):
            self.prefill_traces += 1
            return self.bundle.prefill_fn(params, batch, sh)

        self._prefill_jit = jax.jit(prefill_fn)

        def admit_fn(st, caches_p, logits0, slot, pages, remaining, temp,
                     topk, key, *, bucket: int, ring: int):
            self.admit_traces += 1
            new = dict(st)
            nb = pages.shape[0] if pages is not None else 0
            if nb:
                row = jnp.full((self.kv.max_blocks,), PagedKVCache.SENTINEL,
                               jnp.int32).at[:nb].set(pages)
                new["page_table"] = st["page_table"].at[slot].set(row)
                name = self.kv.attn_subs[0]
                pos_src = caches_p[name]["pos"][0, 0]            # (ring,)
                pos_vals = jnp.full((nb * p_sz,), POS_SENTINEL,
                                    jnp.int32).at[:ring].set(pos_src)
                new["pos_pool"] = st["pos_pool"].at[pages].set(
                    pos_vals.reshape(nb, p_sz))
            nc = {}
            for i, (mixer, _) in enumerate(sched):
                sname = f"sub{i}"
                cur = st["caches"][sname]
                if mixer == ATTN:
                    def to_pages(leaf, pool_leaf):
                        pad = nb * p_sz - ring
                        v = jnp.pad(leaf[:, 0],
                                    ((0, 0), (0, pad), (0, 0), (0, 0)))
                        v = v.reshape(self.n_stages, nb, p_sz,
                                      *leaf.shape[3:])
                        return pool_leaf.at[:, pages].set(
                            v.astype(pool_leaf.dtype))
                    nc[sname] = {"k": to_pages(caches_p[sname]["k"],
                                               cur["k"]),
                                 "v": to_pages(caches_p[sname]["v"],
                                               cur["v"])}
                else:
                    nc[sname] = jax.tree.map(
                        lambda t, cp: t.at[:, slot].set(cp[:, 0]),
                        cur, caches_p[sname])
            new["caches"] = nc
            new["logits"] = st["logits"].at[slot].set(logits0[0])
            new["pos"] = st["pos"].at[slot].set(bucket)
            new["ring"] = st["ring"].at[slot].set(ring)
            new["remaining"] = st["remaining"].at[slot].set(remaining)
            new["temps"] = st["temps"].at[slot].set(temp)
            new["topks"] = st["topks"].at[slot].set(topk)
            new["keys"] = st["keys"].at[slot].set(key)
            new["lstep"] = st["lstep"].at[slot].set(0)
            return new

        self._admit_jit = jax.jit(admit_fn,
                                  static_argnames=("bucket", "ring"))

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def try_admit(self, req: Any) -> bool:
        """Admit one request into a free slot; False when no slot or no
        pages are available right now (caller keeps it queued)."""
        if not self._free_slots:
            return False
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size > self.max_prompt_len:
            raise ValueError(
                f"prompt of {prompt.size} tokens exceeds max_prompt_len="
                f"{self.max_prompt_len}")
        bucket = self.bucket_len(prompt.size)
        ring = self._ring_len(bucket)
        slot = self._free_slots[-1]
        pages = None
        if self.kv.attn_subs:
            pages = self.kv.alloc(slot, self.kv.blocks_for(ring))
            if pages is None:
                return False                 # pool pressure: retry later
        self._free_slots.pop()
        padded = np.zeros((1, bucket), np.int32)
        padded[0, bucket - prompt.size:] = prompt
        logits, caches, _ = self._prefill_jit(self.params,
                                              {"tokens": jnp.asarray(padded)})
        temp = getattr(req, "temperature", None)
        if temp is None:
            temp = self.engine.temperature
        topk = int(getattr(req, "top_k", 0) or 0)
        self.state = self._admit_jit(
            self.state, caches, logits, slot,
            None if pages is None else jnp.asarray(pages),
            int(req.max_new_tokens), float(temp), topk,
            jax.random.PRNGKey(int(getattr(req, "seed", 0) or 0)),
            bucket=bucket, ring=ring)
        self._slots[slot] = _Slot(req, int(req.max_new_tokens),
                                  float(temp), topk)
        return True

    # ------------------------------------------------------------------
    # decode micro-rounds
    # ------------------------------------------------------------------
    def dispatch_round(self) -> RoundHandle:
        """Enqueue one masked micro-round (non-blocking); the caller may
        admit the next requests while it runs on the device."""
        t0 = time.perf_counter()
        # static sampling tier from the live rows (an all-greedy round is a
        # bare argmax; at most 3 round variants ever compile)
        live = [s for s in self._slots if s is not None]
        all_greedy = all(s.temp <= 0 for s in live)
        any_topk = any(s.top_k > 0 for s in live)
        self.state, emitted, act = self._round_jit(
            self.params, self.state, steps=self.inner_steps,
            all_greedy=all_greedy, any_topk=any_topk)
        self.rounds += 1
        return RoundHandle(emitted, act, self.inner_steps, t0,
                           time.perf_counter())

    def collect(self, handle: RoundHandle) -> CollectResult:
        """Materialise a round's emissions, append tokens to their rows and
        retire rows that hit their budget (pages evicted to the free list)."""
        emitted = np.asarray(handle.emitted)
        act = np.asarray(handle.act)
        slot_reqs = [s.req if s is not None else None for s in self._slots]
        active_steps = act.sum(axis=0).astype(np.int64)
        self.row_steps += int(active_steps.sum())
        finished: List[Tuple[Any, np.ndarray, int]] = []
        for c, s in enumerate(self._slots):
            if s is None:
                continue
            s.tokens.extend(int(t) for t in emitted[act[:, c], c])
            if len(s.tokens) >= s.target:
                finished.append((s.req,
                                 np.asarray(s.tokens[:s.target], np.int32),
                                 c))
                self.kv.free(c)
                self._slots[c] = None
                self._free_slots.append(c)
        return CollectResult(finished, active_steps, slot_reqs)

    # ------------------------------------------------------------------
    def run_all(self, requests) -> List[Tuple[Any, np.ndarray]]:
        """FIFO-drain a request list without a scheduler: admit as slots and
        pages free up, one micro-round per iteration.  Returns (request,
        tokens) in completion order."""
        queue: Deque[Any] = collections.deque(requests)
        done: List[Tuple[Any, np.ndarray]] = []
        while queue or self.active_count():
            while queue and self.try_admit(queue[0]):
                queue.popleft()
            res = self.collect(self.dispatch_round())
            done.extend((req, toks) for req, toks, _ in res.finished)
        return done
