"""Serving engine: jit'd prefill + decode with sampling.

The engine owns compiled step functions for one model on one device/mesh;
multi-tenant request scheduling (several tenants sharing the accelerator,
the paper's "multiple applications on one pGPU") sits above it in
:mod:`repro.serving.multitenant`.

Two generation paths share the same sampling semantics:

* :meth:`ServingEngine.generate` — the host-blocking reference loop: one
  jitted decode call per token, sampling on the host between calls.  Kept
  as the A/B baseline and the semantic oracle for the scanned path.
* :meth:`ServingEngine.dispatch` / :meth:`ServingEngine.await_result` — the
  split halves.  ``dispatch`` enqueues the jitted prefill plus a single
  on-device ``lax.scan`` decode loop (sampling folded into the scanned
  step, so the host never round-trips per token) and returns a
  :class:`PendingGeneration` handle *without blocking*; ``await_result``
  blocks on the handle and materialises tokens + prefill/decode timings.
  Between the two calls the host is free — that gap is where the
  multi-tenant scheduler assembles and stages the next tenant's batch
  underneath this tenant's on-device decode (the paper's transfer/compute
  overlap applied to serving).

Both paths draw sampling keys identically (``PRNGKey(seed)`` for the first
token, then ``fold_in(key, step)`` per decode step), so for a fixed seed
they are token-for-token exchangeable — ``tests/test_serving_overlap.py``
locks that in across architectures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.distributed.sharding import Sharder, null_sharder
from repro.models.model import ModelBundle, build_model
from repro.obs.telemetry import Telemetry, get_telemetry


def sample_rows(logits: jax.Array, temps: jax.Array, topks: jax.Array,
                keys: jax.Array, *, all_greedy: bool = False,
                any_topk: bool = True) -> jax.Array:
    """Per-row sampling: greedy where ``temps <= 0``, else temperature
    (optionally top-k truncated) categorical with a *per-row* PRNG key.

    This is the shared sampling mechanism of the per-request paths: the
    split engine threads (temps, topks, keys) through the ``lax.scan``
    decode-loop carry (see :meth:`ServingEngine.dispatch`), and the
    continuous-batching engine threads the same triple through its
    persistent slot-table carry — one sampler, two schedulers.

    logits: (B, V); temps: (B,) float; topks: (B,) int (0 disables top-k);
    keys: (B, 2) uint32 PRNG keys.  Greedy rows ignore temperature and keys
    entirely, so they stay token-exact with the host-blocking ``generate``
    loop regardless of their neighbours' sampling params.

    ``all_greedy`` / ``any_topk`` are *static* strength hints the caller
    derives on the host from the live rows (the row-wise masks make them
    semantics-preserving): an all-greedy step is a bare argmax — the
    vocab-wide sort and categorical draw would otherwise dominate a small
    model's decode step — and ``any_topk=False`` skips the sort.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if all_greedy:
        return greedy
    scaled = logits
    if any_topk:
        v = logits.shape[-1]
        srt = jnp.sort(logits, axis=-1)                   # ascending
        kidx = jnp.clip(v - topks, 0, v - 1).astype(jnp.int32)
        thresh = jnp.take_along_axis(srt, kidx[:, None], axis=-1)
        keep = (topks[:, None] <= 0) | (logits >= thresh)
        scaled = jnp.where(keep, logits, -jnp.inf)
    scaled = scaled / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def resolve_extra_inputs(cfg: ArchConfig, req: Any) -> Dict[str, np.ndarray]:
    """Per-request non-token prefill inputs (encoder frames, vision patch
    embeds), resolved from ``req.extra_inputs`` with per-arch defaults.

    Encoder-decoder archs cannot prefill without ``frames``, so a request
    that carries none gets deterministic zero frames — the *same* default
    on every path (blocking batch build, continuous admission), which keeps
    the A/B token-exactness contract intact for requests that never set
    extras.  Arrays are per-request (no batch axis); batching paths stack
    them."""
    extra = dict(getattr(req, "extra_inputs", None) or {})
    if cfg.enc_dec and "frames" not in extra:
        extra["frames"] = np.zeros((cfg.encoder_seq_len, cfg.d_model),
                                   np.float32)
    return extra


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, steps)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens.size / max(self.decode_s, 1e-9)


@dataclasses.dataclass
class PendingGeneration:
    """Handle for an in-flight generation (prefill + scanned decode both
    enqueued on the device; nothing host-blocking held here).

    ``tokens`` is the (B, steps) device array the scan will fill;
    ``prefill_logits`` the prefill output, kept so :meth:`ServingEngine.
    await_result` can split the ready-time into prefill/decode phases.
    Timestamps are absolute ``perf_counter`` values.
    """
    tokens: jax.Array
    prefill_logits: jax.Array
    steps: int
    t_start: float                # dispatch() entry
    t_dispatched: float           # dispatch() return (host enqueue cost end)

    def ready(self) -> bool:
        """Non-blocking probe: has the scanned decode finished?  Conservative
        for outputs without an ``is_ready`` probe (duck-typed stand-ins):
        reports False rather than claiming a still-running decode is done."""
        is_ready = getattr(self.tokens, "is_ready", None)
        return bool(is_ready()) if is_ready is not None else False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: Any,
                 sh: Optional[Sharder] = None, temperature: float = 0.0,
                 kernel_backend: str = "jnp",
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg
        self.tel = get_telemetry(telemetry)
        self.bundle: ModelBundle = build_model(cfg)
        self.sh = sh or null_sharder()
        if self.sh.mesh is not None:
            # commit the weights onto the mesh replicated (serving shards
            # activations/KV along heads, never the weights) so every jit
            # sees consistently-placed inputs
            params = jax.tree.map(
                lambda a: self.sh.place(a, (None,) * jnp.ndim(a)), params)
        self.params = params
        self.temperature = temperature
        # default paged-attention backend for serving layers built on this
        # engine ("jnp" dense gather | "pallas" fused page-streaming
        # kernels); the engine's own dense ring-cache paths are unaffected,
        # but ContinuousBatchingEngine inherits this unless overridden
        self.kernel_backend = kernel_backend
        self.prefill_traces = 0     # compiles (one per (batch, seq) shape)
        self.prefill_calls = 0      # host invocations

        def prefill_fn(p, b):
            self.prefill_traces += 1     # python side effect: trace time only
            self.tel.count("trace.engine_prefill")
            return self.bundle.prefill_fn(p, b, self.sh)

        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(
            lambda p, t, c, i: self.bundle.decode_fn(p, t, c, i, self.sh))

        def decode_loop(params, logits0, caches, idx, temp, key,
                        *, steps: int, greedy: bool):
            self.tel.count("trace.engine_decode")   # trace time only
            # sampling folded into the scanned step: token i is sampled from
            # logits i with key i, then decoded to produce logits i+1, and
            # key i+1 = fold_in(key i, i) — the exact key/logits schedule of
            # the host loop in generate(), so the two paths are token-exact.
            def sample(logits, key):
                if greedy:
                    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return jax.random.categorical(key, logits / temp,
                                              axis=-1).astype(jnp.int32)

            def step(carry, i):
                logits, caches, key = carry
                tok = sample(logits, key)
                new_logits, new_caches = self.bundle.decode_fn(
                    params, tok[:, None], caches, idx + i, self.sh)
                return (new_logits, new_caches,
                        jax.random.fold_in(key, i)), tok

            (_, _, _), toks = jax.lax.scan(
                step, (logits0, caches, key),
                jnp.arange(steps, dtype=jnp.int32))
            return toks.T                      # (steps, B) -> (B, steps)

        self._decode_loop = jax.jit(decode_loop,
                                    static_argnames=("steps", "greedy"))

        def decode_loop_rows(params, logits0, caches, idx, temps, topks,
                             keys, *, steps: int, all_greedy: bool,
                             any_topk: bool):
            self.tel.count("trace.engine_decode_rows")   # trace time only
            # per-request sampling params ride the scan carry: each row keeps
            # its own (temperature, top_k, key), same key/logits schedule as
            # the scalar path so greedy rows stay token-exact with generate()
            def step(carry, i):
                logits, caches, keys = carry
                tok = sample_rows(logits, temps, topks, keys,
                                  all_greedy=all_greedy, any_topk=any_topk)
                new_logits, new_caches = self.bundle.decode_fn(
                    params, tok[:, None], caches, idx + i, self.sh)
                keys = jax.vmap(lambda k: jax.random.fold_in(k, i))(keys)
                return (new_logits, new_caches, keys), tok

            (_, _, _), toks = jax.lax.scan(
                step, (logits0, caches, keys),
                jnp.arange(steps, dtype=jnp.int32))
            return toks.T

        self._decode_loop_rows = jax.jit(
            decode_loop_rows,
            static_argnames=("steps", "all_greedy", "any_topk"))
        self.decode_steps = 0       # scanned decode steps enqueued (benchmarks)

    # ------------------------------------------------------------------
    def state_kinds(self):
        """The per-request state kinds this arch's serving rows carry
        (attention KV pages / cross-attention pages / SSM records) — the
        capability probe :meth:`repro.serving.continuous.
        ContinuousBatchingEngine.supported_modes` and ``launch/serve.py
        --list-archs`` are built on."""
        from repro.serving.kvcache import state_kinds
        return state_kinds(self.cfg)

    # ------------------------------------------------------------------
    def prefill(self, batch: Dict[str, Any]):
        """Counted, jit-compiled prefill shared by both generation paths
        (and by admission layers above the engine): returns (last-token
        logits, caches, cache index).  One compile per (batch, seq) shape.
        Prefill rows are bitwise independent of their batch neighbours, so
        callers may batch several requests' padded prompts into one call and
        slice the rows back out — the contract the continuous engine's
        batched admission prefill is built on (it keeps its own jit so its
        per-engine trace counters stay isolated).  The dense per-bucket
        caches returned here feed :func:`repro.serving.kvcache.
        paged_scatter` during paged admission — with ``kernel_backend=
        "pallas"`` the scatter lands page-granularly in the allocated pages
        (no dense intermediate hop), which is the compute side of the fused
        prefill-scatter pipeline."""
        self.prefill_calls += 1
        if not self.tel.enabled:
            return self._prefill(self.params, batch)
        with self.tel.span("engine.prefill",
                           batch=int(batch["tokens"].shape[0]),
                           seq=int(batch["tokens"].shape[1])):
            out = self._prefill(self.params, batch)
        self.tel.count("engine.prefill_calls")
        return out

    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature,
                                      axis=-1).astype(jnp.int32)

    def _make_batch(self, prompts: np.ndarray,
                    extra_inputs: Optional[Dict[str, Any]]) -> Dict[str, Any]:
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        return batch

    # ------------------------------------------------------------------
    # Blocking reference path (one jitted decode call per token)
    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy/temperature sampling."""
        batch = self._make_batch(prompts, extra_inputs)
        self.decode_steps += int(max_new_tokens)
        t0 = time.perf_counter()
        logits, caches, idx = self.prefill(batch)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        out = []
        t0 = time.perf_counter()
        tok = self._sample(logits, key)
        for step in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok[:, None], caches,
                                          idx + step)
            key = jax.random.fold_in(key, step)
            tok = self._sample(logits, key)
        jax.block_until_ready(logits)
        t_done = time.perf_counter()
        decode_s = t_done - t0
        if self.tel.enabled:
            self.tel.record_span("engine.generate",
                                 t_done - prefill_s - decode_s, t_done,
                                 batch=int(prompts.shape[0]),
                                 steps=int(max_new_tokens))
            self.tel.count("engine.decode_steps", int(max_new_tokens))
        return GenerationResult(np.stack(out, axis=1), prefill_s, decode_s,
                                max_new_tokens)

    # ------------------------------------------------------------------
    # Split path: dispatch (non-blocking enqueue) / await (materialise)
    # ------------------------------------------------------------------
    def dispatch(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 seed: int = 0,
                 temperatures: Optional[Any] = None,
                 top_ks: Optional[Any] = None,
                 seeds: Optional[Any] = None) -> PendingGeneration:
        """Enqueue prefill + the full on-device decode loop; never blocks on
        device results, so the caller can stage other work under it.

        ``temperatures``/``top_ks``/``seeds`` (each (B,), any one optional)
        switch the scanned sampler to per-request params threaded through the
        scan carry via :func:`sample_rows`; left as None, the engine-level
        scalar path runs (token-exact with ``generate``, same key schedule).
        """
        if not self.tel.enabled:
            return self._dispatch_inner(prompts, max_new_tokens,
                                        extra_inputs, seed, temperatures,
                                        top_ks, seeds)
        with self.tel.span("engine.dispatch", batch=int(prompts.shape[0]),
                           steps=int(max_new_tokens)):
            return self._dispatch_inner(prompts, max_new_tokens,
                                        extra_inputs, seed, temperatures,
                                        top_ks, seeds)

    def _dispatch_inner(self, prompts, max_new_tokens, extra_inputs, seed,
                        temperatures, top_ks, seeds) -> PendingGeneration:
        batch = self._make_batch(prompts, extra_inputs)
        t_start = time.perf_counter()
        logits, caches, idx = self.prefill(batch)
        self.decode_steps += int(max_new_tokens)
        self.tel.count("engine.decode_steps", int(max_new_tokens))
        if temperatures is not None or top_ks is not None or seeds is not None:
            b = prompts.shape[0]
            temps = np.full(b, self.temperature, np.float32) \
                if temperatures is None else np.asarray(temperatures, np.float32)
            topks = np.zeros(b, np.int32) if top_ks is None \
                else np.asarray(top_ks, np.int32)
            seed_arr = np.full(b, seed, np.int64) if seeds is None \
                else np.asarray(seeds)
            keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seed_arr])
            toks = self._decode_loop_rows(
                self.params, logits, caches, idx, jnp.asarray(temps),
                jnp.asarray(topks), keys, steps=int(max_new_tokens),
                all_greedy=bool((temps <= 0).all()),
                any_topk=bool((topks > 0).any()))
            return PendingGeneration(toks, logits, int(max_new_tokens),
                                     t_start, time.perf_counter())
        # temperature is passed unclamped: greedy is static, so the
        # logits/temp division is never traced when temperature <= 0
        toks = self._decode_loop(
            self.params, logits, caches, idx,
            jnp.float32(self.temperature), jax.random.PRNGKey(seed),
            steps=int(max_new_tokens), greedy=self.temperature <= 0.0)
        return PendingGeneration(toks, logits, int(max_new_tokens),
                                 t_start, time.perf_counter())

    def await_result(self, handle: PendingGeneration) -> GenerationResult:
        """Block until the handle's generation is device-complete and return
        the materialised tokens.  ``prefill_s``/``decode_s`` are time-to-
        ready from dispatch entry: with host work interleaved between
        dispatch and await they measure pipeline latency, not exclusive
        device occupancy (the scheduler's timeline carries the honest
        per-window stamps)."""
        with self.tel.span("engine.await", steps=handle.steps):
            jax.block_until_ready(handle.prefill_logits)
            t_prefill = time.perf_counter()
            tokens = np.asarray(handle.tokens)  # blocks on the scanned decode
            t_done = time.perf_counter()
        return GenerationResult(tokens, t_prefill - handle.t_start,
                                t_done - t_prefill, handle.steps)
