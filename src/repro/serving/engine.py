"""Serving engine: jit'd prefill + decode with sampling.

The engine owns compiled step functions for one model on one device/mesh;
multi-tenant request scheduling (several tenants sharing the accelerator,
the paper's "multiple applications on one pGPU") sits above it in
:mod:`repro.serving.multitenant`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.distributed.sharding import Sharder, null_sharder
from repro.models.model import ModelBundle, build_model


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, steps)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens.size / max(self.decode_s, 1e-9)


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params: Any,
                 sh: Optional[Sharder] = None, temperature: float = 0.0):
        self.cfg = cfg
        self.bundle: ModelBundle = build_model(cfg)
        self.params = params
        self.sh = sh or null_sharder()
        self.temperature = temperature
        self._prefill = jax.jit(
            lambda p, b: self.bundle.prefill_fn(p, b, self.sh))
        self._decode = jax.jit(
            lambda p, t, c, i: self.bundle.decode_fn(p, t, c, i, self.sh))

    # ------------------------------------------------------------------
    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature,
                                      axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16,
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, S) int32.  Greedy/temperature sampling."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        t0 = time.perf_counter()
        logits, caches, idx = self._prefill(self.params, batch)
        logits.block_until_ready()
        prefill_s = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        out = []
        t0 = time.perf_counter()
        tok = self._sample(logits, key)
        for step in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, caches = self._decode(self.params, tok[:, None], caches,
                                          idx + step)
            key = jax.random.fold_in(key, step)
            tok = self._sample(logits, key)
        jax.block_until_ready(logits)
        decode_s = time.perf_counter() - t0
        return GenerationResult(np.stack(out, axis=1), prefill_s, decode_s,
                                max_new_tokens)
