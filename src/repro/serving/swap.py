"""Host-tier KV swap store: the preemption data plane (swap-out / swap-in).

When a high-priority request arrives and the paged pool is full, the
scheduler preempts a low-priority victim: the engine snapshots the victim's
page blocks (K/V per attention sublayer + the position rows) and its entire
per-slot decode state to the host, frees the device pages through the
ordinary allocator accounting, and parks a :class:`SwapRecord` here.  This
module is the host side of that tiering:

* **pinned host store** — records live in plain numpy buffers (the
  process-level analogue of pinned host memory: no device residency, ready
  to stage back at full link bandwidth).  Only the victim's *private*
  blocks are uniquely held here — shared prefix pages stay device-resident
  under their other readers (the allocator never evicts a shared page from
  under a sequence) — but the snapshot covers every block, so restore never
  depends on what happened to the trie while the victim was out.
* **staged swap-in** — restoration stages a record's arrays back through
  :class:`repro.core.transfer.StagingEngine` in **sequential** mode, the
  paper's winning host->device strategy (§V-D1: one transfer at a time at
  full bandwidth, overlapping the already-dispatched compute).
  :meth:`prefetch` enqueues the asynchronous ``device_put`` *ahead of*
  re-admission, so by the time a slot frees up the pages are typically
  already device-resident and :meth:`fetch` only has to block on the tail.
* **fault injection** — a :class:`repro.distributed.fault.FaultPlane` can
  poison reads: :meth:`fetch` then raises
  :class:`~repro.distributed.fault.InjectedFault` *before* handing the
  staged copy to the restore jit and drops the (possibly corrupt) staged
  buffers.  The host-side record itself is never touched by a poisoned
  read, so a retry re-stages the intact copy — the scheduler's retry/limit
  policy decides whether the request survives.

Conservation: :meth:`pages` is the store's total private-block count, which
:meth:`repro.serving.kvcache.PagedKVCache.assert_conserved` checks against
the allocator's ``swapped_pages`` ledger (``host_pages=store.pages()``);
:meth:`pages_by_kind` is the per-state-kind split (attention blocks, cross
pages, SSM records) audited by the dict form of the same call.

Records are per-kind (PR 9): a victim's snapshot carries its attention page
blocks, its cross-attention page row (enc-dec archs — read-only content,
restored verbatim) and its checkpointed SSM slot state (SSM/hybrid archs —
fixed-width records from :func:`repro.models.ssm.checkpoint_slot_state`),
all staged back through the same sequential lanes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Set

import numpy as np

from repro.core.tenancy import TenancyConfig, TenantTask, VirtualDevicePool
from repro.core.transfer import StagedChunk, StagingEngine, _tree_bytes
from repro.obs.telemetry import get_telemetry


@dataclasses.dataclass
class SwapRecord:
    """Everything needed to resume a preempted request token-exactly.

    The decode step reads nothing but (page content, position rows, the
    slot's page-table row, and the per-slot scalars below), and the PRNG
    schedule is ``fold_in(key, lstep)`` per emitted token — so restoring
    these bitwise and re-pointing the page table at pages holding the
    snapshot content makes the remaining decode indistinguishable from an
    uninterrupted run.
    """
    req: Any                        # the preempted request object
    priority: int
    target: int                     # total token budget
    temp: float
    top_k: int
    bucket: int
    ring: int
    tokens: List[int]               # collected so far (resume appends)
    chain_keys: List[bytes]         # prefix-trie keys of the prompt blocks
    written: Set[int]               # blocks the decode ring already wrote
    pos: int                        # per-slot scalars, read off the device
    remaining: int                  # at preemption time (bitwise resume)
    lstep: int
    key: np.ndarray                 # (2,) uint32 PRNG key
    logits: np.ndarray              # (V,) f32 last logits row
    host_kv: Dict[str, Dict[str, np.ndarray]]  # sub -> k/v, zero-padded to
    #                                 (S, max_blocks, P, H, D) — fixed width
    #                                 so the restore jit traces once
    host_pos: np.ndarray            # (max_blocks, P) int32 position rows
    n_private: int                  # blocks uniquely held by this record
    preemptions: int = 1            # times this request has been swapped
    t_first: Optional[float] = None  # first-token stamp (TTFT survives swap)
    # per-kind snapshots (PR 9): cross-attention pages (enc-dec archs) and
    # checkpointed SSM slot-state records (SSM/hybrid archs).  Keyword-only
    # in spirit — defaults keep pure-attention records source-compatible.
    host_cross: Optional[Dict[str, np.ndarray]] = None  # k/v (S, nbc, P, H, D)
    n_cross: int = 0                # cross pages held by this record
    host_state: Optional[Dict[str, Any]] = None  # sub -> {ssm, conv} records
    n_state: int = 0                # SSM records (one per SSM sublayer)


class HostSwapStore:
    """Ticketed host-side store of preempted requests' KV + decode state."""

    def __init__(self, staging: Optional[StagingEngine] = None,
                 fault_plane: Optional[Any] = None,
                 sharder: Optional[Any] = None):
        if staging is None:
            # sequential mode: the paper's winner for host->device staging
            staging = StagingEngine(
                VirtualDevicePool(TenancyConfig(1, 1, "sequential")))
        self.staging = staging
        self.fault_plane = fault_plane
        # per-mesh-slice staging lanes: swap-ins split along the KV-head
        # sharding and each shard stages on its own lane, landing already
        # committed to the pool's mesh layout (no post-restore reshard)
        self.sharder = sharder
        self.lanes = None
        if sharder is not None and sharder.mesh is not None:
            from repro.core.transfer import MeshStagingLanes
            self.lanes = MeshStagingLanes(sharder.mesh)
        self._records: Dict[int, SwapRecord] = {}
        self._staged: Dict[int, StagedChunk] = {}
        self._next_ticket = 0
        self.puts = 0
        self.fetches = 0
        self.poisoned_reads = 0
        # telemetry plane (owning engine re-points this at its own one)
        self.tel = get_telemetry(None)

    def retarget_telemetry(self, tel: Any) -> None:
        """Re-point the store *and its staging lanes* at ``tel`` — the
        lane engines record the ``transfer.stage`` spans, so an owning
        engine with an instance plane must redirect them too."""
        self.tel = tel
        self.staging.tel = tel
        if self.lanes is not None:
            self.lanes.tel = tel
            for eng in self.lanes.engines.values():
                eng.tel = tel

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._records)

    def pages(self) -> int:
        """Total private attention page blocks currently held by the host
        tier (the store half of the two-tier conservation audit)."""
        return sum(r.n_private for r in self._records.values())

    def pages_by_kind(self) -> Dict[str, int]:
        """Host-held blocks per state kind — the store half of the
        *per-kind* two-tier audit (:meth:`repro.serving.kvcache.
        PagedKVCache.assert_conserved` with a dict)."""
        recs = self._records.values()
        return {"attn": sum(r.n_private for r in recs),
                "cross": sum(r.n_cross for r in recs),
                "ssm": sum(r.n_state for r in recs)}

    def tickets(self) -> List[int]:
        return sorted(self._records)

    def record(self, ticket: int) -> SwapRecord:
        return self._records[ticket]

    # ------------------------------------------------------------------
    def put(self, rec: SwapRecord) -> int:
        # crash-at-swap injection (SIGKILL, no return): exercises the
        # mid-preemption crash window — the victim is host-gathered but no
        # PREEMPT journal record exists yet, so recovery must fall back to
        # the last checkpoint's view of the slot
        if self.fault_plane is not None:
            crash = getattr(self.fault_plane, "swap_put_crash", None)
            if crash is not None:
                crash()
        ticket = self._next_ticket
        self._next_ticket += 1
        self._records[ticket] = rec
        self.puts += 1
        if self.tel.enabled:
            self.tel.count("swap.puts")
            nbytes = _tree_bytes(rec.host_kv) + rec.host_pos.nbytes
            if rec.host_cross is not None:
                nbytes += _tree_bytes(rec.host_cross)
            if rec.host_state is not None:
                nbytes += _tree_bytes(rec.host_state)
            self.tel.count("swap.bytes_out", nbytes)
            self.tel.gauge("swap.host_pages", self.pages())
        return ticket

    def prefetch(self, ticket: int) -> None:
        """Enqueue the record's host->device transfer (asynchronous: returns
        immediately).  Idempotent; called ahead of re-admission so the
        staged copy overlaps whatever round is on the device."""
        if ticket in self._staged:
            return
        rec = self._records[ticket]
        tree = {"kv": rec.host_kv, "pos": rec.host_pos}
        if rec.host_cross is not None:
            tree["cross"] = rec.host_cross
        if rec.host_state is not None:
            tree["state"] = rec.host_state
        self.tel.event("swap.prefetch", ticket=ticket,
                       lanes=(self.lanes.n_lanes if self.lanes is not None
                              else 1))
        if self.lanes is not None:
            # KV blocks (S, max_blocks, P, Hkv, D) — self- or cross-attention
            # — shard along Hkv; position rows and SSM state records
            # replicate.  Each shard stages on its own lane.
            sh = self.sharder

            def sharding_of(a):
                axes = ((None, None, None, "kv", None) if a.ndim == 5
                        else (None,) * a.ndim)
                return sh.named(axes, a.shape)

            self._staged[ticket] = self.lanes.put(tree, sharding_of,
                                                  slot=ticket)
            return
        task = TenantTask(vdev=0, pdev=0, slot=0, start=0, stop=1)
        self._staged[ticket] = self.staging.put(task, tree)

    def fetch(self, ticket: int) -> Any:
        """Block until the record's arrays are device-resident and return
        the device pytree ``{"kv": ..., "pos": ...}``.  A poisoned read
        (fault plane) raises before the copy is handed out and discards the
        staged buffers — the host record stays intact for the retry."""
        if self.fault_plane is not None:
            try:
                self.fault_plane.swap_read_fault()
            except Exception:
                self.poisoned_reads += 1
                self.tel.count("swap.poisoned_reads")
                self._staged.pop(ticket, None)
                raise
        with self.tel.span("swap.fetch", ticket=ticket):
            self.prefetch(ticket)
            staged = self._staged.pop(ticket)
            if self.lanes is not None:
                arrays = self.lanes.wait(staged)
            else:
                arrays = self.staging.wait(staged).arrays
        self.fetches += 1
        if self.tel.enabled:
            self.tel.count("swap.fetches")
            self.tel.count("swap.bytes_in", _tree_bytes(arrays))
        return arrays

    def pop(self, ticket: int) -> SwapRecord:
        """Remove a record (successful restore, or terminal drop after a
        poisoned-read retry budget is exhausted)."""
        self._staged.pop(ticket, None)
        rec = self._records.pop(ticket)
        self.tel.gauge("swap.host_pages", self.pages())
        return rec

    def restore_records(self, records: Dict[int, SwapRecord]) -> None:
        """Re-park checkpointed records under their *original* tickets
        (crash recovery: the scheduler's restore queue names tickets, so
        ticket numbers must survive the process boundary).  The store must
        be empty — recovery rebuilds from scratch, never merges."""
        assert not self._records, "restore_records on a non-empty store"
        self._records = dict(records)
        self._next_ticket = max(self._records, default=-1) + 1
        self.tel.gauge("swap.host_pages", self.pages())


# ----------------------------------------------------------------------
# Checkpoint serialization (crash-safe serving)
# ----------------------------------------------------------------------
def _flatten_state(tree: Any, prefix: str, out: Dict[str, np.ndarray]):
    """Flatten a nested dict-of-arrays (SSM checkpoint records) into
    '/'-joined names; inverse is :func:`_unflatten_state`."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            _flatten_state(tree[k], f"{prefix}/{k}", out)
    else:
        out[prefix] = np.asarray(tree)


def _unflatten_state(arrays: Dict[str, np.ndarray], prefix: str) -> Any:
    sub: Dict[str, Any] = {}
    for name, arr in arrays.items():
        if not name.startswith(prefix + "/"):
            continue
        parts = name[len(prefix) + 1:].split("/")
        node = sub
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return sub


def swap_record_to_payload(rec: SwapRecord, req_record: Any
                           ) -> "tuple[Dict[str, Any], Dict[str, np.ndarray]]":
    """Serialize a record for an engine checkpoint: a json-able meta dict
    plus named numpy arrays (the format ``distributed/checkpoint.py``
    persists).  ``req_record`` is the caller-serialized request (the
    journal's SUBMIT payload — the store does not know about rids)."""
    meta = {
        "req": req_record,
        "priority": int(rec.priority), "target": int(rec.target),
        "temp": float(rec.temp), "top_k": int(rec.top_k),
        "bucket": int(rec.bucket), "ring": int(rec.ring),
        "tokens": [int(t) for t in rec.tokens],
        "chain_keys": [k.hex() for k in rec.chain_keys],
        "written": sorted(int(b) for b in rec.written),
        "pos": int(rec.pos), "remaining": int(rec.remaining),
        "lstep": int(rec.lstep), "n_private": int(rec.n_private),
        "preemptions": int(rec.preemptions),
        "t_first": None if rec.t_first is None else float(rec.t_first),
        "n_cross": int(rec.n_cross), "n_state": int(rec.n_state),
        "kv_subs": sorted(rec.host_kv),
        "has_cross": rec.host_cross is not None,
        "state_subs": (sorted(rec.host_state)
                       if rec.host_state is not None else None),
    }
    arrays: Dict[str, np.ndarray] = {
        "key": np.asarray(rec.key), "logits": np.asarray(rec.logits),
        "host_pos": np.asarray(rec.host_pos)}
    for sub, kv in rec.host_kv.items():
        arrays[f"kv/{sub}/k"] = np.asarray(kv["k"])
        arrays[f"kv/{sub}/v"] = np.asarray(kv["v"])
    if rec.host_cross is not None:
        arrays["cross/k"] = np.asarray(rec.host_cross["k"])
        arrays["cross/v"] = np.asarray(rec.host_cross["v"])
    if rec.host_state is not None:
        for sub, state in rec.host_state.items():
            _flatten_state(state, f"state/{sub}", arrays)
    return meta, arrays


def swap_record_from_payload(meta: Dict[str, Any],
                             arrays: Dict[str, np.ndarray],
                             req: Any) -> SwapRecord:
    """Inverse of :func:`swap_record_to_payload`.  ``req`` is the rebuilt
    request object (the caller owns request deserialization)."""
    host_kv = {sub: {"k": arrays[f"kv/{sub}/k"], "v": arrays[f"kv/{sub}/v"]}
               for sub in meta["kv_subs"]}
    host_cross = ({"k": arrays["cross/k"], "v": arrays["cross/v"]}
                  if meta["has_cross"] else None)
    host_state = None
    if meta["state_subs"] is not None:
        host_state = {sub: _unflatten_state(arrays, f"state/{sub}")
                      for sub in meta["state_subs"]}
    return SwapRecord(
        req=req, priority=meta["priority"], target=meta["target"],
        temp=meta["temp"], top_k=meta["top_k"], bucket=meta["bucket"],
        ring=meta["ring"], tokens=list(meta["tokens"]),
        chain_keys=[bytes.fromhex(k) for k in meta["chain_keys"]],
        written=set(meta["written"]), pos=meta["pos"],
        remaining=meta["remaining"], lstep=meta["lstep"],
        key=np.asarray(arrays["key"], np.uint32),
        logits=np.asarray(arrays["logits"], np.float32),
        host_kv=host_kv, host_pos=np.asarray(arrays["host_pos"], np.int32),
        n_private=meta["n_private"], preemptions=meta["preemptions"],
        t_first=meta["t_first"], host_cross=host_cross,
        n_cross=meta["n_cross"], host_state=host_state,
        n_state=meta["n_state"])
