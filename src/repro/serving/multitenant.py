"""Multi-tenant serving scheduler (the paper's second multi-tenancy reading:
several applications share one physical accelerator).

Each tenant owns a request queue; the scheduler round-robins *tenant slots*
on the shared device, so tenant k+1's host-side batch assembly and staging
overlap tenant k's on-device step — exactly the paper's sequential-transfer
overlap, applied to serving.  Per-tenant accounting feeds the straggler
detector and the planner's utilisation model.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro.core.tenancy import TenancyConfig
from repro.distributed.fault import StragglerDetector
from repro.serving.engine import GenerationResult, ServingEngine


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    arrival_s: float = dataclasses.field(default_factory=time.perf_counter)


@dataclasses.dataclass
class Response:
    tenant: str
    tokens: np.ndarray
    latency_s: float
    batch_size: int


class MultiTenantScheduler:
    """Round-robin tenant batching over one shared engine."""

    def __init__(self, engine: ServingEngine, max_batch: int = 8,
                 tenancy: Optional[TenancyConfig] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.tenancy = tenancy or TenancyConfig(1, 2)
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self.detector = StragglerDetector()
        self.stats: Dict[str, Dict[str, float]] = collections.defaultdict(
            lambda: {"requests": 0, "tokens": 0, "busy_s": 0.0})
        self._order: List[str] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.tenant not in self._order:
            self._order.append(req.tenant)
        self.queues[req.tenant].append(req)

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # ------------------------------------------------------------------
    def _next_tenant(self) -> Optional[str]:
        for _ in range(len(self._order)):
            t = self._order.pop(0)
            self._order.append(t)
            if self.queues[t]:
                return t
        return None

    def _assemble(self, tenant: str) -> List[Request]:
        q = self.queues[tenant]
        batch = []
        while q and len(batch) < self.max_batch:
            batch.append(q.popleft())
        return batch

    def step(self) -> Optional[List[Response]]:
        """Serve one tenant slot; returns its responses (None if idle)."""
        tenant = self._next_tenant()
        if tenant is None:
            return None
        reqs = self._assemble(tenant)
        # pad prompts to a common length (right-aligned batch)
        s_max = max(r.prompt.size for r in reqs)
        prompts = np.zeros((len(reqs), s_max), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, s_max - r.prompt.size:] = r.prompt
        steps = max(r.max_new_tokens for r in reqs)
        t0 = time.perf_counter()
        result: GenerationResult = self.engine.generate(prompts, steps)
        busy = time.perf_counter() - t0
        st = self.stats[tenant]
        st["requests"] += len(reqs)
        st["tokens"] += result.tokens.size
        st["busy_s"] += busy
        self.detector.update({hash(tenant) % (2 ** 31): busy / max(len(reqs), 1)})
        now = time.perf_counter()
        return [Response(tenant, result.tokens[i], now - r.arrival_s,
                         len(reqs)) for i, r in enumerate(reqs)]

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.pending():
            r = self.step()
            if r:
                out.extend(r)
        return out

    # ------------------------------------------------------------------
    def utilization_report(self) -> Dict[str, Dict[str, float]]:
        total_busy = sum(s["busy_s"] for s in self.stats.values())
        return {t: dict(s, busy_share=(s["busy_s"] / total_busy
                                       if total_busy else 0.0))
                for t, s in self.stats.items()}
