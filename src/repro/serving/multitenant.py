"""Multi-tenant serving scheduler (the paper's second multi-tenancy reading:
several applications share one physical accelerator).

Each tenant owns a request queue; the scheduler cycles *tenant slots* on the
shared device.  The engine exposes split ``dispatch``/``await_result``
halves (prefill + a single on-device ``lax.scan`` decode loop are enqueued
without blocking), so with ``overlapped=True`` (default) the scheduler runs
the paper's transfer-under-compute schedule at serving granularity: while
tenant k's decode loop occupies the device, the host assembles and stages
tenant k+1's padded batch and enqueues its prefill+decode — the serving
analogue of the stage(k+1)-under-compute(k) schedule the risk stack runs on
:class:`repro.core.pipeline.PipelineExecutor`.  ``overlapped=False`` keeps
the legacy blocking schedule (``engine.generate`` per slot, stage-ahead
limited to host-side batch assembly) as the A/B baseline.

Slot selection is straggler-aware: with ``straggler_priority=True`` the
scheduler serves the tenant with the slowest recent per-request time first
(the serving analogue of ``reorder_for_stragglers``), subject to the round
invariant that every backlogged tenant is served exactly once per round;
otherwise plain round-robin.  Per-slot :class:`repro.core.pipeline.
TenantTimeline` records (transfer window = batch assembly + staging
dispatch, compute window = dispatch -> device-ready) feed the benchmark
harness and the planner's utilisation model; in overlapped mode a shared
:class:`repro.core.pipeline.CompletionWaiter` stamps ``compute_end`` the
moment the decode output is ready, so :func:`repro.core.pipeline.
timeline_overlaps` is falsifiable on the serving timeline exactly as on the
risk pipeline's.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import CompletionWaiter, TenantTimeline
from repro.core.tenancy import TenancyConfig
from repro.distributed.fault import StragglerDetector
from repro.serving.engine import (GenerationResult, PendingGeneration,
                                  ServingEngine)


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    arrival_s: float = dataclasses.field(default_factory=time.perf_counter)


@dataclasses.dataclass
class Response:
    tenant: str
    tokens: np.ndarray
    latency_s: float
    batch_size: int


@dataclasses.dataclass
class _Inflight:
    """One dispatched tenant slot: requests + handle + its timeline entry
    (compute_end stamped by the CompletionWaiter at device readiness)."""
    tenant: str
    reqs: List[Request]
    handle: PendingGeneration
    entry: TenantTimeline
    stamped: Any                     # threading.Event from the waiter


class MultiTenantScheduler:
    """Tenant-slot batching over one shared engine (round-robin or
    straggler-priority), with tenant k+1's batch assembly + staging
    dispatched underneath tenant k's on-device decode."""

    def __init__(self, engine: ServingEngine, max_batch: int = 8,
                 tenancy: Optional[TenancyConfig] = None,
                 straggler_priority: bool = False,
                 overlapped: bool = True):
        self.engine = engine
        self.max_batch = max_batch
        self.tenancy = tenancy or TenancyConfig(1, 2)
        self.straggler_priority = straggler_priority
        self.overlapped = overlapped
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self.detector = StragglerDetector()
        self.stats: Dict[str, Dict[str, float]] = collections.defaultdict(
            lambda: {"requests": 0, "tokens": 0, "busy_s": 0.0})
        self.timeline: List[TenantTimeline] = []
        self._order: List[str] = []
        self._slot_of: Dict[str, int] = {}
        # blocking path: next tenant slot's pre-assembled batch (tenant,
        # reqs, prompts, steps) — assembled while the previous slot's
        # responses were being finalised (host-side stage-ahead)
        self._prepared: Optional[Tuple[str, List[Request], np.ndarray, int]] \
            = None
        self._asm_window = (0.0, 0.0)
        # overlapped path: the dispatched-but-not-awaited tenant slot
        self._inflight: Optional[_Inflight] = None
        self._waiter: Optional[CompletionWaiter] = None
        self._last_ready = 0.0           # previous slot's compute_end
        self._round_served: set = set()
        self._recent: Dict[str, float] = {}   # EWMA per-request seconds
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.tenant not in self._order:
            self._slot_of[req.tenant] = len(self._order)
            self._order.append(req.tenant)
        self.queues[req.tenant].append(req)

    def pending(self) -> int:
        n = sum(len(q) for q in self.queues.values())
        if self._prepared is not None:   # staged-ahead batch not yet served
            n += len(self._prepared[1])
        if self._inflight is not None:   # dispatched batch not yet awaited
            n += len(self._inflight.reqs)
        return n

    def close(self) -> None:
        """Reap the completion-waiter thread (daemon, so optional)."""
        if self._waiter is not None:
            self._waiter.close()
            self._waiter = None

    # ------------------------------------------------------------------
    # EWMA weight for per-tenant recent latency (straggler-priority pick)
    _RECENT_ALPHA = 0.5

    def _recent_s(self, tenant: str) -> float:
        return self._recent.get(tenant, 0.0)

    def _note_batch_time(self, tenant: str, per_req_s: float) -> None:
        """EWMA of per-request time: tracks *recent* speed, so a tenant that
        was slow long ago but recovered stops being prioritised (a lifetime
        mean would pin the priority to stale history)."""
        prev = self._recent.get(tenant)
        a = self._RECENT_ALPHA
        self._recent[tenant] = (per_req_s if prev is None
                                else a * per_req_s + (1 - a) * prev)

    def _next_tenant(self) -> Optional[str]:
        if self.straggler_priority:
            backlog = [t for t in self._order if self.queues[t]]
            if not backlog:
                return None
            # slowest recent tenant first *within a round*: every tenant
            # with backlog is served once before any tenant repeats, so the
            # priority orders a finite round (the serving analogue of
            # reorder_for_stragglers) instead of starving fast tenants
            fresh = [t for t in backlog if t not in self._round_served]
            if not fresh:
                self._round_served.clear()
                fresh = backlog
            pick = max(fresh, key=self._recent_s)
            self._round_served.add(pick)
            return pick
        for _ in range(len(self._order)):
            t = self._order.pop(0)
            self._order.append(t)
            if self.queues[t]:
                return t
        return None

    def _assemble(self, tenant: str) -> List[Request]:
        q = self.queues[tenant]
        batch = []
        while q and len(batch) < self.max_batch:
            batch.append(q.popleft())
        return batch

    def _build_batch(self, tenant: str
                     ) -> Optional[Tuple[str, List[Request], np.ndarray, int]]:
        reqs = self._assemble(tenant)
        if not reqs:
            return None
        # pad prompts to a common length (right-aligned batch)
        s_max = max(r.prompt.size for r in reqs)
        prompts = np.zeros((len(reqs), s_max), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, s_max - r.prompt.size:] = r.prompt
        return tenant, reqs, prompts, max(r.max_new_tokens for r in reqs)

    # ------------------------------------------------------------------
    # Accounting shared by both schedules
    # ------------------------------------------------------------------
    def _account(self, tenant: str, reqs: List[Request], tokens: np.ndarray,
                 busy_s: float) -> None:
        st = self.stats[tenant]
        st["requests"] += len(reqs)
        st["tokens"] += tokens.size
        st["busy_s"] += busy_s
        per_req = busy_s / max(len(reqs), 1)
        self._note_batch_time(tenant, per_req)
        # keyed by the stable tenant slot: hash(tenant) is salted per
        # process and can collide across tenants, which would merge two
        # tenants' EWMAs in the detector
        self.detector.update({self._slot_of[tenant]: per_req})

    # ------------------------------------------------------------------
    # Overlapped schedule: dispatch k+1's staging under k's decode
    # ------------------------------------------------------------------
    def _launch_next(self) -> Optional[_Inflight]:
        """Assemble + stage + dispatch the next tenant slot (non-blocking).

        transfer window = batch assembly through dispatch return (host
        staging of prompts + prefill/decode enqueue); compute window opens
        at dispatch return and is closed by the CompletionWaiter when the
        decode output is device-ready.
        """
        tenant = self._next_tenant()
        if tenant is None:
            return None
        asm_start = time.perf_counter() - self._t0
        # _next_tenant only returns tenants with backlog, so the batch is
        # never empty (and the tenant's round-served mark stays consistent)
        tenant, reqs, prompts, steps = self._build_batch(tenant)
        handle = self.engine.dispatch(prompts, steps)
        te = time.perf_counter() - self._t0
        slot = self._slot_of[tenant]
        entry = TenantTimeline(vdev=slot, pdev=0, slot=slot,
                               transfer_start=asm_start, transfer_end=te,
                               compute_start=te, compute_end=0.0)
        if self._waiter is None:
            self._waiter = CompletionWaiter(
                lambda: time.perf_counter() - self._t0,
                name="serving-waiter")
        stamped = self._waiter.submit(handle.tokens, entry)
        return _Inflight(tenant, reqs, handle, entry, stamped)

    def _step_overlapped(self) -> Optional[List[Response]]:
        if self._inflight is None:
            self._inflight = self._launch_next()
            if self._inflight is None:
                return None
        cur = self._inflight
        # overlap point: tenant k+1's assembly + staging + dispatch run here,
        # while tenant k's decode loop is still executing on the device
        self._inflight = self._launch_next()
        result = self.engine.await_result(cur.handle)
        cur.stamped.wait()           # compute_end stamped at device-ready
        # open the compute window at device occupancy, not dispatch return:
        # this slot was enqueued behind the previous slot's decode (the
        # device stream serialises them), and that queue wait must not be
        # billed to this tenant's busy/EWMA or double-counted in
        # utilisation.  The previous slot's compute_end is known here —
        # slots complete in dispatch order and slot k-1 was awaited before
        # slot k+1 was staged, so the clamp can only move compute_start
        # earlier than the next slot's transfer_start, never past it (the
        # overlap predicate stays falsifiable).
        cur.entry.compute_start = max(cur.entry.compute_start,
                                      min(self._last_ready,
                                          cur.entry.compute_end))
        self._last_ready = cur.entry.compute_end
        self._account(cur.tenant, cur.reqs, result.tokens,
                      cur.entry.compute_end - cur.entry.compute_start)
        self.timeline.append(cur.entry)
        done_abs = self._t0 + cur.entry.compute_end
        return [Response(cur.tenant, result.tokens[i],
                         done_abs - r.arrival_s, len(cur.reqs))
                for i, r in enumerate(cur.reqs)]

    # ------------------------------------------------------------------
    # Blocking schedule (A/B baseline): generate() per slot
    # ------------------------------------------------------------------
    def _stage_next(self) -> None:
        if self._prepared is None:
            tenant = self._next_tenant()
            if tenant is not None:
                asm_start = time.perf_counter() - self._t0
                self._prepared = self._build_batch(tenant)
                if self._prepared is not None:
                    self._asm_window = (asm_start,
                                        time.perf_counter() - self._t0)

    def _step_blocking(self) -> Optional[List[Response]]:
        self._stage_next()
        if self._prepared is None:
            return None
        tenant, reqs, prompts, steps = self._prepared
        self._prepared = None
        asm_start, asm_end = self._asm_window
        t0 = time.perf_counter()
        result: GenerationResult = self.engine.generate(prompts, steps)
        done = time.perf_counter()       # service completion: BEFORE the
        busy = done - t0                 # stage-ahead work below, so the
        # compute window and latencies don't absorb the next slot's assembly
        # (stats recorded first so the stage-ahead pick sees this batch's
        # fresh latency, not stale data)
        self._account(tenant, reqs, result.tokens, busy)
        # stage-ahead: assemble the next slot's batch before finalising this
        # slot's responses (host-side analogue of stage(k+1) under compute(k))
        self._stage_next()
        self.timeline.append(TenantTimeline(
            vdev=self._slot_of[tenant], pdev=0, slot=self._slot_of[tenant],
            transfer_start=asm_start, transfer_end=asm_end,
            compute_start=t0 - self._t0, compute_end=done - self._t0))
        return [Response(tenant, result.tokens[i], done - r.arrival_s,
                         len(reqs)) for i, r in enumerate(reqs)]

    # ------------------------------------------------------------------
    def step(self) -> Optional[List[Response]]:
        """Serve one tenant slot; returns its responses (None if idle)."""
        if self.overlapped:
            return self._step_overlapped()
        return self._step_blocking()

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.pending():
            r = self.step()
            if r:
                out.extend(r)
        # reap the now-idle completion-waiter thread so schedulers that end
        # with drain() (the common shape) don't each park a daemon thread
        # rooting the scheduler; it is recreated lazily on the next launch
        self.close()
        return out

    # ------------------------------------------------------------------
    def utilization_report(self) -> Dict[str, Dict[str, float]]:
        total_busy = sum(s["busy_s"] for s in self.stats.values())
        return {t: dict(s, busy_share=(s["busy_s"] / total_busy
                                       if total_busy else 0.0))
                for t, s in self.stats.items()}
