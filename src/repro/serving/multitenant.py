"""Multi-tenant serving scheduler (the paper's second multi-tenancy reading:
several applications share one physical accelerator).

Each tenant owns a request queue; the scheduler serves them on one shared
engine under one of three schedules (``mode=``):

* ``"continuous"`` — continuous batching over a persistent slot table
  (:class:`repro.serving.continuous.ContinuousBatchingEngine`): each outer
  step admits queued requests into free slots (picked round-robin or
  straggler-priority across tenants, then admitted as *one batch* — all
  same-bucket picks share one batched prefill call, and prefix sharing maps
  common prompt prefixes onto existing pages), dispatches one masked
  fixed-step decode micro-round over *all* slots, and retires rows that hit
  their token budget, dropping their :class:`repro.serving.kvcache.
  PagedKVCache` page references.  The device never drains between tenant
  batches and short requests never pad out long ones — the finest-grained
  sharing of the three, and the paper's utilisation argument taken to
  per-request granularity.  Admission + the next round's dispatch run while
  the previous round still occupies the device, so the same falsifiable
  :func:`repro.core.pipeline.timeline_overlaps` predicate applies
  round-to-round.  When the in-flight round has already landed by the time
  a step runs, it is collected *first* (retire-before-dispatch fast path):
  finished rows are evicted and their slots/pages offered to this step's
  admissions before round k+1 dispatches, instead of riding one extra round
  as masked lanes.  Per-request admission windows are stamped into
  ``admission_timeline`` (batch-admitted requests share one transfer
  window).
* ``"overlapped"`` (default) — tenant-slot batching on the engine's split
  ``dispatch``/``await_result`` halves: while tenant k's scanned decode
  occupies the device, the host assembles, stages and dispatches up to
  ``stage_depth`` further tenant batches (a depth-N generalisation of PR 2's
  double buffering).
* ``"blocking"`` — the legacy host-blocking ``engine.generate`` per slot
  (stage-ahead limited to host-side batch assembly), kept as the A/B
  baseline.

Slot selection is straggler-aware: with ``straggler_priority=True`` the
scheduler serves the tenant with the slowest recent per-request time first,
subject to the round invariant that every backlogged tenant is served
exactly once per round.  The EWMA is stamped *as soon as a completion has
landed* — before the next pick — via :meth:`_harvest_ready`, closing PR 2's
one-batch lag (the pick for slot k+1 used to run before slot k's completion
could stamp its latency even when the device was already done).

Per-slot :class:`repro.core.pipeline.TenantTimeline` records (transfer
window = batch assembly / admission + staging dispatch, compute window =
dispatch -> device-ready) feed the benchmark harness; a shared
:class:`repro.core.pipeline.CompletionWaiter` stamps ``compute_end`` the
moment the decode output is ready, so :func:`repro.core.pipeline.
timeline_overlaps` is falsifiable on the serving timeline exactly as on the
risk pipeline's.

Priority, preemption & overload (continuous mode)
-------------------------------------------------

The paper's on-demand sharing claim only holds if the shared device
degrades *gracefully* past saturation, so the continuous schedule carries
an overload-survival layer:

* **priority classes + fair share** — each :class:`Request` carries a
  ``priority`` tier (0 = highest; default 1) and an optional ``deadline_s``
  hint.  When queue heads span more than one tier, or a tenant holds more
  than its fair share of the paged pool while a same-tier tenant with
  backlog holds less, admission picks by ``(priority, over-share, deadline,
  row-steps consumed)`` instead of the plain rotation; workloads that never
  set priorities keep the legacy round-robin / straggler order bit-for-bit.
  Per-tenant pages held and decode row-steps consumed are the fair-share
  accounting inputs.
* **bounded retry + backoff, terminal REJECTED** — an admission the pool
  refuses no longer raises: the request re-queues with exponential backoff
  (clocked by admission passes) and, after ``admission_retry_limit``
  failed attempts, lands in a terminal ``REJECTED`` outcome — an empty
  :class:`Response` with ``outcome="rejected"``, surfaced through
  :meth:`step`/:meth:`drain` and the per-tenant stats.  When the backlog
  exceeds ``max_backlog`` (the SLO bound), the lowest-priority,
  furthest-deadline queued work is load-shed the same way (``shed`` stat).
* **preemption via KV tiering** — when a higher-priority request cannot
  admit and a strictly lower-priority row is live, the scheduler
  force-collects the in-flight round (preemption needs a quiesced engine)
  and swaps the victim out through :meth:`repro.serving.continuous.
  ContinuousBatchingEngine.preempt` (pages to the host-side
  :class:`repro.serving.swap.HostSwapStore`, shared prefix pages left
  under their readers).  Swapped requests wait in a restore queue served
  *before* fresh picks of their own or lower tiers (free slots are left
  to strictly-higher-priority waiting arrivals — a lower-tier restore
  never re-takes the slot a blocked tier-0 request needs), stage their
  pages back through the
  sequential :class:`repro.core.transfer.StagingEngine` with async
  prefetch, and resume token-exactly.  Every state kind swaps (PR 9):
  attention and cross-attention pages as blocks, SSM slot state as
  fixed-width checkpoint records — so SSM/hybrid and encoder-decoder
  rows are ordinary preemption victims, picked by priority alone.
* **graceful degradation under faults** — a :class:`repro.distributed.
  fault.FaultPlane` can drop rounds, stall admissions and poison swap
  reads; each injection raises before state mutates and feeds a retry/limit
  policy (``round_fault_limit``): transient faults are retried, persistent
  ones land requests in terminal ``FAILED`` outcomes instead of crashing
  or hanging the drain.  A :class:`repro.distributed.fault.
  HeartbeatMonitor` is beaten once per collected round; missed beats are
  counted in ``heartbeat_suspects``.

Every submitted request therefore ends in exactly one terminal outcome —
``completed``, ``rejected`` or ``failed`` — and ``drain()`` returns a
response for each.  Completed responses carry ``ttft_s`` (first collected
token minus arrival) and their ``preemptions`` count for the load harness's
per-priority latency reporting.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import CompletionWaiter, TenantTimeline
from repro.core.tenancy import TenancyConfig
from repro.distributed import checkpoint as ckpt_mod
from repro.distributed.fault import (HeartbeatMonitor, InjectedFault,
                                     StragglerDetector)
from repro.obs.telemetry import Telemetry, get_telemetry, record_timeline
from repro.serving import journal as journal_mod
from repro.serving.engine import (GenerationResult, PendingGeneration,
                                  ServingEngine, resolve_extra_inputs)
from repro.serving.journal import JournalWriter, RecoverySummary
from repro.serving.swap import (swap_record_from_payload,
                                swap_record_to_payload)

MODES = ("continuous", "overlapped", "blocking")
OUTCOMES = ("completed", "rejected", "failed")


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    # per-request sampling: None temperature inherits the engine default;
    # top_k=0 disables truncation.  Honoured by the overlapped schedule
    # (threaded through the scanned decode-loop carry) and the continuous
    # schedule (slot-table carry); the blocking baseline stays engine-level.
    temperature: Optional[float] = None
    top_k: int = 0
    seed: int = 0
    arrival_s: float = dataclasses.field(default_factory=time.perf_counter)
    # overload layer (continuous mode): priority tier (0 = highest; lower
    # tiers are admitted first, shed last, and preempt higher numbers) and
    # an optional absolute-deadline hint used to order same-tier admissions
    # and pick shedding victims
    priority: int = 1
    deadline_s: Optional[float] = None
    # non-token prefill inputs, per-request and without a batch axis (e.g.
    # {"patch_embeds": (num_patches, 1024)} for vision archs, {"frames":
    # (encoder_seq_len, d_model)} for encoder-decoder archs — the latter
    # defaults to zero frames via resolve_extra_inputs when omitted).
    # Batching paths stack them; the continuous engine folds them into the
    # prefix-sharing chain keys so only identical extras share pages.
    extra_inputs: Optional[Dict[str, Any]] = None


@dataclasses.dataclass
class Response:
    tenant: str
    tokens: np.ndarray
    latency_s: float
    batch_size: int
    # terminal outcome: "completed" (tokens valid), "rejected" (admission
    # retry budget or load shed; tokens empty) or "failed" (fault-injection
    # limit exceeded; tokens empty)
    outcome: str = "completed"
    ttft_s: Optional[float] = None   # first collected token minus arrival
    priority: int = 1
    preemptions: int = 0             # times the row was swapped out


@dataclasses.dataclass
class _Inflight:
    """One dispatched tenant slot: requests + handle + its timeline entry
    (compute_end stamped by the CompletionWaiter at device readiness)."""
    tenant: str
    reqs: List[Request]
    handle: PendingGeneration
    entry: TenantTimeline
    stamped: Any                     # threading.Event from the waiter
    accounted: bool = False          # EWMA/busy already stamped (harvest)


@dataclasses.dataclass
class _InflightRound:
    """One dispatched continuous-batching micro-round."""
    handle: Any                      # continuous.RoundHandle
    entry: TenantTimeline
    stamped: Any


class MultiTenantScheduler:
    """Tenant batching over one shared engine (round-robin or
    straggler-priority) under a continuous, overlapped or blocking schedule
    (see module docstring)."""

    def __init__(self, engine: ServingEngine, max_batch: int = 8,
                 tenancy: Optional[TenancyConfig] = None,
                 straggler_priority: bool = False,
                 overlapped: bool = True,
                 mode: Optional[str] = None,
                 stage_depth: int = 1,
                 continuous: Optional[Dict[str, Any]] = None,
                 continuous_engine: Optional[Any] = None,
                 preemption: bool = True,
                 max_backlog: Optional[int] = None,
                 admission_retry_limit: int = 8,
                 round_fault_limit: int = 3,
                 fault_plane: Optional[Any] = None,
                 heartbeat_timeout_s: float = 300.0,
                 restore_prefetch: int = 4,
                 telemetry: Optional[Telemetry] = None,
                 journal: Optional[Any] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 checkpoint_keep: int = 3):
        self.engine = engine
        self.tel = get_telemetry(telemetry)
        self.max_batch = max_batch
        self.tenancy = tenancy or TenancyConfig(1, 2)
        self.straggler_priority = straggler_priority
        self.mode = mode or ("overlapped" if overlapped else "blocking")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}")
        if self.mode != "continuous" and (journal is not None
                                          or checkpoint_dir is not None):
            # only the continuous collect loop emits ROUND_COMMIT/RETIRE,
            # so a journal written under another mode would have SUBMITs
            # with no terminal records — recover() would then re-decode
            # every already-completed request as pending
            raise ValueError(
                "journal/checkpoint_dir require mode='continuous' "
                f"(got mode={self.mode!r})")
        self.overlapped = self.mode == "overlapped"
        self.stage_depth = max(int(stage_depth), 1)
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self.detector = StragglerDetector()
        self.stats: Dict[str, Dict[str, float]] = collections.defaultdict(
            lambda: {"requests": 0, "tokens": 0, "busy_s": 0.0,
                     "rejected": 0, "failed": 0, "preempted": 0, "shed": 0})
        self.timeline: List[TenantTimeline] = []
        self._order: List[str] = []
        self._slot_of: Dict[str, int] = {}
        # blocking path: next tenant slot's pre-assembled batch (tenant,
        # reqs, prompts, steps) — assembled while the previous slot's
        # responses were being finalised (host-side stage-ahead)
        self._prepared: Optional[Tuple[str, List[Request], np.ndarray, int]] \
            = None
        self._asm_window = (0.0, 0.0)
        # overlapped path: dispatched-but-not-awaited tenant slots, oldest
        # first; holds at most 1 + stage_depth entries (the one being
        # awaited plus the staged-ahead queue)
        self._inflight: Deque[_Inflight] = collections.deque()
        self._waiter: Optional[CompletionWaiter] = None
        self._last_ready = 0.0           # previous slot's compute_end
        self._round_served: set = set()
        self._recent: Dict[str, float] = {}   # EWMA per-request seconds
        self._t0 = time.perf_counter()
        # continuous path: pass continuous_engine to share one (compiled)
        # ContinuousBatchingEngine across scheduler instances — jit caches
        # are per-engine, and a drained engine's slot table is fully reusable
        self._ceng = None
        if self.mode == "continuous":
            if continuous_engine is not None:
                self._ceng = continuous_engine
            else:
                from repro.serving.continuous import ContinuousBatchingEngine
                ckw = dict(continuous or {})
                if fault_plane is not None:
                    ckw.setdefault("fault_plane", fault_plane)
                ckw.setdefault("telemetry", telemetry)
                self._ceng = ContinuousBatchingEngine(engine, **ckw)
        self._cont_inflight: Optional[_InflightRound] = None
        self._cont_rounds = 0
        self._row_busy: Dict[int, float] = collections.defaultdict(float)
        # ---- overload-survival layer (continuous mode) ----
        self.preemption = preemption
        self.max_backlog = max_backlog
        self.admission_retry_limit = int(admission_retry_limit)
        self.round_fault_limit = int(round_fault_limit)
        self.restore_prefetch = max(int(restore_prefetch), 1)
        self.fault_plane = fault_plane or getattr(self._ceng, "fault_plane",
                                                  None)
        self.heartbeat = HeartbeatMonitor(timeout_s=heartbeat_timeout_s)
        self.heartbeat_suspects = 0
        self.faults_survived = 0        # injected faults retried past
        self.rejected: List[Request] = []
        self.failed: List[Request] = []
        self._terminal: List[Response] = []   # awaiting emission via step()
        self._adm_clock = 0             # admission passes (backoff clock)
        self._attempts: Dict[int, int] = {}       # id(req) -> failed admits
        self._backoff: Dict[int, int] = {}        # id(req) -> eligible clock
        self._restore_q: List[int] = []           # swap tickets to re-admit
        self._ticket_attempts: Dict[int, int] = {}
        self._ticket_backoff: Dict[int, int] = {}
        self._tenant_steps: Dict[str, int] = collections.defaultdict(int)
        self._round_fault_streak = 0
        self._admission_blocked = False
        # continuous path: one entry per admitted request (vdev/slot = the
        # tenant slot, transfer window = its admission batch's host window:
        # pick + batched prefill + page mapping + state scatter).  Kept
        # separate from `timeline` so the round-level overlap predicate
        # isn't polluted by degenerate compute windows.
        self.admission_timeline: List[TenantTimeline] = []
        # ---- crash-safety layer (continuous mode) ----
        # write-ahead journal (path or JournalWriter) + periodic engine
        # checkpoints every `checkpoint_every` committed rounds; recover()
        # rebuilds a fresh scheduler/engine pair from the (journal,
        # latest-checkpoint) pair after a crash (mode='continuous' only —
        # validated up top, before any state is built)
        self.journal: Optional[JournalWriter] = None
        if journal is not None:
            self.journal = (journal if isinstance(journal, JournalWriter)
                            else JournalWriter(str(journal),
                                               telemetry=telemetry))
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        self.checkpoint_keep = int(checkpoint_keep)
        self.checkpoints_taken = 0
        self._rids: Dict[int, int] = {}       # id(req) -> stable journal rid
        self._next_rid = 0
        self._committed_rounds = 0            # collected decode rounds
        self._last_ckpt_round = 0
        self._ckpt_step = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        # WAL discipline: the SUBMIT record is durably on disk *before* the
        # queue mutation, so a crash between the two re-queues the request
        # on recovery instead of losing it
        if self.journal is not None:
            rid = self._next_rid
            self._next_rid += 1
            self._rids[id(req)] = rid
            self.journal.append(
                "SUBMIT", **journal_mod.request_to_record(rid, req))
        self._enqueue(req)

    def _register_tenant(self, tenant: str) -> None:
        if tenant not in self._order:
            self._slot_of[tenant] = len(self._order)
            self._order.append(tenant)

    def _enqueue(self, req: Request) -> None:
        self._register_tenant(req.tenant)
        self.queues[req.tenant].append(req)

    # ------------------------------------------------------------------
    # crash-safety: journal hooks (no-ops without a journal)
    # ------------------------------------------------------------------
    def _rid(self, req: Any) -> int:
        return self._rids.get(id(req), -1)

    def _journal(self, kind: str, **fields: Any) -> None:
        if self.journal is not None:
            self.journal.append(kind, **fields)

    def _journal_admits(self, reqs: List[Request]) -> None:
        """ADMIT records for freshly admitted picks: scan the slot table
        for the rows these request objects landed in."""
        if self.journal is None or not reqs:
            return
        want = {id(r) for r in reqs}
        for c, s in enumerate(self._ceng._slots):
            if s is not None and id(s.req) in want:
                self.journal.append(
                    "ADMIT", rid=self._rid(s.req), slot=int(c),
                    bucket=int(s.bucket), ring=int(s.ring))

    def _journal_round(self, res: Any) -> None:
        """One collected micro-round: cumulative emitted token counts for
        every row that was live in it (retired rows report their final
        count; JSON object keys must be strings, replay int()s them)."""
        self._committed_rounds += 1
        if self.journal is None:
            return
        emitted: Dict[str, int] = {}
        for (req, tokens, _c), _srec in zip(res.finished, res.retired):
            emitted[str(self._rid(req))] = int(tokens.size)
        for s in self._ceng._slots:
            if s is not None:
                emitted[str(self._rid(s.req))] = len(s.tokens)
        self.journal.append("ROUND_COMMIT", rnd=self._committed_rounds,
                            emitted=emitted)

    # ------------------------------------------------------------------
    # crash-safety: engine checkpoint + recovery (continuous mode)
    # ------------------------------------------------------------------
    def _checkpoint_due(self, pending: int = 0) -> bool:
        """`pending` counts rounds that are collected-but-not-yet-
        journalled at the call site (the dispatch-suppression check runs
        before the current round's ROUND_COMMIT lands) — without it the
        quiesce bubble, and hence the checkpoint, would trigger one round
        late: every K+1 committed rounds instead of every K."""
        return (self.checkpoint_dir is not None
                and self.checkpoint_every > 0
                and self._committed_rounds + pending - self._last_ckpt_round
                >= self.checkpoint_every)

    def save_checkpoint(self) -> int:
        """Snapshot the whole serving state to disk (engine quiesced: no
        round in flight).  Data plane: one :class:`~repro.serving.swap.
        SwapRecord` payload per live slot (the preemption host-gather,
        without vacating the slot) plus the host swap tier's records under
        their original tickets.  Control plane: the queued requests in
        admission order, the restore queue, ticket retry budgets, and the
        prefix-trie chain keys (audit).  Written via
        :func:`repro.distributed.checkpoint.save_engine_checkpoint`
        (marker-file atomicity), then journalled as a CHECKPOINT record —
        the recovery baseline."""
        eng = self._ceng
        assert eng is not None and self._cont_inflight is None, \
            "engine checkpoint requires a quiesced continuous engine"
        step = self._ckpt_step
        self._ckpt_step += 1
        arrays: Dict[str, np.ndarray] = {}
        live_meta: List[Dict[str, Any]] = []
        for c, rec in eng.snapshot_live():
            m, arrs = swap_record_to_payload(
                rec, journal_mod.request_to_record(self._rid(rec.req),
                                                   rec.req))
            live_meta.append({"slot": int(c), "rid": self._rid(rec.req),
                              "rec": m})
            for k, v in arrs.items():
                arrays[f"live/{c}/{k}"] = v
        swapped_meta: List[Dict[str, Any]] = []
        if eng.swap_store is not None:
            for ticket in eng.swap_store.tickets():
                rec = eng.swap_store.record(ticket)
                m, arrs = swap_record_to_payload(
                    rec, journal_mod.request_to_record(
                        self._rid(rec.req), rec.req))
                swapped_meta.append({"ticket": int(ticket),
                                     "rid": self._rid(rec.req), "rec": m})
                for k, v in arrs.items():
                    arrays[f"swapped/{ticket}/{k}"] = v
        queued = [journal_mod.request_to_record(self._rid(r), r)
                  for t in self._order for r in self.queues[t]]
        meta = {
            "step": int(step),
            "rounds": int(self._committed_rounds),
            "next_rid": int(self._next_rid),
            "live": live_meta,
            "swapped": swapped_meta,
            "queued": queued,
            "restore_q": [int(t) for t in self._restore_q],
            "ticket_attempts": {str(k): int(v) for k, v in
                                self._ticket_attempts.items()},
            "trie": [k.hex() for k in eng.kv.trie_keys()],
        }
        ckpt_mod.save_engine_checkpoint(self.checkpoint_dir, step, meta,
                                        arrays,
                                        keep_last=self.checkpoint_keep)
        self._last_ckpt_round = self._committed_rounds
        self.checkpoints_taken += 1
        self._journal("CHECKPOINT", step=int(step),
                      rnd=int(self._committed_rounds))
        if self.tel.enabled:
            self.tel.count("recovery.checkpoints")
        return step

    def recover(self) -> RecoverySummary:
        """Rebuild serving state on a *fresh* scheduler/engine pair from
        the (journal, latest checkpoint) pair after a crash.

        * checkpointed live slots re-enter the pool through the ordinary
          swap-restore path (same jits, same staging lanes — so a 1x8 mesh
          checkpoint restores onto any mesh the engine runs on);
        * checkpointed host-tier records re-park under their original
          tickets, with the pool's two-tier ledgers seeded to match;
        * checkpointed queued requests re-queue in admission order;
        * journalled-but-never-checkpointed rids (SUBMIT without terminal
          outcome or checkpoint presence) re-queue — never lost;
        * rounds committed after the checkpoint are *replayed*: seeded
          sampling makes the re-decoded tokens bitwise-identical for
          non-MoE archs, and journalled post-checkpoint RETIRE records
          become the ``replay_check`` oracle.

        Wall clocks (``arrival_s``/``t_first``) are process-relative and
        meaningless across the crash: every rebuilt request is re-stamped
        to recovery time."""
        assert self.mode == "continuous", "recover() is continuous-only"
        assert self.journal is not None, "recover() needs a journal"
        eng = self._ceng
        assert eng.active_count() == 0 and not any(
            len(q) for q in self.queues.values()), \
            "recover() must run on a fresh scheduler"
        with self.tel.span("recovery.replay") as sp:
            js = journal_mod.replay(journal_mod.read_journal(
                self.journal.path))
            step = (ckpt_mod.latest_engine_step(self.checkpoint_dir)
                    if self.checkpoint_dir is not None else None)
            meta, arrays = ((None, None) if step is None else
                            ckpt_mod.load_engine_checkpoint(
                                self.checkpoint_dir, step))
            now = time.perf_counter()
            accounted: set = set()
            live_recs: List[Any] = []
            swapped_recs: Dict[int, Any] = {}
            tokens_preserved = 0

            def _rebuild(ent: Dict[str, Any], prefix: str):
                sub = {k[len(prefix):]: v for k, v in arrays.items()
                       if k.startswith(prefix)}
                req = journal_mod.request_from_record(ent["rec"]["req"])
                req.arrival_s = now
                self._register_tenant(req.tenant)
                rec = swap_record_from_payload(ent["rec"], sub, req)
                rec.t_first = None
                self._rids[id(req)] = int(ent["rid"])
                accounted.add(int(ent["rid"]))
                return rec

            if meta is not None:
                for ent in meta["live"]:
                    rec = _rebuild(ent, f"live/{ent['slot']}/")
                    live_recs.append(rec)
                    tokens_preserved += len(rec.tokens)
                for ent in meta["swapped"]:
                    rec = _rebuild(ent, f"swapped/{ent['ticket']}/")
                    swapped_recs[int(ent["ticket"])] = rec
                    tokens_preserved += len(rec.tokens)
            eng.restore_from(live_recs, swapped_recs)
            if meta is not None:
                self._restore_q = [int(t) for t in meta["restore_q"]]
                self._ticket_attempts = {
                    int(k): int(v)
                    for k, v in meta["ticket_attempts"].items()}
                for qrec in meta["queued"]:
                    req = journal_mod.request_from_record(qrec)
                    req.arrival_s = now
                    self._rids[id(req)] = int(qrec["rid"])
                    accounted.add(int(qrec["rid"]))
                    self._enqueue(req)
                self._committed_rounds = max(int(meta["rounds"]),
                                             js.last_round)
                self._ckpt_step = int(meta["step"]) + 1
            else:
                self._committed_rounds = js.last_round
            self._last_ckpt_round = self._committed_rounds
            # journalled but neither terminal nor checkpointed: SUBMIT hit
            # disk before the crash, so the request re-queues — the "never
            # lost" half of the WAL contract
            requeued = 0
            for rid in js.pending():
                if rid in accounted:
                    continue
                req = journal_mod.request_from_record(js.submitted[rid])
                req.arrival_s = now
                self._rids[id(req)] = rid
                self._enqueue(req)
                requeued += 1
            self._next_rid = max(js.next_rid,
                                 0 if meta is None else int(
                                     meta["next_rid"]))
            already, oracle = {}, {}
            for rid, toks in js.retired_tokens.items():
                (oracle if rid in accounted else already)[rid] = toks
            summary = RecoverySummary(
                checkpoint_step=step,
                restored_live=len(live_recs),
                restored_swapped=len(swapped_recs),
                requeued=requeued,
                already_complete=already,
                replay_check=oracle,
                rounds_replayed=js.rounds_after_checkpoint,
                tokens_preserved=tokens_preserved,
                tokens_replayed=js.tokens_after_checkpoint)
            sp.note(step=-1 if step is None else int(step),
                    restored_live=summary.restored_live,
                    restored_swapped=summary.restored_swapped,
                    requeued=summary.requeued,
                    rounds_replayed=summary.rounds_replayed)
        self._journal("RECOVER", step=-1 if step is None else int(step),
                      restored_live=summary.restored_live,
                      restored_swapped=summary.restored_swapped,
                      requeued=summary.requeued,
                      rounds_replayed=summary.rounds_replayed)
        self.heartbeat.beat()
        return summary

    def pending(self) -> int:
        n = sum(len(q) for q in self.queues.values())
        if self._prepared is not None:   # staged-ahead batch not yet served
            n += len(self._prepared[1])
        n += sum(len(fl.reqs) for fl in self._inflight)   # dispatched slots
        if self._ceng is not None:       # admitted, not yet retired rows
            n += self._ceng.active_count()
        n += len(self._restore_q)        # swapped out, awaiting re-admission
        n += len(self._terminal)         # terminal responses to emit
        return n

    def close(self) -> None:
        """Reap the completion-waiter thread (daemon, so optional)."""
        if self._waiter is not None:
            self._waiter.close()
            self._waiter = None

    # ------------------------------------------------------------------
    # EWMA weight for per-tenant recent latency (straggler-priority pick)
    _RECENT_ALPHA = 0.5

    def _recent_s(self, tenant: str) -> float:
        return self._recent.get(tenant, 0.0)

    def _note_batch_time(self, tenant: str, per_req_s: float) -> None:
        """EWMA of per-request time: tracks *recent* speed, so a tenant that
        was slow long ago but recovered stops being prioritised (a lifetime
        mean would pin the priority to stale history)."""
        prev = self._recent.get(tenant)
        a = self._RECENT_ALPHA
        self._recent[tenant] = (per_req_s if prev is None
                                else a * per_req_s + (1 - a) * prev)

    def _next_tenant(self) -> Optional[str]:
        if self.straggler_priority:
            backlog = [t for t in self._order if self.queues[t]]
            if not backlog:
                return None
            # slowest recent tenant first *within a round*: every tenant
            # with backlog is served once before any tenant repeats, so the
            # priority orders a finite round (the serving analogue of
            # reorder_for_stragglers) instead of starving fast tenants
            fresh = [t for t in backlog if t not in self._round_served]
            if not fresh:
                self._round_served.clear()
                fresh = backlog
            pick = max(fresh, key=self._recent_s)
            self._round_served.add(pick)
            return pick
        for _ in range(len(self._order)):
            t = self._order.pop(0)
            self._order.append(t)
            if self.queues[t]:
                return t
        return None

    def _assemble(self, tenant: str) -> List[Request]:
        q = self.queues[tenant]
        batch = []
        while q and len(batch) < self.max_batch:
            batch.append(q.popleft())
        return batch

    def _build_batch(self, tenant: str
                     ) -> Optional[Tuple[str, List[Request], np.ndarray, int]]:
        reqs = self._assemble(tenant)
        if not reqs:
            return None
        # pad prompts to a common length (right-aligned batch)
        s_max = max(r.prompt.size for r in reqs)
        prompts = np.zeros((len(reqs), s_max), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, s_max - r.prompt.size:] = r.prompt
        return tenant, reqs, prompts, max(r.max_new_tokens for r in reqs)

    def _batch_extras(self, reqs: List[Request]
                      ) -> Optional[Dict[str, np.ndarray]]:
        """Stack the batch's per-request non-token prefill inputs (None when
        no request carries any).  A key missing from some rows is zero-
        filled — sound for encoder frames (resolve_extra_inputs defaults
        them anyway), but mixing with-image and text-only vision requests
        in one tenant batch merges zero patches into the text-only rows, so
        keep a tenant's extras uniform (the continuous schedule groups by
        extra-key signature instead and has no such caveat)."""
        cfg = getattr(self.engine, "cfg", None)
        if cfg is None:      # engine test-doubles: no per-arch defaults
            per_req = [dict(getattr(r, "extra_inputs", None) or {})
                       for r in reqs]
        else:
            per_req = [resolve_extra_inputs(cfg, r) for r in reqs]
        names = sorted({k for ex in per_req for k in ex})
        if not names:
            return None
        out = {}
        for name in names:
            proto = next(np.asarray(ex[name]) for ex in per_req
                         if name in ex)
            out[name] = np.stack([np.asarray(ex[name]) if name in ex
                                  else np.zeros_like(proto)
                                  for ex in per_req])
        return out

    def _sampling_kwargs(self, reqs: List[Request]) -> Dict[str, Any]:
        """Per-request sampling arrays for dispatch(); empty when every row
        uses engine defaults so the scalar (token-exact) path keeps running."""
        if not any(r.temperature is not None or r.top_k or r.seed
                   for r in reqs):
            return {}
        return {
            "temperatures": [self.engine.temperature if r.temperature is None
                             else r.temperature for r in reqs],
            "top_ks": [r.top_k for r in reqs],
            "seeds": [r.seed for r in reqs],
        }

    # ------------------------------------------------------------------
    # Accounting shared by the schedules
    # ------------------------------------------------------------------
    def _account_busy(self, tenant: str, n_reqs: int, busy_s: float) -> None:
        st = self.stats[tenant]
        st["requests"] += n_reqs
        st["busy_s"] += busy_s
        per_req = busy_s / max(n_reqs, 1)
        self._note_batch_time(tenant, per_req)
        # keyed by the stable tenant slot: hash(tenant) is salted per
        # process and can collide across tenants, which would merge two
        # tenants' EWMAs in the detector
        self.detector.update({self._slot_of[tenant]: per_req})

    def _finalise_windows(self, fl: _Inflight) -> None:
        """Clamp the compute window to device occupancy and stamp the
        tenant's EWMA/busy accounting.  Idempotent via ``fl.accounted``;
        callable as soon as the waiter has stamped ``compute_end`` — in
        particular from :meth:`_harvest_ready`, *before* the next straggler
        pick, which is what closes the one-batch EWMA lag."""
        # open the compute window at device occupancy, not dispatch return:
        # this slot was enqueued behind the previous slot's decode (the
        # device stream serialises them), and that queue wait must not be
        # billed to this tenant's busy/EWMA or double-counted in
        # utilisation.  The previous slot's compute_end is known here —
        # slots complete in dispatch order — so the clamp can only move
        # compute_start earlier than the next slot's transfer_start, never
        # past it (the overlap predicate stays falsifiable).
        fl.entry.compute_start = max(fl.entry.compute_start,
                                     min(self._last_ready,
                                         fl.entry.compute_end))
        self._last_ready = fl.entry.compute_end
        self._account_busy(fl.tenant, len(fl.reqs),
                           fl.entry.compute_end - fl.entry.compute_start)
        fl.accounted = True

    def _harvest_ready(self) -> None:
        """Stamp accounting for inflight slots whose decode has already
        landed (completions arrive in dispatch order, so stop at the first
        unstamped one).  Runs before every pick: a straggler-priority pick
        therefore sees the freshest latency the device can possibly have
        reported, instead of lagging one batch behind."""
        for fl in self._inflight:
            if not fl.stamped.is_set():
                break
            if not fl.accounted:
                self._finalise_windows(fl)

    # ------------------------------------------------------------------
    # Overlapped schedule: depth-N staging under the head slot's decode
    # ------------------------------------------------------------------
    def _launch_next(self) -> Optional[_Inflight]:
        """Assemble + stage + dispatch the next tenant slot (non-blocking).

        transfer window = batch assembly through dispatch return (host
        staging of prompts + prefill/decode enqueue); compute window opens
        at dispatch return and is closed by the CompletionWaiter when the
        decode output is device-ready.
        """
        self._harvest_ready()
        tenant = self._next_tenant()
        if tenant is None:
            return None
        asm_start = time.perf_counter() - self._t0
        # _next_tenant only returns tenants with backlog, so the batch is
        # never empty (and the tenant's round-served mark stays consistent)
        tenant, reqs, prompts, steps = self._build_batch(tenant)
        handle = self.engine.dispatch(prompts, steps,
                                      extra_inputs=self._batch_extras(reqs),
                                      **self._sampling_kwargs(reqs))
        te = time.perf_counter() - self._t0
        slot = self._slot_of[tenant]
        entry = TenantTimeline(vdev=slot, pdev=0, slot=slot,
                               transfer_start=asm_start, transfer_end=te,
                               compute_start=te, compute_end=0.0)
        stamped = self._get_waiter().submit(handle.tokens, entry)
        return _Inflight(tenant, reqs, handle, entry, stamped)

    def _get_waiter(self) -> CompletionWaiter:
        if self._waiter is None:
            self._waiter = CompletionWaiter(
                lambda: time.perf_counter() - self._t0,
                name="serving-waiter")
        return self._waiter

    def _fill_inflight(self) -> None:
        """Top the dispatch queue up to 1 + stage_depth entries: the head
        (next to be awaited) plus stage_depth staged-ahead batches whose
        assembly + staging run under the head's on-device decode."""
        while len(self._inflight) < 1 + self.stage_depth:
            nxt = self._launch_next()
            if nxt is None:
                return
            self._inflight.append(nxt)

    def _step_overlapped(self) -> Optional[List[Response]]:
        # overlap point: everything staged beyond the head is assembled +
        # dispatched here, while the head's decode loop runs on the device
        self._fill_inflight()
        if not self._inflight:
            return None
        cur = self._inflight.popleft()
        result = self.engine.await_result(cur.handle)
        cur.stamped.wait()           # compute_end stamped at device-ready
        if not cur.accounted:        # else already stamped by a harvest
            self._finalise_windows(cur)
        self.stats[cur.tenant]["tokens"] += result.tokens.size
        self.timeline.append(cur.entry)
        record_timeline(self.tel, cur.entry, base=self._t0,
                        tenant=cur.tenant, nv=self.tenancy.n_vdev)
        done_abs = self._t0 + cur.entry.compute_end
        return [Response(cur.tenant, result.tokens[i],
                         done_abs - r.arrival_s, len(cur.reqs))
                for i, r in enumerate(cur.reqs)]

    # ------------------------------------------------------------------
    # Continuous schedule: admission + micro-rounds over the slot table
    # ------------------------------------------------------------------
    @staticmethod
    def _prio(req: Any) -> int:
        return int(getattr(req, "priority", 1))

    @staticmethod
    def _deadline(req: Any) -> float:
        d = getattr(req, "deadline_s", None)
        return float("inf") if d is None else float(d)

    def _reject(self, req: Request, shed: bool = False) -> None:
        """Terminal REJECTED outcome: an empty response surfaced through
        :meth:`step` (and counted per tenant), never a silent drop."""
        self.rejected.append(req)
        st = self.stats[req.tenant]
        st["rejected"] += 1
        self.tel.count("sched.rejected")
        if shed:
            st["shed"] += 1
            self.tel.count("sched.shed")
        if self.journal is not None:
            self.journal.append("REJECT", rid=self._rid(req),
                                shed=bool(shed))
        self._rids.pop(id(req), None)
        self._attempts.pop(id(req), None)
        self._backoff.pop(id(req), None)
        self._terminal.append(Response(
            req.tenant, np.zeros((0,), np.int32),
            time.perf_counter() - req.arrival_s, 0, outcome="rejected",
            priority=self._prio(req)))

    def _fail(self, req: Any, preemptions: int = 0) -> None:
        """Terminal FAILED outcome (a fault-injection retry limit was
        exceeded for this request)."""
        self.failed.append(req)
        self.stats[req.tenant]["failed"] += 1
        self.tel.count("sched.failed")
        if self.journal is not None:
            self.journal.append("FAIL", rid=self._rid(req),
                                preemptions=int(preemptions))
        self._rids.pop(id(req), None)
        self._attempts.pop(id(req), None)
        self._backoff.pop(id(req), None)
        self._terminal.append(Response(
            req.tenant, np.zeros((0,), np.int32),
            time.perf_counter() - req.arrival_s, 0, outcome="failed",
            priority=self._prio(req), preemptions=preemptions))

    def _pop_terminal(self, responses: Optional[List[Response]] = None
                      ) -> List[Response]:
        out = list(responses or [])
        if self._terminal:
            out.extend(self._terminal)
            self._terminal.clear()
        return out

    def _shed_backlog(self) -> None:
        """Load-shed above the SLO bound: while the queued backlog exceeds
        ``max_backlog``, the lowest-priority, furthest-deadline, newest
        queued request is dropped with an explicit REJECTED outcome."""
        if self.max_backlog is None:
            return
        backlog = sum(len(q) for q in self.queues.values())
        while backlog > self.max_backlog:
            victim = None
            for t, q in self.queues.items():
                for r in q:
                    key = (self._prio(r), self._deadline(r), r.arrival_s)
                    if victim is None or key > victim[0]:
                        victim = (key, t, r)
            _, tenant, req = victim
            self.queues[tenant].remove(req)
            self._reject(req, shed=True)
            backlog -= 1

    def _tenant_pages(self) -> Dict[str, int]:
        """Pages currently held per tenant (fair-share accounting input)."""
        held: Dict[str, int] = collections.defaultdict(int)
        eng = self._ceng
        for c, s in enumerate(eng._slots):
            if s is not None:
                held[s.req.tenant] += len(eng.kv.owned_pages(c))
        return held

    def _over_share(self, held: Dict[str, int]) -> Dict[str, bool]:
        """Per-tenant fair-share check: over-share means holding strictly
        more pages than usable_pages / active_tenants."""
        eng = self._ceng
        active = {t for t, q in self.queues.items() if q} | set(held)
        if not active:
            return {}
        share = (eng.kv.num_pages - eng.kv.RESERVED) / len(active)
        return {t: held.get(t, 0) > share for t in active}

    def _pick_continuous(self, budget: int) -> List[Request]:
        """Pick up to ``budget`` queue heads for this admission batch.

        Legacy path — bit-for-bit the pre-overload behaviour — when every
        head shares one priority tier, nobody is in admission backoff and
        no fair-share conflict exists: plain rotation / straggler order.
        Otherwise candidates are ordered by (priority tier, page
        over-share, deadline, row-steps consumed, tenant order): the
        priority-aware fair-share admission of the overload layer."""
        # deadline-miss shedding: a queued request already past its absolute
        # deadline can never meet it — admitting it would only burn pool
        # pages and decode steps under overload.  Shed it terminally
        # (REJECTED, counted as shed) before picking.
        now = time.perf_counter()
        for q in self.queues.values():
            for req in [r for r in q if self._deadline(r) < now]:
                q.remove(req)
                self._reject(req, shed=True)
        picked: List[Request] = []
        while len(picked) < budget:
            heads = [(t, q[0]) for t, q in self.queues.items()
                     if q and self._adm_clock >= self._backoff.get(
                         id(q[0]), 0)]
            if not heads:
                break
            backoff_free = not any(id(q[0]) in self._backoff
                                   for q in self.queues.values() if q)
            over = self._over_share(self._tenant_pages())
            flags = [over.get(t, False) for t, _ in heads]
            conflict = any(flags) and not all(flags)
            if (backoff_free and not conflict
                    and len({self._prio(r) for _, r in heads}) == 1):
                tenant = self._next_tenant()
                if tenant is None:
                    break
                picked.append(self.queues[tenant].popleft())
                continue
            tenant, _ = min(heads, key=lambda tr: (
                self._prio(tr[1]), over.get(tr[0], False),
                self._deadline(tr[1]), self._tenant_steps[tr[0]],
                self._order.index(tr[0])))
            picked.append(self.queues[tenant].popleft())
        return picked

    def _victim_slot(self, prio: int) -> Optional[int]:
        """Preemption victim: the live row with the *largest* priority
        number strictly above ``prio`` (never a same-or-higher tier), ties
        broken toward the most decode budget left (evicting it frees
        capacity longest).  None when nobody is preemptable."""
        eng = self._ceng
        best = None
        for c, s in enumerate(eng._slots):
            if s is None or s.priority <= prio:
                continue
            key = (-s.priority, -(s.target - len(s.tokens)), c)
            if best is None or key < best[0]:
                best = (key, c)
        return None if best is None else best[1]

    def _preempt_slot(self, victim: int) -> int:
        """Swap one victim row out to the host tier and queue its restore
        ticket (journalled: the PREEMPT record names the ticket so the
        checkpointed swap record can be matched back to its rid)."""
        eng = self._ceng
        req = eng._slots[victim].req
        ticket = eng.preempt(victim)
        self._journal("PREEMPT", rid=self._rid(req), ticket=int(ticket))
        self._restore_q.append(ticket)
        return ticket

    def _preempt_for(self, reqs: List[Request]
                     ) -> Tuple[int, List[Request]]:
        """Admit failed picks by swapping strictly-lower-priority victims
        out to the host tier (the engine is quiesced by the caller).
        Returns (newly admitted, still-failed)."""
        eng = self._ceng
        admitted, remaining = 0, []
        for req in sorted(reqs, key=self._prio):
            ok = False
            while not ok:
                victim = self._victim_slot(self._prio(req))
                if victim is None:
                    break
                self.stats[eng._slots[victim].req.tenant]["preempted"] += 1
                # the victim's accumulated busy share must not leak onto
                # whatever request next occupies this slot
                self._row_busy.pop(victim, None)
                self._preempt_slot(victim)
                try:
                    ok = eng.try_admit_batch([req])[0]
                except InjectedFault:
                    self.faults_survived += 1
                    break
            if ok:
                admitted += 1
                self._attempts.pop(id(req), None)
                self._backoff.pop(id(req), None)
                self._journal_admits([req])
            else:
                remaining.append(req)
        return admitted, remaining

    def _drain_restores(self, allow_preempt: bool) -> int:
        """Re-admit swapped-out requests, highest tier first.  Restores
        beat fresh picks of their own or lower tiers, but a lower-tier
        restore never consumes a free slot a strictly-higher-priority
        queued arrival is waiting for — otherwise every such arrival pays
        a full preempt/swap cycle to reclaim the slot the restore just
        re-took.  A restore blocked on pool pressure with an
        otherwise-idle engine, or a poisoned swap read past the retry
        budget, fails terminally — the drain can never hang on an
        unrestorable ticket.  The queue head is prefetched (async
        host->device staging) ahead of its re-admission."""
        eng = self._ceng
        if not self._restore_q or eng.swap_store is None:
            return 0
        pending = sorted(self._restore_q,
                         key=lambda t: eng.swap_store.record(t).priority)
        self._restore_q = []
        done = 0
        for ticket in pending:
            if self._adm_clock < self._ticket_backoff.get(ticket, 0):
                self._restore_q.append(ticket)
                continue
            rec = eng.swap_store.record(ticket)
            hi_wait = sum(1 for q in self.queues.values() for r in q
                          if self._prio(r) < rec.priority)
            if hi_wait and eng.free_slot_count() <= hi_wait:
                self._restore_q.append(ticket)
                continue
            try:
                ok = eng.try_restore(ticket)
                if not ok and allow_preempt and self.preemption:
                    victim = self._victim_slot(rec.priority)
                    if victim is not None:
                        self.stats[eng._slots[victim].req.tenant][
                            "preempted"] += 1
                        self._row_busy.pop(victim, None)
                        self._preempt_slot(victim)
                        ok = eng.try_restore(ticket)
            except InjectedFault:
                self.faults_survived += 1
                n = self._ticket_attempts.get(ticket, 0) + 1
                if n > self.round_fault_limit:
                    rec = eng.drop_swapped(ticket)
                    self._ticket_attempts.pop(ticket, None)
                    self._ticket_backoff.pop(ticket, None)
                    self._fail(rec.req, preemptions=rec.preemptions)
                    continue
                self._ticket_attempts[ticket] = n
                self._ticket_backoff[ticket] = self._adm_clock + min(
                    1 << (n - 1), 16)
                self._restore_q.append(ticket)
                continue
            if ok:
                done += 1
                self._ticket_attempts.pop(ticket, None)
                self._ticket_backoff.pop(ticket, None)
                self._journal("RESTORE", rid=self._rid(rec.req),
                              ticket=int(ticket))
            else:
                if (eng.active_count() == 0
                        and self._cont_inflight is None):
                    # nothing live can ever free more pages: bound the spin
                    n = self._ticket_attempts.get(ticket, 0) + 1
                    self._ticket_attempts[ticket] = n
                    if n > self.admission_retry_limit:
                        rec = eng.drop_swapped(ticket)
                        # drop BOTH ticket maps with the record: leaving
                        # them keyed on a dead ticket leaked bookkeeping
                        # (and pages stayed attributed at the drain audit)
                        self._ticket_attempts.pop(ticket, None)
                        self._ticket_backoff.pop(ticket, None)
                        self._fail(rec.req, preemptions=rec.preemptions)
                        continue
                self._restore_q.append(ticket)
        # prefetch a bounded window (not just the head): the second and
        # later restores overlap their host->device staging with the
        # in-flight round instead of eating the full transfer latency at
        # re-admission time
        for ticket in self._restore_q[:self.restore_prefetch]:
            eng.swap_store.prefetch(ticket)
        return done

    def _admit_continuous(self, allow_preempt: bool = False) -> int:
        """Admit queued requests into free slots: restores of preempted
        work first, then one queue head per pick (legacy rotation or the
        priority/fair-share order — see :meth:`_pick_continuous`), the
        whole pick list admitted as one batch — same-bucket picks share a
        single batched prefill call and prefix-share pages.  Rejected
        picks are requeued at the front of their tenant's queue; when
        nothing is in flight and nothing was admitted (so no retirement
        can ever free pages), failed picks count against the bounded
        retry budget and reject terminally past it."""
        if not self.tel.enabled:
            return self._admit_continuous_inner(allow_preempt)
        with self.tel.span("sched.admit",
                           backlog=sum(len(q) for q in
                                       self.queues.values())) as sp:
            n = self._admit_continuous_inner(allow_preempt)
            sp.note(admitted=n)
            return n

    def _admit_continuous_inner(self, allow_preempt: bool) -> int:
        eng = self._ceng
        self._adm_clock += 1
        self._shed_backlog()
        admitted = self._drain_restores(allow_preempt)
        picked = self._pick_continuous(eng.free_slot_count())
        failures: List[Request] = []
        if picked:
            t0 = time.perf_counter() - self._t0
            try:
                flags = eng.try_admit_batch(picked)
            except InjectedFault:
                self.faults_survived += 1
                flags = [False] * len(picked)
            t1 = time.perf_counter() - self._t0
            self._journal_admits([r for r, ok in zip(picked, flags) if ok])
            for req, ok in zip(picked, flags):
                if ok:
                    admitted += 1
                    self._attempts.pop(id(req), None)
                    self._backoff.pop(id(req), None)
                    slot = self._slot_of[req.tenant]
                    entry = TenantTimeline(
                        vdev=slot, pdev=eng.pdev, slot=slot,
                        transfer_start=t0, transfer_end=t1,
                        compute_start=t1, compute_end=t1)
                    self.admission_timeline.append(entry)
                    record_timeline(self.tel, entry, base=self._t0,
                                    prefix="admission",
                                    tenant=req.tenant,
                                    nv=self.tenancy.n_vdev)
                else:
                    failures.append(req)
            if (failures and allow_preempt and self.preemption
                    and eng.can_preempt):
                extra, failures = self._preempt_for(failures)
                admitted += extra
            # ordinary pool pressure (anything live or just admitted) will
            # free pages: plain requeue, exactly the pre-overload path.
            # A hopeless failure — nothing in flight, nothing admitted,
            # nothing restorable — is the old unrecoverable-raise
            # condition: count it against the bounded retry budget instead
            hopeless = (admitted == 0 and eng.active_count() == 0
                        and self._cont_inflight is None
                        and not self._restore_q)
            still: List[Request] = []
            for req in failures:
                if not hopeless:
                    still.append(req)
                    continue
                n = self._attempts.get(id(req), 0) + 1
                if n > self.admission_retry_limit:
                    self._reject(req)
                    continue
                self._attempts[id(req)] = n
                self._backoff[id(req)] = self._adm_clock + min(
                    1 << (n - 1), 16)
                still.append(req)
            for req in reversed(still):
                self.queues[req.tenant].appendleft(req)
                # the pick didn't result in service: un-mark the tenant so
                # a straggler whose admission failed keeps its priority for
                # the rest of the round instead of being demoted
                self._round_served.discard(req.tenant)
        elif (allow_preempt and self.preemption and eng.can_preempt
                and eng.free_slot_count() == 0):
            # slot exhaustion (nothing pickable): a waiting request of a
            # strictly higher tier than some live row still preempts —
            # swapping the victim frees its slot and its private pages
            heads = [q[0] for q in self.queues.values() if q]
            if heads:
                best = min(heads, key=lambda r: (
                    self._prio(r), self._deadline(r), r.arrival_s))
                if self._victim_slot(self._prio(best)) is not None:
                    self.queues[best.tenant].popleft()
                    extra, remaining = self._preempt_for([best])
                    admitted += extra
                    for req in remaining:
                        self.queues[req.tenant].appendleft(req)
        starved = (eng.free_slot_count() == 0
                   and any(self.queues.values()))
        self._admission_blocked = bool(failures or self._restore_q
                                       or starved)
        return admitted

    def _dispatch_round(self, asm_start: float) -> _InflightRound:
        handle = self._ceng.dispatch_round()
        te = time.perf_counter() - self._t0
        idx = self._cont_rounds
        self._cont_rounds += 1
        entry = TenantTimeline(vdev=idx, pdev=self._ceng.pdev, slot=idx,
                               transfer_start=asm_start, transfer_end=te,
                               compute_start=te, compute_end=0.0)
        stamped = self._get_waiter().submit(handle.emitted, entry)
        return _InflightRound(handle, entry, stamped)

    def _try_dispatch_round(self, asm0: float) -> Optional[_InflightRound]:
        """Dispatch with the round-fault retry/limit policy: a dropped round
        raises before any state mutation, so the slot table is untouched and
        the round is simply re-dispatched next step; a streak past
        ``round_fault_limit`` fails every live row terminally so the drain
        always finishes."""
        try:
            fl = self._dispatch_round(asm0)
        except InjectedFault:
            self.faults_survived += 1
            self._round_fault_streak += 1
            if self._round_fault_streak > self.round_fault_limit:
                for req in self._ceng.fail_live():
                    self._fail(req)
                self._round_fault_streak = 0
            return None
        self._round_fault_streak = 0
        return fl

    def _preemption_pressure(self) -> bool:
        """True when the in-flight round should be force-collected so a
        preemption can run under a quiesced engine: admission is blocked, a
        strictly higher-priority request is waiting (queued or swapped), and
        a lower-priority victim is live."""
        eng = self._ceng
        if not (self.preemption and eng.can_preempt
                and self._admission_blocked):
            return False
        prios = [self._prio(q[0]) for q in self.queues.values() if q]
        if eng.swap_store is not None:
            prios += [eng.swap_store.record(t).priority
                      for t in self._restore_q]
        if not prios:
            return False
        p = min(prios)
        return any(lp > p for lp in eng.live_priorities())

    def _step_continuous(self) -> Optional[List[Response]]:
        eng = self._ceng
        if self.heartbeat.suspect():
            self.heartbeat_suspects += 1
            if self.tel.enabled:
                self.tel.count("heartbeat.missed")
                self.tel.gauge("heartbeat.suspects",
                               self.heartbeat_suspects)
        if self._cont_inflight is None:
            # engine quiesced (no round in flight): the only sound window
            # for an engine checkpoint — snapshot_live() must not race a
            # decode round's donated state
            if self._checkpoint_due():
                self.save_checkpoint()
            asm0 = time.perf_counter() - self._t0
            admitted = self._admit_continuous(
                allow_preempt=self.preemption)
            if admitted == 0 and eng.active_count() == 0:
                # nothing in flight and nothing admitted: queued heads are
                # in bounded retry/backoff (terminally REJECTED past the
                # budget — never the PR-5 unrecoverable raise), so drain()
                # always makes progress; surface any terminal outcomes
                return self._pop_terminal() or None
            self._cont_inflight = self._try_dispatch_round(asm0)
            if self._cont_inflight is None:      # injected round drop
                return self._pop_terminal() or None
        cur = self._cont_inflight
        # retire-before-dispatch fast path: when round k's emissions have
        # already landed there is nothing to pipeline under — harvest and
        # retire its finished rows NOW, so their slots and pages are offered
        # to this step's admissions and round k+1 never carries them as
        # masked lanes (the PR-3 one-round retirement lag)
        res = eng.collect(cur.handle) if cur.handle.ready() else None
        if res is None and self._preemption_pressure():
            # preemption must run against a quiesced engine: force-collect
            # round k now, trading one round of pipelining for the
            # high-priority admission
            res = eng.collect(cur.handle)
        # overlap point: the next round's admissions (host assembly, prefill
        # + KV-scatter enqueue) and its dispatch land here, while round k
        # still occupies the device — rows that finish in round k ride as
        # masked lanes in round k+1 only when round k is still in flight
        asm0 = time.perf_counter() - self._t0
        admitted = self._admit_continuous(
            allow_preempt=self.preemption and res is not None)
        # pipeline round k+1 only if it will have live rows: fresh
        # admissions, or a current row whose budget outlasts round k (when
        # round k was already collected above, live_after(0) is exactly
        # "anything still unfinished"; otherwise its emissions are still in
        # flight and live_after(inner_steps) is "survives round k") — else
        # the drain would end on a dispatched-but-never-collected all-masked
        # round, wasting a device round and skewing the occupancy counters
        # a due checkpoint suppresses the pipelined dispatch: the next step
        # then starts with a quiesced engine and snapshots before round
        # k+1 — one pipeline bubble per checkpoint interval.  pending=1
        # counts round k, whose ROUND_COMMIT lands below at
        # _journal_round(res) — this step always commits it
        live = eng.live_after(0 if res is not None else eng.inner_steps)
        self._cont_inflight = (self._try_dispatch_round(asm0)
                               if (admitted or live)
                               and not self._checkpoint_due(pending=1)
                               else None)
        if res is None:
            res = eng.collect(cur.handle)
        self._journal_round(res)
        self.heartbeat.beat()                    # round k landed
        self.tel.count("heartbeat.beats")
        cur.stamped.wait()
        cur.entry.compute_start = max(cur.entry.compute_start,
                                      min(self._last_ready,
                                          cur.entry.compute_end))
        self._last_ready = cur.entry.compute_end
        self.timeline.append(cur.entry)
        record_timeline(self.tel, cur.entry, base=self._t0,
                        nv=self.tenancy.n_vdev)
        # busy attribution: the round's device window split across tenants
        # by live row-steps (masked lanes bill nobody); the same row-steps
        # feed the fair-share admission order
        busy = cur.entry.compute_end - cur.entry.compute_start
        total_steps = int(res.active_steps.sum())
        if total_steps > 0:
            for c, req in enumerate(res.slot_reqs):
                if req is None or res.active_steps[c] == 0:
                    continue
                share = busy * float(res.active_steps[c]) / total_steps
                self.stats[req.tenant]["busy_s"] += share
                self._row_busy[c] += share
                self._tenant_steps[req.tenant] += int(res.active_steps[c])
        done_abs = self._t0 + cur.entry.compute_end
        responses: List[Response] = []
        for (req, tokens, c), srec in zip(res.finished, res.retired):
            st = self.stats[req.tenant]
            st["requests"] += 1
            st["tokens"] += tokens.size
            row_busy = self._row_busy.pop(c, 0.0)
            self._note_batch_time(req.tenant, row_busy)
            self.detector.update({self._slot_of[req.tenant]: row_busy})
            ttft = (None if srec.t_first is None
                    else srec.t_first - req.arrival_s)
            if self.journal is not None:
                self.journal.append("RETIRE", rid=self._rid(req),
                                    tokens=[int(t) for t in tokens])
            self._rids.pop(id(req), None)
            responses.append(Response(
                req.tenant, tokens, done_abs - req.arrival_s, 1,
                ttft_s=ttft, priority=self._prio(req),
                preemptions=srec.preemptions))
        return self._pop_terminal(responses)

    # ------------------------------------------------------------------
    # Blocking schedule (A/B baseline): generate() per slot
    # ------------------------------------------------------------------
    def _stage_next(self) -> None:
        if self._prepared is None:
            tenant = self._next_tenant()
            if tenant is not None:
                asm_start = time.perf_counter() - self._t0
                self._prepared = self._build_batch(tenant)
                if self._prepared is not None:
                    self._asm_window = (asm_start,
                                        time.perf_counter() - self._t0)

    def _step_blocking(self) -> Optional[List[Response]]:
        self._stage_next()
        if self._prepared is None:
            return None
        tenant, reqs, prompts, steps = self._prepared
        self._prepared = None
        asm_start, asm_end = self._asm_window
        t0 = time.perf_counter()
        result: GenerationResult = self.engine.generate(
            prompts, steps, extra_inputs=self._batch_extras(reqs))
        done = time.perf_counter()       # service completion: BEFORE the
        busy = done - t0                 # stage-ahead work below, so the
        # compute window and latencies don't absorb the next slot's assembly
        # (stats recorded first so the stage-ahead pick sees this batch's
        # fresh latency, not stale data)
        self._account_busy(tenant, len(reqs), busy)
        self.stats[tenant]["tokens"] += result.tokens.size
        # stage-ahead: assemble the next slot's batch before finalising this
        # slot's responses (host-side analogue of stage(k+1) under compute(k))
        self._stage_next()
        entry = TenantTimeline(
            vdev=self._slot_of[tenant], pdev=0, slot=self._slot_of[tenant],
            transfer_start=asm_start, transfer_end=asm_end,
            compute_start=t0 - self._t0, compute_end=done - self._t0)
        self.timeline.append(entry)
        record_timeline(self.tel, entry, base=self._t0, tenant=tenant,
                        nv=self.tenancy.n_vdev)
        return [Response(tenant, result.tokens[i], done - r.arrival_s,
                         len(reqs)) for i, r in enumerate(reqs)]

    # ------------------------------------------------------------------
    def step(self) -> Optional[List[Response]]:
        """Serve one scheduling step; returns responses (None if idle).
        Overlapped/blocking: one tenant slot.  Continuous: one decode
        micro-round (responses are the rows that retired in it)."""
        if not self.tel.enabled:
            return self._step_inner()
        with self.tel.span("sched.step", mode=self.mode) as sp:
            r = self._step_inner()
            sp.note(responses=0 if r is None else len(r))
            return r

    def _step_inner(self) -> Optional[List[Response]]:
        if self.mode == "continuous":
            return self._step_continuous()
        if self.mode == "overlapped":
            return self._step_overlapped()
        return self._step_blocking()

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.pending():
            r = self.step()
            if r:
                out.extend(r)
        # two-tier audit: every request is terminal, so the host swap tier
        # must be empty, its ledgers must agree with the pool's, and no
        # ticket bookkeeping may survive its record (the REJECTED/FAILED-
        # after-swap-out leak class)
        if self._ceng is not None and self._ceng.swap_store is not None:
            eng = self._ceng
            eng.kv.assert_conserved(
                host_pages=eng.swap_store.pages_by_kind())
            leaked = (set(self._ticket_attempts)
                      | set(self._ticket_backoff))
            assert not leaked, \
                f"drain: ticket bookkeeping leaked for {sorted(leaked)}"
        # reap the now-idle completion-waiter thread so schedulers that end
        # with drain() (the common shape) don't each park a daemon thread
        # rooting the scheduler; it is recreated lazily on the next launch
        self.close()
        return out

    # ------------------------------------------------------------------
    @property
    def continuous_engine(self):
        """The scheduler's ContinuousBatchingEngine (None outside
        mode='continuous') — the public handle for occupancy/page stats."""
        return self._ceng

    # ------------------------------------------------------------------
    def utilization_report(self) -> Dict[str, Dict[str, float]]:
        total_busy = sum(s["busy_s"] for s in self.stats.values())
        return {t: dict(s, busy_share=(s["busy_s"] / total_busy
                                       if total_busy else 0.0))
                for t, s in self.stats.items()}
