"""Multi-tenant serving scheduler (the paper's second multi-tenancy reading:
several applications share one physical accelerator).

Each tenant owns a request queue; the scheduler serves them on one shared
engine under one of three schedules (``mode=``):

* ``"continuous"`` — continuous batching over a persistent slot table
  (:class:`repro.serving.continuous.ContinuousBatchingEngine`): each outer
  step admits queued requests into free slots (picked round-robin or
  straggler-priority across tenants, then admitted as *one batch* — all
  same-bucket picks share one batched prefill call, and prefix sharing maps
  common prompt prefixes onto existing pages), dispatches one masked
  fixed-step decode micro-round over *all* slots, and retires rows that hit
  their token budget, dropping their :class:`repro.serving.kvcache.
  PagedKVCache` page references.  The device never drains between tenant
  batches and short requests never pad out long ones — the finest-grained
  sharing of the three, and the paper's utilisation argument taken to
  per-request granularity.  Admission + the next round's dispatch run while
  the previous round still occupies the device, so the same falsifiable
  :func:`repro.core.pipeline.timeline_overlaps` predicate applies
  round-to-round.  When the in-flight round has already landed by the time
  a step runs, it is collected *first* (retire-before-dispatch fast path):
  finished rows are evicted and their slots/pages offered to this step's
  admissions before round k+1 dispatches, instead of riding one extra round
  as masked lanes.  Per-request admission windows are stamped into
  ``admission_timeline`` (batch-admitted requests share one transfer
  window).
* ``"overlapped"`` (default) — tenant-slot batching on the engine's split
  ``dispatch``/``await_result`` halves: while tenant k's scanned decode
  occupies the device, the host assembles, stages and dispatches up to
  ``stage_depth`` further tenant batches (a depth-N generalisation of PR 2's
  double buffering).
* ``"blocking"`` — the legacy host-blocking ``engine.generate`` per slot
  (stage-ahead limited to host-side batch assembly), kept as the A/B
  baseline.

Slot selection is straggler-aware: with ``straggler_priority=True`` the
scheduler serves the tenant with the slowest recent per-request time first,
subject to the round invariant that every backlogged tenant is served
exactly once per round.  The EWMA is stamped *as soon as a completion has
landed* — before the next pick — via :meth:`_harvest_ready`, closing PR 2's
one-batch lag (the pick for slot k+1 used to run before slot k's completion
could stamp its latency even when the device was already done).

Per-slot :class:`repro.core.pipeline.TenantTimeline` records (transfer
window = batch assembly / admission + staging dispatch, compute window =
dispatch -> device-ready) feed the benchmark harness; a shared
:class:`repro.core.pipeline.CompletionWaiter` stamps ``compute_end`` the
moment the decode output is ready, so :func:`repro.core.pipeline.
timeline_overlaps` is falsifiable on the serving timeline exactly as on the
risk pipeline's.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import CompletionWaiter, TenantTimeline
from repro.core.tenancy import TenancyConfig
from repro.distributed.fault import StragglerDetector
from repro.serving.engine import (GenerationResult, PendingGeneration,
                                  ServingEngine)

MODES = ("continuous", "overlapped", "blocking")


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    # per-request sampling: None temperature inherits the engine default;
    # top_k=0 disables truncation.  Honoured by the overlapped schedule
    # (threaded through the scanned decode-loop carry) and the continuous
    # schedule (slot-table carry); the blocking baseline stays engine-level.
    temperature: Optional[float] = None
    top_k: int = 0
    seed: int = 0
    arrival_s: float = dataclasses.field(default_factory=time.perf_counter)


@dataclasses.dataclass
class Response:
    tenant: str
    tokens: np.ndarray
    latency_s: float
    batch_size: int


@dataclasses.dataclass
class _Inflight:
    """One dispatched tenant slot: requests + handle + its timeline entry
    (compute_end stamped by the CompletionWaiter at device readiness)."""
    tenant: str
    reqs: List[Request]
    handle: PendingGeneration
    entry: TenantTimeline
    stamped: Any                     # threading.Event from the waiter
    accounted: bool = False          # EWMA/busy already stamped (harvest)


@dataclasses.dataclass
class _InflightRound:
    """One dispatched continuous-batching micro-round."""
    handle: Any                      # continuous.RoundHandle
    entry: TenantTimeline
    stamped: Any


class MultiTenantScheduler:
    """Tenant batching over one shared engine (round-robin or
    straggler-priority) under a continuous, overlapped or blocking schedule
    (see module docstring)."""

    def __init__(self, engine: ServingEngine, max_batch: int = 8,
                 tenancy: Optional[TenancyConfig] = None,
                 straggler_priority: bool = False,
                 overlapped: bool = True,
                 mode: Optional[str] = None,
                 stage_depth: int = 1,
                 continuous: Optional[Dict[str, Any]] = None,
                 continuous_engine: Optional[Any] = None):
        self.engine = engine
        self.max_batch = max_batch
        self.tenancy = tenancy or TenancyConfig(1, 2)
        self.straggler_priority = straggler_priority
        self.mode = mode or ("overlapped" if overlapped else "blocking")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}")
        self.overlapped = self.mode == "overlapped"
        self.stage_depth = max(int(stage_depth), 1)
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self.detector = StragglerDetector()
        self.stats: Dict[str, Dict[str, float]] = collections.defaultdict(
            lambda: {"requests": 0, "tokens": 0, "busy_s": 0.0})
        self.timeline: List[TenantTimeline] = []
        self._order: List[str] = []
        self._slot_of: Dict[str, int] = {}
        # blocking path: next tenant slot's pre-assembled batch (tenant,
        # reqs, prompts, steps) — assembled while the previous slot's
        # responses were being finalised (host-side stage-ahead)
        self._prepared: Optional[Tuple[str, List[Request], np.ndarray, int]] \
            = None
        self._asm_window = (0.0, 0.0)
        # overlapped path: dispatched-but-not-awaited tenant slots, oldest
        # first; holds at most 1 + stage_depth entries (the one being
        # awaited plus the staged-ahead queue)
        self._inflight: Deque[_Inflight] = collections.deque()
        self._waiter: Optional[CompletionWaiter] = None
        self._last_ready = 0.0           # previous slot's compute_end
        self._round_served: set = set()
        self._recent: Dict[str, float] = {}   # EWMA per-request seconds
        self._t0 = time.perf_counter()
        # continuous path: pass continuous_engine to share one (compiled)
        # ContinuousBatchingEngine across scheduler instances — jit caches
        # are per-engine, and a drained engine's slot table is fully reusable
        self._ceng = None
        if self.mode == "continuous":
            if continuous_engine is not None:
                self._ceng = continuous_engine
            else:
                from repro.serving.continuous import ContinuousBatchingEngine
                self._ceng = ContinuousBatchingEngine(engine,
                                                      **(continuous or {}))
        self._cont_inflight: Optional[_InflightRound] = None
        self._cont_rounds = 0
        self._row_busy: Dict[int, float] = collections.defaultdict(float)
        # continuous path: one entry per admitted request (vdev/slot = the
        # tenant slot, transfer window = its admission batch's host window:
        # pick + batched prefill + page mapping + state scatter).  Kept
        # separate from `timeline` so the round-level overlap predicate
        # isn't polluted by degenerate compute windows.
        self.admission_timeline: List[TenantTimeline] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.tenant not in self._order:
            self._slot_of[req.tenant] = len(self._order)
            self._order.append(req.tenant)
        self.queues[req.tenant].append(req)

    def pending(self) -> int:
        n = sum(len(q) for q in self.queues.values())
        if self._prepared is not None:   # staged-ahead batch not yet served
            n += len(self._prepared[1])
        n += sum(len(fl.reqs) for fl in self._inflight)   # dispatched slots
        if self._ceng is not None:       # admitted, not yet retired rows
            n += self._ceng.active_count()
        return n

    def close(self) -> None:
        """Reap the completion-waiter thread (daemon, so optional)."""
        if self._waiter is not None:
            self._waiter.close()
            self._waiter = None

    # ------------------------------------------------------------------
    # EWMA weight for per-tenant recent latency (straggler-priority pick)
    _RECENT_ALPHA = 0.5

    def _recent_s(self, tenant: str) -> float:
        return self._recent.get(tenant, 0.0)

    def _note_batch_time(self, tenant: str, per_req_s: float) -> None:
        """EWMA of per-request time: tracks *recent* speed, so a tenant that
        was slow long ago but recovered stops being prioritised (a lifetime
        mean would pin the priority to stale history)."""
        prev = self._recent.get(tenant)
        a = self._RECENT_ALPHA
        self._recent[tenant] = (per_req_s if prev is None
                                else a * per_req_s + (1 - a) * prev)

    def _next_tenant(self) -> Optional[str]:
        if self.straggler_priority:
            backlog = [t for t in self._order if self.queues[t]]
            if not backlog:
                return None
            # slowest recent tenant first *within a round*: every tenant
            # with backlog is served once before any tenant repeats, so the
            # priority orders a finite round (the serving analogue of
            # reorder_for_stragglers) instead of starving fast tenants
            fresh = [t for t in backlog if t not in self._round_served]
            if not fresh:
                self._round_served.clear()
                fresh = backlog
            pick = max(fresh, key=self._recent_s)
            self._round_served.add(pick)
            return pick
        for _ in range(len(self._order)):
            t = self._order.pop(0)
            self._order.append(t)
            if self.queues[t]:
                return t
        return None

    def _assemble(self, tenant: str) -> List[Request]:
        q = self.queues[tenant]
        batch = []
        while q and len(batch) < self.max_batch:
            batch.append(q.popleft())
        return batch

    def _build_batch(self, tenant: str
                     ) -> Optional[Tuple[str, List[Request], np.ndarray, int]]:
        reqs = self._assemble(tenant)
        if not reqs:
            return None
        # pad prompts to a common length (right-aligned batch)
        s_max = max(r.prompt.size for r in reqs)
        prompts = np.zeros((len(reqs), s_max), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, s_max - r.prompt.size:] = r.prompt
        return tenant, reqs, prompts, max(r.max_new_tokens for r in reqs)

    def _sampling_kwargs(self, reqs: List[Request]) -> Dict[str, Any]:
        """Per-request sampling arrays for dispatch(); empty when every row
        uses engine defaults so the scalar (token-exact) path keeps running."""
        if not any(r.temperature is not None or r.top_k or r.seed
                   for r in reqs):
            return {}
        return {
            "temperatures": [self.engine.temperature if r.temperature is None
                             else r.temperature for r in reqs],
            "top_ks": [r.top_k for r in reqs],
            "seeds": [r.seed for r in reqs],
        }

    # ------------------------------------------------------------------
    # Accounting shared by the schedules
    # ------------------------------------------------------------------
    def _account_busy(self, tenant: str, n_reqs: int, busy_s: float) -> None:
        st = self.stats[tenant]
        st["requests"] += n_reqs
        st["busy_s"] += busy_s
        per_req = busy_s / max(n_reqs, 1)
        self._note_batch_time(tenant, per_req)
        # keyed by the stable tenant slot: hash(tenant) is salted per
        # process and can collide across tenants, which would merge two
        # tenants' EWMAs in the detector
        self.detector.update({self._slot_of[tenant]: per_req})

    def _finalise_windows(self, fl: _Inflight) -> None:
        """Clamp the compute window to device occupancy and stamp the
        tenant's EWMA/busy accounting.  Idempotent via ``fl.accounted``;
        callable as soon as the waiter has stamped ``compute_end`` — in
        particular from :meth:`_harvest_ready`, *before* the next straggler
        pick, which is what closes the one-batch EWMA lag."""
        # open the compute window at device occupancy, not dispatch return:
        # this slot was enqueued behind the previous slot's decode (the
        # device stream serialises them), and that queue wait must not be
        # billed to this tenant's busy/EWMA or double-counted in
        # utilisation.  The previous slot's compute_end is known here —
        # slots complete in dispatch order — so the clamp can only move
        # compute_start earlier than the next slot's transfer_start, never
        # past it (the overlap predicate stays falsifiable).
        fl.entry.compute_start = max(fl.entry.compute_start,
                                     min(self._last_ready,
                                         fl.entry.compute_end))
        self._last_ready = fl.entry.compute_end
        self._account_busy(fl.tenant, len(fl.reqs),
                           fl.entry.compute_end - fl.entry.compute_start)
        fl.accounted = True

    def _harvest_ready(self) -> None:
        """Stamp accounting for inflight slots whose decode has already
        landed (completions arrive in dispatch order, so stop at the first
        unstamped one).  Runs before every pick: a straggler-priority pick
        therefore sees the freshest latency the device can possibly have
        reported, instead of lagging one batch behind."""
        for fl in self._inflight:
            if not fl.stamped.is_set():
                break
            if not fl.accounted:
                self._finalise_windows(fl)

    # ------------------------------------------------------------------
    # Overlapped schedule: depth-N staging under the head slot's decode
    # ------------------------------------------------------------------
    def _launch_next(self) -> Optional[_Inflight]:
        """Assemble + stage + dispatch the next tenant slot (non-blocking).

        transfer window = batch assembly through dispatch return (host
        staging of prompts + prefill/decode enqueue); compute window opens
        at dispatch return and is closed by the CompletionWaiter when the
        decode output is device-ready.
        """
        self._harvest_ready()
        tenant = self._next_tenant()
        if tenant is None:
            return None
        asm_start = time.perf_counter() - self._t0
        # _next_tenant only returns tenants with backlog, so the batch is
        # never empty (and the tenant's round-served mark stays consistent)
        tenant, reqs, prompts, steps = self._build_batch(tenant)
        handle = self.engine.dispatch(prompts, steps,
                                      **self._sampling_kwargs(reqs))
        te = time.perf_counter() - self._t0
        slot = self._slot_of[tenant]
        entry = TenantTimeline(vdev=slot, pdev=0, slot=slot,
                               transfer_start=asm_start, transfer_end=te,
                               compute_start=te, compute_end=0.0)
        stamped = self._get_waiter().submit(handle.tokens, entry)
        return _Inflight(tenant, reqs, handle, entry, stamped)

    def _get_waiter(self) -> CompletionWaiter:
        if self._waiter is None:
            self._waiter = CompletionWaiter(
                lambda: time.perf_counter() - self._t0,
                name="serving-waiter")
        return self._waiter

    def _fill_inflight(self) -> None:
        """Top the dispatch queue up to 1 + stage_depth entries: the head
        (next to be awaited) plus stage_depth staged-ahead batches whose
        assembly + staging run under the head's on-device decode."""
        while len(self._inflight) < 1 + self.stage_depth:
            nxt = self._launch_next()
            if nxt is None:
                return
            self._inflight.append(nxt)

    def _step_overlapped(self) -> Optional[List[Response]]:
        # overlap point: everything staged beyond the head is assembled +
        # dispatched here, while the head's decode loop runs on the device
        self._fill_inflight()
        if not self._inflight:
            return None
        cur = self._inflight.popleft()
        result = self.engine.await_result(cur.handle)
        cur.stamped.wait()           # compute_end stamped at device-ready
        if not cur.accounted:        # else already stamped by a harvest
            self._finalise_windows(cur)
        self.stats[cur.tenant]["tokens"] += result.tokens.size
        self.timeline.append(cur.entry)
        done_abs = self._t0 + cur.entry.compute_end
        return [Response(cur.tenant, result.tokens[i],
                         done_abs - r.arrival_s, len(cur.reqs))
                for i, r in enumerate(cur.reqs)]

    # ------------------------------------------------------------------
    # Continuous schedule: admission + micro-rounds over the slot table
    # ------------------------------------------------------------------
    def _admit_continuous(self) -> int:
        """Admit queued requests into free slots: one request per tenant
        pick so the slot table fills fairly (round-robin / straggler order),
        then the whole pick list admitted as one batch — same-bucket picks
        share a single batched prefill call and prefix-share pages.
        Rejected picks (slot or page pressure) are requeued at the front of
        their tenant's queue, preserving order."""
        eng = self._ceng
        picked: List[Request] = []
        while len(picked) < eng.free_slot_count():
            tenant = self._next_tenant()
            if tenant is None:
                break
            picked.append(self.queues[tenant].popleft())
        if not picked:
            return 0
        t0 = time.perf_counter() - self._t0
        flags = eng.try_admit_batch(picked)
        t1 = time.perf_counter() - self._t0
        admitted = 0
        for req, ok in zip(picked, flags):
            if ok:
                admitted += 1
                slot = self._slot_of[req.tenant]
                self.admission_timeline.append(TenantTimeline(
                    vdev=slot, pdev=0, slot=slot, transfer_start=t0,
                    transfer_end=t1, compute_start=t1, compute_end=t1))
        for req, ok in reversed(list(zip(picked, flags))):
            if not ok:
                self.queues[req.tenant].appendleft(req)
                # the pick didn't result in service: un-mark the tenant so
                # a straggler whose admission failed keeps its priority for
                # the rest of the round instead of being demoted
                self._round_served.discard(req.tenant)
        return admitted

    def _dispatch_round(self, asm_start: float) -> _InflightRound:
        handle = self._ceng.dispatch_round()
        te = time.perf_counter() - self._t0
        idx = self._cont_rounds
        self._cont_rounds += 1
        entry = TenantTimeline(vdev=idx, pdev=0, slot=idx,
                               transfer_start=asm_start, transfer_end=te,
                               compute_start=te, compute_end=0.0)
        stamped = self._get_waiter().submit(handle.emitted, entry)
        return _InflightRound(handle, entry, stamped)

    def _step_continuous(self) -> Optional[List[Response]]:
        eng = self._ceng
        if self._cont_inflight is None:
            asm0 = time.perf_counter() - self._t0
            if self._admit_continuous() == 0 and eng.active_count() == 0:
                if any(self.queues.values()):
                    # nothing in flight, so no retirement can ever free
                    # pages: admission failure is permanent — surface it
                    # instead of letting drain() spin on pending() forever
                    # (run_all has the same guard)
                    raise RuntimeError(
                        "paged pool cannot admit any queued request (pool "
                        "too small for the head request)")
                return None
            self._cont_inflight = self._dispatch_round(asm0)
        cur = self._cont_inflight
        # retire-before-dispatch fast path: when round k's emissions have
        # already landed there is nothing to pipeline under — harvest and
        # retire its finished rows NOW, so their slots and pages are offered
        # to this step's admissions and round k+1 never carries them as
        # masked lanes (the PR-3 one-round retirement lag)
        res = eng.collect(cur.handle) if cur.handle.ready() else None
        # overlap point: the next round's admissions (host assembly, prefill
        # + KV-scatter enqueue) and its dispatch land here, while round k
        # still occupies the device — rows that finish in round k ride as
        # masked lanes in round k+1 only when round k is still in flight
        asm0 = time.perf_counter() - self._t0
        admitted = self._admit_continuous()
        # pipeline round k+1 only if it will have live rows: fresh
        # admissions, or a current row whose budget outlasts round k (when
        # round k was already collected above, live_after(0) is exactly
        # "anything still unfinished"; otherwise its emissions are still in
        # flight and live_after(inner_steps) is "survives round k") — else
        # the drain would end on a dispatched-but-never-collected all-masked
        # round, wasting a device round and skewing the occupancy counters
        live = eng.live_after(0 if res is not None else eng.inner_steps)
        self._cont_inflight = (self._dispatch_round(asm0)
                               if admitted or live else None)
        if res is None:
            res = eng.collect(cur.handle)
        cur.stamped.wait()
        cur.entry.compute_start = max(cur.entry.compute_start,
                                      min(self._last_ready,
                                          cur.entry.compute_end))
        self._last_ready = cur.entry.compute_end
        self.timeline.append(cur.entry)
        # busy attribution: the round's device window split across tenants
        # by live row-steps (masked lanes bill nobody)
        busy = cur.entry.compute_end - cur.entry.compute_start
        total_steps = int(res.active_steps.sum())
        if total_steps > 0:
            for c, req in enumerate(res.slot_reqs):
                if req is None or res.active_steps[c] == 0:
                    continue
                share = busy * float(res.active_steps[c]) / total_steps
                self.stats[req.tenant]["busy_s"] += share
                self._row_busy[c] += share
        done_abs = self._t0 + cur.entry.compute_end
        responses: List[Response] = []
        for req, tokens, c in res.finished:
            st = self.stats[req.tenant]
            st["requests"] += 1
            st["tokens"] += tokens.size
            row_busy = self._row_busy.pop(c, 0.0)
            self._note_batch_time(req.tenant, row_busy)
            self.detector.update({self._slot_of[req.tenant]: row_busy})
            responses.append(Response(req.tenant, tokens,
                                      done_abs - req.arrival_s, 1))
        return responses

    # ------------------------------------------------------------------
    # Blocking schedule (A/B baseline): generate() per slot
    # ------------------------------------------------------------------
    def _stage_next(self) -> None:
        if self._prepared is None:
            tenant = self._next_tenant()
            if tenant is not None:
                asm_start = time.perf_counter() - self._t0
                self._prepared = self._build_batch(tenant)
                if self._prepared is not None:
                    self._asm_window = (asm_start,
                                        time.perf_counter() - self._t0)

    def _step_blocking(self) -> Optional[List[Response]]:
        self._stage_next()
        if self._prepared is None:
            return None
        tenant, reqs, prompts, steps = self._prepared
        self._prepared = None
        asm_start, asm_end = self._asm_window
        t0 = time.perf_counter()
        result: GenerationResult = self.engine.generate(prompts, steps)
        done = time.perf_counter()       # service completion: BEFORE the
        busy = done - t0                 # stage-ahead work below, so the
        # compute window and latencies don't absorb the next slot's assembly
        # (stats recorded first so the stage-ahead pick sees this batch's
        # fresh latency, not stale data)
        self._account_busy(tenant, len(reqs), busy)
        self.stats[tenant]["tokens"] += result.tokens.size
        # stage-ahead: assemble the next slot's batch before finalising this
        # slot's responses (host-side analogue of stage(k+1) under compute(k))
        self._stage_next()
        self.timeline.append(TenantTimeline(
            vdev=self._slot_of[tenant], pdev=0, slot=self._slot_of[tenant],
            transfer_start=asm_start, transfer_end=asm_end,
            compute_start=t0 - self._t0, compute_end=done - self._t0))
        return [Response(tenant, result.tokens[i], done - r.arrival_s,
                         len(reqs)) for i, r in enumerate(reqs)]

    # ------------------------------------------------------------------
    def step(self) -> Optional[List[Response]]:
        """Serve one scheduling step; returns responses (None if idle).
        Overlapped/blocking: one tenant slot.  Continuous: one decode
        micro-round (responses are the rows that retired in it)."""
        if self.mode == "continuous":
            return self._step_continuous()
        if self.mode == "overlapped":
            return self._step_overlapped()
        return self._step_blocking()

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.pending():
            r = self.step()
            if r:
                out.extend(r)
        # reap the now-idle completion-waiter thread so schedulers that end
        # with drain() (the common shape) don't each park a daemon thread
        # rooting the scheduler; it is recreated lazily on the next launch
        self.close()
        return out

    # ------------------------------------------------------------------
    @property
    def continuous_engine(self):
        """The scheduler's ContinuousBatchingEngine (None outside
        mode='continuous') — the public handle for occupancy/page stats."""
        return self._ceng

    # ------------------------------------------------------------------
    def utilization_report(self) -> Dict[str, Dict[str, float]]:
        total_busy = sum(s["busy_s"] for s in self.stats.values())
        return {t: dict(s, busy_share=(s["busy_s"] / total_busy
                                       if total_busy else 0.0))
                for t, s in self.stats.items()}
