"""Multi-tenant serving scheduler (the paper's second multi-tenancy reading:
several applications share one physical accelerator).

Each tenant owns a request queue; the scheduler cycles *tenant slots* on the
shared device.  Batch assembly for the *next* tenant slot is pipelined: the
scheduler pre-assembles slot k+1's padded batch before fetching slot k's
responses, mirroring the stage(k+1)-under-compute(k) schedule the risk stack
runs on :class:`repro.core.pipeline.PipelineExecutor` (the engine's generate
loop is host-blocking, so here the overlap is batch-granular host work; true
device-transfer overlap is the pipeline's domain — see the contract note in
:mod:`repro.core.pipeline`).

Slot selection is straggler-aware: with ``straggler_priority=True`` the
scheduler serves the tenant with the slowest recent per-request time first
(the serving analogue of ``reorder_for_stragglers``); otherwise plain
round-robin.  Per-slot :class:`repro.core.pipeline.TenantTimeline` records
(assembly window = transfer, generate window = compute) feed the benchmark
harness and the planner's utilisation model.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pipeline import TenantTimeline
from repro.core.tenancy import TenancyConfig
from repro.distributed.fault import StragglerDetector
from repro.serving.engine import GenerationResult, ServingEngine


@dataclasses.dataclass
class Request:
    tenant: str
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    arrival_s: float = dataclasses.field(default_factory=time.perf_counter)


@dataclasses.dataclass
class Response:
    tenant: str
    tokens: np.ndarray
    latency_s: float
    batch_size: int


class MultiTenantScheduler:
    """Tenant-slot batching over one shared engine (round-robin or
    straggler-priority), with pipelined next-slot batch assembly."""

    def __init__(self, engine: ServingEngine, max_batch: int = 8,
                 tenancy: Optional[TenancyConfig] = None,
                 straggler_priority: bool = False):
        self.engine = engine
        self.max_batch = max_batch
        self.tenancy = tenancy or TenancyConfig(1, 2)
        self.straggler_priority = straggler_priority
        self.queues: Dict[str, Deque[Request]] = collections.defaultdict(
            collections.deque)
        self.detector = StragglerDetector()
        self.stats: Dict[str, Dict[str, float]] = collections.defaultdict(
            lambda: {"requests": 0, "tokens": 0, "busy_s": 0.0})
        self.timeline: List[TenantTimeline] = []
        self._order: List[str] = []
        self._slot_of: Dict[str, int] = {}
        # next tenant slot's pre-assembled batch: (tenant, reqs, prompts,
        # steps) — assembled while the previous slot's responses were being
        # finalised (host-side stage-ahead)
        self._prepared: Optional[Tuple[str, List[Request], np.ndarray, int]] \
            = None
        self._asm_window = (0.0, 0.0)
        self._round_served: set = set()
        self._recent: Dict[str, float] = {}   # EWMA per-request seconds
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.tenant not in self._order:
            self._slot_of[req.tenant] = len(self._order)
            self._order.append(req.tenant)
        self.queues[req.tenant].append(req)

    def pending(self) -> int:
        n = sum(len(q) for q in self.queues.values())
        if self._prepared is not None:   # staged-ahead batch not yet served
            n += len(self._prepared[1])
        return n

    # ------------------------------------------------------------------
    # EWMA weight for per-tenant recent latency (straggler-priority pick)
    _RECENT_ALPHA = 0.5

    def _recent_s(self, tenant: str) -> float:
        return self._recent.get(tenant, 0.0)

    def _note_batch_time(self, tenant: str, per_req_s: float) -> None:
        """EWMA of per-request time: tracks *recent* speed, so a tenant that
        was slow long ago but recovered stops being prioritised (a lifetime
        mean would pin the priority to stale history)."""
        prev = self._recent.get(tenant)
        a = self._RECENT_ALPHA
        self._recent[tenant] = (per_req_s if prev is None
                                else a * per_req_s + (1 - a) * prev)

    def _next_tenant(self) -> Optional[str]:
        if self.straggler_priority:
            backlog = [t for t in self._order if self.queues[t]]
            if not backlog:
                return None
            # slowest recent tenant first *within a round*: every tenant
            # with backlog is served once before any tenant repeats, so the
            # priority orders a finite round (the serving analogue of
            # reorder_for_stragglers) instead of starving fast tenants
            fresh = [t for t in backlog if t not in self._round_served]
            if not fresh:
                self._round_served.clear()
                fresh = backlog
            pick = max(fresh, key=self._recent_s)
            self._round_served.add(pick)
            return pick
        for _ in range(len(self._order)):
            t = self._order.pop(0)
            self._order.append(t)
            if self.queues[t]:
                return t
        return None

    def _assemble(self, tenant: str) -> List[Request]:
        q = self.queues[tenant]
        batch = []
        while q and len(batch) < self.max_batch:
            batch.append(q.popleft())
        return batch

    def _build_batch(self, tenant: str
                     ) -> Optional[Tuple[str, List[Request], np.ndarray, int]]:
        reqs = self._assemble(tenant)
        if not reqs:
            return None
        # pad prompts to a common length (right-aligned batch)
        s_max = max(r.prompt.size for r in reqs)
        prompts = np.zeros((len(reqs), s_max), np.int32)
        for i, r in enumerate(reqs):
            prompts[i, s_max - r.prompt.size:] = r.prompt
        return tenant, reqs, prompts, max(r.max_new_tokens for r in reqs)

    def _stage_next(self) -> None:
        if self._prepared is None:
            tenant = self._next_tenant()
            if tenant is not None:
                asm_start = time.perf_counter() - self._t0
                self._prepared = self._build_batch(tenant)
                if self._prepared is not None:
                    self._asm_window = (asm_start,
                                        time.perf_counter() - self._t0)

    def step(self) -> Optional[List[Response]]:
        """Serve one tenant slot; returns its responses (None if idle)."""
        self._stage_next()
        if self._prepared is None:
            return None
        tenant, reqs, prompts, steps = self._prepared
        self._prepared = None
        asm_start, asm_end = self._asm_window
        t0 = time.perf_counter()
        result: GenerationResult = self.engine.generate(prompts, steps)
        done = time.perf_counter()       # service completion: BEFORE the
        busy = done - t0                 # stage-ahead work below, so the
        # compute window and latencies don't absorb the next slot's assembly
        st = self.stats[tenant]          # record stats first so the
        st["requests"] += len(reqs)      # stage-ahead pick sees this batch's
        st["tokens"] += result.tokens.size   # fresh latency, not stale data
        st["busy_s"] += busy
        self._note_batch_time(tenant, busy / max(len(reqs), 1))
        self.detector.update({hash(tenant) % (2 ** 31): busy / max(len(reqs), 1)})
        # stage-ahead: assemble the next slot's batch before finalising this
        # slot's responses (host-side analogue of stage(k+1) under compute(k))
        self._stage_next()
        self.timeline.append(TenantTimeline(
            vdev=self._slot_of[tenant], pdev=0, slot=self._slot_of[tenant],
            transfer_start=asm_start, transfer_end=asm_end,
            compute_start=t0 - self._t0, compute_end=done - self._t0))
        return [Response(tenant, result.tokens[i], done - r.arrival_s,
                         len(reqs)) for i, r in enumerate(reqs)]

    def drain(self) -> List[Response]:
        out: List[Response] = []
        while self.pending():
            r = self.step()
            if r:
                out.extend(r)
        return out

    # ------------------------------------------------------------------
    def utilization_report(self) -> Dict[str, Dict[str, float]]:
        total_busy = sum(s["busy_s"] for s in self.stats.values())
        return {t: dict(s, busy_share=(s["busy_s"] / total_busy
                                       if total_busy else 0.0))
                for t, s in self.stats.items()}
