"""Deployment planner (paper §V-F): pick (#pdev, tenants) for an objective.

Objectives: "time" (Figs 17/18), "energy" (Figs 19/20), "edp" = energy x time
(Figs 21/22).  The planner also serves elastic scaling: given any chip budget
it emits the best feasible deployment (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import energymodel as em
from repro.core import perfmodel as pm


@dataclasses.dataclass(frozen=True)
class Deployment:
    n_pdev: int
    tenants_per_pdev: int
    exec_time_s: float
    energy_ws: float
    memory_per_pdev_mb: float

    @property
    def n_vdev(self) -> int:
        return self.n_pdev * self.tenants_per_pdev

    @property
    def edp(self) -> float:
        return self.exec_time_s * self.energy_ws


def evaluate(n_pdev: int, tenants: int, m: pm.PerfModelInputs,
             pw: em.PowerParams = em.K20) -> Deployment:
    return Deployment(
        n_pdev, tenants,
        exec_time_s=pm.exec_time_multitenancy(n_pdev, tenants, m),
        energy_ws=em.total_energy(n_pdev, tenants, m, pw),
        memory_per_pdev_mb=pm.memory_per_pdev_mb(n_pdev, tenants, m,
                                                 with_context=True))


def plan(m: pm.PerfModelInputs, objective: str = "time",
         max_pdev: int = pm.MAX_PDEV_PLATFORM, max_tenants: int = 12,
         pw: em.PowerParams = em.K20,
         budget_pdev: Optional[int] = None) -> Deployment:
    """Best feasible deployment under the objective (and chip budget)."""
    assert objective in ("time", "energy", "edp")
    best: Optional[Deployment] = None
    limit = min(max_pdev, budget_pdev) if budget_pdev else max_pdev
    for p in range(1, limit + 1):
        for v in range(1, max_tenants + 1):
            if not pm.feasible(p, v, m):
                continue
            d = evaluate(p, v, m, pw)
            key = {"time": d.exec_time_s, "energy": d.energy_ws,
                   "edp": d.edp}[objective]
            bkey = (None if best is None else
                    {"time": best.exec_time_s, "energy": best.energy_ws,
                     "edp": best.edp}[objective])
            if best is None or key < bkey - 1e-12:
                best = d
    assert best is not None, "no feasible deployment"
    return best


def full_surface(m: pm.PerfModelInputs, pw: em.PowerParams = em.K20,
                 max_pdev: int = 16, max_tenants: int = 12,
                 ) -> Dict[Tuple[int, int], Deployment]:
    out = {}
    for p in range(1, max_pdev + 1):
        for v in range(1, max_tenants + 1):
            if pm.feasible(p, v, m):
                out[(p, v)] = evaluate(p, v, m, pw)
    return out
