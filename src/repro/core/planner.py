"""Deployment planner (paper §V-F): pick (#pdev, tenants) for an objective.

Objectives: "time" (Figs 17/18), "energy" (Figs 19/20), "edp" = energy x time
(Figs 21/22).  The planner also serves elastic scaling: given any chip budget
it emits the best feasible deployment (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import energymodel as em
from repro.core import perfmodel as pm


@dataclasses.dataclass(frozen=True)
class Deployment:
    n_pdev: int
    tenants_per_pdev: int
    exec_time_s: float
    energy_ws: float
    memory_per_pdev_mb: float

    @property
    def n_vdev(self) -> int:
        return self.n_pdev * self.tenants_per_pdev

    @property
    def edp(self) -> float:
        return self.exec_time_s * self.energy_ws


def evaluate(n_pdev: int, tenants: int, m: pm.PerfModelInputs,
             pw: em.PowerParams = em.K20) -> Deployment:
    return Deployment(
        n_pdev, tenants,
        exec_time_s=pm.exec_time_multitenancy(n_pdev, tenants, m),
        energy_ws=em.total_energy(n_pdev, tenants, m, pw),
        memory_per_pdev_mb=pm.memory_per_pdev_mb(n_pdev, tenants, m,
                                                 with_context=True))


def plan(m: pm.PerfModelInputs, objective: str = "time",
         max_pdev: int = pm.MAX_PDEV_PLATFORM, max_tenants: int = 12,
         pw: em.PowerParams = em.K20,
         budget_pdev: Optional[int] = None) -> Deployment:
    """Best feasible deployment under the objective (and chip budget)."""
    assert objective in ("time", "energy", "edp")
    best: Optional[Deployment] = None
    limit = min(max_pdev, budget_pdev) if budget_pdev else max_pdev
    for p in range(1, limit + 1):
        for v in range(1, max_tenants + 1):
            if not pm.feasible(p, v, m):
                continue
            d = evaluate(p, v, m, pw)
            key = {"time": d.exec_time_s, "energy": d.energy_ws,
                   "edp": d.edp}[objective]
            bkey = (None if best is None else
                    {"time": best.exec_time_s, "energy": best.energy_ws,
                     "edp": best.edp}[objective])
            if best is None or key < bkey - 1e-12:
                best = d
    assert best is not None, "no feasible deployment"
    return best


@dataclasses.dataclass(frozen=True)
class TelemetryPlan:
    """`plan_from_telemetry` result: the deployment plus its provenance."""
    deployment: Deployment
    transfer_mode: str           # "sequential" | "concurrent", sim-compared
    m: pm.PerfModelInputs        # fitted perf-model inputs
    pw: em.PowerParams           # fitted (or fallback) power params
    transfer_rms_s: float        # fit residuals, for falsifiability
    compute_rms_s: float


def plan_from_telemetry(tel, objective: str = "time",
                        max_pdev: int = pm.MAX_PDEV_PLATFORM,
                        max_tenants: int = 12,
                        pw: Optional[em.PowerParams] = None,
                        budget_pdev: Optional[int] = None,
                        **fit_kw) -> TelemetryPlan:
    """Plan from recorded telemetry instead of static Table II constants.

    Fits `PerfModelInputs` by least squares over the per-round
    transfer/compute spans on the plane (``replay.*`` and
    ``timeline.*`` — see `repro.obs.fit`), fits `PowerParams` from any
    recorded ``power.sample`` events (falling back to ``pw`` or the
    paper's K20 set when none were recorded), runs the same search as
    `plan`, then picks the transfer mode by simulating both under the
    fitted inputs at the chosen deployment (ties go to sequential, the
    paper's winner).
    """
    from repro.core.simulator import SimInputs, simulate
    from repro.core.tenancy import TenancyConfig
    from repro.obs import fit as obs_fit

    pf = obs_fit.fit_perf_inputs(obs_fit.samples_from_telemetry(tel),
                                 **fit_kw)
    if pw is None:
        psamples = obs_fit.power_samples_from_telemetry(tel)
        pw = (obs_fit.fit_power_params(psamples) if len(psamples) >= 2
              else em.K20)
    d = plan(pf.m, objective=objective, max_pdev=max_pdev,
             max_tenants=max_tenants, pw=pw, budget_pdev=budget_pdev)
    makespans = {}
    for mode in ("sequential", "concurrent"):
        si = SimInputs(TenancyConfig(d.n_pdev, d.tenants_per_pdev, mode),
                       net=pf.m.net,
                       compute_time_1pdev=pf.m.compute_time_1pdev,
                       yet_mb=pf.m.yet_mb, elt_mb=pf.m.elt_mb,
                       pf_mb=pf.m.pf_mb, power=pw)
        makespans[mode] = simulate(si).makespan
    mode = ("sequential"
            if makespans["sequential"] <= makespans["concurrent"] + 1e-12
            else "concurrent")
    return TelemetryPlan(d, mode, pf.m, pw, pf.transfer_rms_s,
                         pf.compute_rms_s)


def full_surface(m: pm.PerfModelInputs, pw: em.PowerParams = em.K20,
                 max_pdev: int = 16, max_tenants: int = 12,
                 ) -> Dict[Tuple[int, int], Deployment]:
    out = {}
    for p in range(1, max_pdev + 1):
        for v in range(1, max_tenants + 1):
            if pm.feasible(p, v, m):
                out[(p, v)] = evaluate(p, v, m, pw)
    return out
