"""Host -> accelerator staging engines (paper §V-D1).

Two modes:
  * CONCURRENT — enqueue every tenant chunk at once; all transfers share the
    host link (each attains ~BW/n, Fig 8/10).
  * SEQUENTIAL — enqueue chunks one at a time in slot-major tenant order;
    each transfer gets full link bandwidth and tenant k's compute overlaps
    tenant k+1's staging (the paper's winning strategy).

`jax.device_put` is asynchronous, so SEQUENTIAL staging naturally overlaps
the already-dispatched tenant's compute.  The engine records per-chunk wall
times for the benchmark harness.

The engine exposes two levels of API: non-blocking :meth:`StagingEngine.put`
/ :meth:`StagingEngine.wait` primitives that the overlapped executor in
:mod:`repro.core.pipeline` interleaves with compute dispatch (the paper's
winning schedule), and the stage-everything :meth:`StagingEngine.stage`
entry point (the pre-pipeline blocking schedule, kept for A/B benchmarks).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.tenancy import TenantTask, TenancyConfig, VirtualDevicePool
from repro.obs.telemetry import Telemetry, get_telemetry


def _tree_bytes(tree: Any) -> int:
    """Total payload bytes of a pytree (host or device leaves)."""
    return sum(getattr(a, "nbytes", 0) for a in jax.tree.leaves(tree))


@dataclasses.dataclass
class StagedChunk:
    task: TenantTask
    arrays: Any                   # device-resident pytree
    enqueue_s: float
    ready_s: Optional[float] = None
    base_s: float = 0.0           # perf_counter() origin of the timestamps


class StagingEngine:
    def __init__(self, pool: VirtualDevicePool, mode: Optional[str] = None,
                 telemetry: Optional[Telemetry] = None):
        self.pool = pool
        self.mode = mode or pool.cfg.transfer_mode
        assert self.mode in ("sequential", "concurrent")
        self.log: List[Dict[str, float]] = []
        self.tel = get_telemetry(telemetry)

    # ------------------------------------------------------------------
    def _put(self, host_tree, device) -> Any:
        if device is None:
            return jax.tree.map(jax.numpy.asarray, host_tree)
        return jax.tree.map(lambda a: jax.device_put(a, device), host_tree)

    # -- non-blocking primitives (used by core.pipeline) ----------------
    def put(self, task: TenantTask, host_tree: Any,
            t0: Optional[float] = None) -> StagedChunk:
        """Enqueue one tenant chunk's host->device transfer (asynchronous:
        ``jax.device_put`` returns immediately).  ``t0`` anchors the chunk's
        timestamps; without it the enqueue instant is the origin."""
        base = t0 if t0 is not None else time.perf_counter()
        arrays = self._put(host_tree, self.pool.device_of(task.vdev))
        return StagedChunk(task, arrays, time.perf_counter() - base,
                           base_s=base)

    def wait(self, chunk: StagedChunk, t0: Optional[float] = None) -> StagedChunk:
        """Block until the chunk is device-resident; records the ready time
        against the same origin ``put`` used (or an explicit ``t0``).
        While the caller blocks here, previously dispatched compute keeps
        running on its device — this is the pipeline's overlap point."""
        jax.block_until_ready(chunk.arrays)
        base = t0 if t0 is not None else chunk.base_s
        chunk.ready_s = time.perf_counter() - base
        self.log.append({"vdev": chunk.task.vdev, "ready_s": chunk.ready_s,
                         "mode": self.mode})
        if self.tel.enabled:
            # the staging-lane span: enqueue -> device-resident, stamped
            # against the same origin the chunk's log times use
            nbytes = _tree_bytes(chunk.arrays)
            self.tel.record_span("transfer.stage", base + chunk.enqueue_s,
                                 base + chunk.ready_s, vdev=chunk.task.vdev,
                                 pdev=chunk.task.pdev, slot=chunk.task.slot,
                                 mode=self.mode, bytes=nbytes)
            self.tel.count("transfer.bytes", nbytes)
            self.tel.count("transfer.chunks")
        return chunk

    def stage(self, tasks: Sequence[TenantTask],
              chunk_of: Callable[[TenantTask], Any],
              block: bool = False) -> List[StagedChunk]:
        """Stage every tenant chunk per the configured mode.

        ``chunk_of(task)`` returns the host pytree for that tenant.  In
        sequential mode each chunk blocks until on-device before the next is
        enqueued (full-bandwidth transfers); concurrent mode enqueues all and
        only then (optionally) waits.

        This is the *stage-everything* entry point (the pre-pipeline blocking
        path, kept for A/B benchmarking); the overlapped executor in
        :mod:`repro.core.pipeline` drives :meth:`put`/:meth:`wait` directly so
        compute dispatch can interleave with staging.
        """
        t0 = time.perf_counter()
        out: List[StagedChunk] = []
        if self.mode == "sequential":
            for t in tasks:
                c = self.put(t, chunk_of(t), t0)
                self.wait(c, t0)
                out.append(c)
        else:
            for t in tasks:
                out.append(self.put(t, chunk_of(t), t0))
            if block:
                for c in out:
                    self.wait(c, t0)
        return out


@dataclasses.dataclass
class MeshStagedChunk:
    """One logical host->device transfer split across per-device lanes."""
    chunks: Dict[Any, StagedChunk]      # device -> its slice's StagedChunk
    host_tree: Any                      # original host pytree (for assembly)
    sharding_of: Callable[[Any], Any]   # leaf -> target NamedSharding


class MeshStagingLanes:
    """Per-mesh-slice staging: one sequential :class:`StagingEngine` per
    device of the mesh (the PR-1 multi-host staging item, revived).

    A host pytree destined for a sharded placement is split per device along
    the target sharding's index map and each slice rides its own lane —
    every lane is an independent sequential engine, so each transfer gets its
    slice of the link while slices of *different* lanes overlap.  ``wait``
    reassembles the staged single-device shards into committed global arrays
    with :func:`jax.make_array_from_single_device_arrays` (replicated leaves
    degenerate to one full copy per lane).
    """

    def __init__(self, mesh, telemetry: Optional[Telemetry] = None):
        self.mesh = mesh
        self.tel = get_telemetry(telemetry)
        devs = [d for d in mesh.devices.reshape(-1)]
        # each lane reports its own ``transfer.stage`` spans (pdev = lane
        # ordinal) onto the same plane
        self.engines = {
            d: StagingEngine(VirtualDevicePool(
                TenancyConfig(1, 1, "sequential"), devices=[d]),
                telemetry=self.tel)
            for d in devs}

    @property
    def n_lanes(self) -> int:
        return len(self.engines)

    def put(self, host_tree: Any, sharding_of: Callable[[Any], Any],
            slot: int = 0) -> MeshStagedChunk:
        chunks: Dict[Any, StagedChunk] = {}
        for lane, (dev, eng) in enumerate(self.engines.items()):
            def slice_leaf(a, _dev=dev):
                idx = sharding_of(a).devices_indices_map(a.shape)[_dev]
                return a[idx]
            task = TenantTask(vdev=0, pdev=lane, slot=slot, start=0, stop=1)
            chunks[dev] = eng.put(task, jax.tree.map(slice_leaf, host_tree))
        return MeshStagedChunk(chunks, host_tree, sharding_of)

    def wait(self, staged: MeshStagedChunk) -> Any:
        """Block every lane, then assemble the global sharded arrays."""
        with self.tel.span("transfer.assemble", lanes=len(staged.chunks)):
            for dev, chunk in staged.chunks.items():
                self.engines[dev].wait(chunk)
        devs = list(staged.chunks)

        def assemble(path_leaves):
            host, *shards = path_leaves
            sharding = staged.sharding_of(host)
            return jax.make_array_from_single_device_arrays(
                host.shape, sharding, list(shards))

        return jax.tree.map(
            lambda *leaves: assemble(leaves), staged.host_tree,
            *[staged.chunks[d].arrays for d in devs])


def reorder_for_stragglers(tasks: Sequence[TenantTask],
                           last_step_times: Optional[Dict[int, float]],
                           ) -> List[TenantTask]:
    """Straggler mitigation: stage the slowest tenant of the previous step
    first so its data is ready earliest (DESIGN.md §7)."""
    if not last_step_times:
        return list(tasks)
    return sorted(tasks, key=lambda t: -last_step_times.get(t.vdev, 0.0))
