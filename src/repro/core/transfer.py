"""Host -> accelerator staging engines (paper §V-D1).

Two modes:
  * CONCURRENT — enqueue every tenant chunk at once; all transfers share the
    host link (each attains ~BW/n, Fig 8/10).
  * SEQUENTIAL — enqueue chunks one at a time in slot-major tenant order;
    each transfer gets full link bandwidth and tenant k's compute overlaps
    tenant k+1's staging (the paper's winning strategy).

`jax.device_put` is asynchronous, so SEQUENTIAL staging naturally overlaps
the already-dispatched tenant's compute.  The engine records per-chunk wall
times for the benchmark harness.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.tenancy import TenantTask, TenancyConfig, VirtualDevicePool


@dataclasses.dataclass
class StagedChunk:
    task: TenantTask
    arrays: Any                   # device-resident pytree
    enqueue_s: float
    ready_s: Optional[float] = None


class StagingEngine:
    def __init__(self, pool: VirtualDevicePool, mode: Optional[str] = None):
        self.pool = pool
        self.mode = mode or pool.cfg.transfer_mode
        assert self.mode in ("sequential", "concurrent")
        self.log: List[Dict[str, float]] = []

    # ------------------------------------------------------------------
    def _put(self, host_tree, device) -> Any:
        if device is None:
            return jax.tree.map(jax.numpy.asarray, host_tree)
        return jax.tree.map(lambda a: jax.device_put(a, device), host_tree)

    def stage(self, tasks: Sequence[TenantTask],
              chunk_of: Callable[[TenantTask], Any],
              block: bool = False) -> List[StagedChunk]:
        """Stage every tenant chunk per the configured mode.

        ``chunk_of(task)`` returns the host pytree for that tenant.  In
        sequential mode each chunk blocks until on-device before the next is
        enqueued (full-bandwidth transfers); concurrent mode enqueues all and
        only then (optionally) waits.
        """
        t0 = time.perf_counter()
        out: List[StagedChunk] = []
        if self.mode == "sequential":
            for t in tasks:
                arrays = self._put(chunk_of(t), self.pool.device_of(t.vdev))
                jax.block_until_ready(arrays)
                now = time.perf_counter() - t0
                out.append(StagedChunk(t, arrays, now, now))
                self.log.append({"vdev": t.vdev, "ready_s": now,
                                 "mode": "sequential"})
        else:
            for t in tasks:
                arrays = self._put(chunk_of(t), self.pool.device_of(t.vdev))
                out.append(StagedChunk(t, arrays,
                                       time.perf_counter() - t0))
            if block:
                for c in out:
                    jax.block_until_ready(c.arrays)
                    c.ready_s = time.perf_counter() - t0
                    self.log.append({"vdev": c.task.vdev, "ready_s": c.ready_s,
                                     "mode": "concurrent"})
        return out


def reorder_for_stragglers(tasks: Sequence[TenantTask],
                           last_step_times: Optional[Dict[int, float]],
                           ) -> List[TenantTask]:
    """Straggler mitigation: stage the slowest tenant of the previous step
    first so its data is ready earliest (DESIGN.md §7)."""
    if not last_step_times:
        return list(tasks)
    return sorted(tasks, key=lambda t: -last_step_times.get(t.vdev, 0.0))
