"""Energy model — paper Equation 10 and the 4-state power model (§V-F2).

GPU states: (1) idle-assigned, (2) receiving data, (3) receive+compute,
(4) compute.  States 1-2 draw P_idle_assigned; states 3-4 draw P_busy.
The K20 constants are the paper's nvidia-smi measurements; the v5e set is an
estimated target-hardware profile (documented in DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core import perfmodel as pm


@dataclasses.dataclass(frozen=True)
class PowerParams:
    name: str
    p_busy: float            # W, computing (with or without concurrent DMA)
    p_idle_assigned: float   # W, initialised & waiting / receiving only
    p_unassigned: float      # W, not assigned to any application


K20 = PowerParams("K20", p_busy=102.0, p_idle_assigned=47.0, p_unassigned=25.0)
V5E = PowerParams("v5e-est", p_busy=170.0, p_idle_assigned=60.0,
                  p_unassigned=30.0)


def total_energy(n_pdev: int, tenants_per_pdev: int, m: pm.PerfModelInputs,
                 pw: PowerParams = K20) -> float:
    """Eq 10: every pdev computes for tenants*T_comp(#v) = T_comp(#p) seconds
    at P_busy and idles (assigned) the rest of the makespan."""
    exec_time = pm.exec_time_multitenancy(n_pdev, tenants_per_pdev, m)
    compute_time = pm.t_computation(n_pdev, m)
    return n_pdev * (compute_time * pw.p_busy +
                     (exec_time - compute_time) * pw.p_idle_assigned)


def energy_surface(m: pm.PerfModelInputs, pw: PowerParams = K20,
                   max_pdev: int = pm.MAX_PDEV_PLATFORM, max_tenants: int = 12,
                   ) -> Dict[Tuple[int, int], float]:
    out = {}
    for p in range(1, max_pdev + 1):
        for v in range(1, max_tenants + 1):
            if pm.feasible(p, v, m):
                out[(p, v)] = total_energy(p, v, m, pw)
    return out


def edp_surface(m: pm.PerfModelInputs, pw: PowerParams = K20,
                max_pdev: int = pm.MAX_PDEV_PLATFORM, max_tenants: int = 12,
                ) -> Dict[Tuple[int, int], float]:
    """energy * execution-time space (Figs 21/22)."""
    t = pm.surface(m, max_pdev, max_tenants)
    e = energy_surface(m, pw, max_pdev, max_tenants)
    return {k: t[k] * e[k] for k in t}
