"""Performance model — paper Equations 4-9 with Table II constants.

The model predicts total execution time for any (#pdev, tenants_per_pdev)
deployment, for a given network.  Validated against the paper's own numbers
(tests/test_perfmodel.py): optimal deployments 7x2 (QDR) and 9x2 (FDR),
and the single-tenant rCUDA curves of Fig 9.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class NetworkParams:
    """Per-vdev staging cost constants (Table II, seconds)."""
    name: str
    t_malloc: float
    t_small: float            # all <100 B structures together
    t_4mb: float              # PF
    t_120mb: float            # ELT
    t_4gb: float              # the full YET (bandwidth-bound part)

    @property
    def per_vdev_overhead(self) -> float:
        return self.t_malloc + self.t_small + self.t_4mb + self.t_120mb


# --- Table II ---------------------------------------------------------------
QDR = NetworkParams("QDR-IB", t_malloc=0.00267, t_small=0.0048,
                    t_4mb=0.00133, t_120mb=0.036, t_4gb=1.171)
FDR = NetworkParams("FDR-IB", t_malloc=0.0027, t_small=0.0028,
                    t_4mb=0.00079, t_120mb=0.0205, t_4gb=0.67)
# --- TPU v5e host->HBM staging (beyond-paper target; estimated constants:
#     ~50 GB/s effective host DMA per chip, O(0.1 ms) per-buffer overheads) ---
V5E = NetworkParams("v5e-DMA", t_malloc=0.0001, t_small=0.0001,
                    t_4mb=0.00008, t_120mb=0.0024, t_4gb=0.08)

COMPUTATION_TIME_1PDEV = 9.55   # s, paper §V-F1 Table II (NVIDIA K20)
K20_MEMORY_MB = 4799            # nvidia-smi total memory
YET_MB, ELT_MB, PF_MB = 4000.0, 120.0, 1.0
CONTEXT_MB = 75.0               # per-tenant GPU-context overhead: reproduces
                                # the paper's ">4 vGPUs exhaust the K20" cap
MAX_PDEV_PLATFORM = 12          # paper §V-E: "Up to 12 pGPUs will be used"


@dataclasses.dataclass(frozen=True)
class PerfModelInputs:
    net: NetworkParams
    compute_time_1pdev: float = COMPUTATION_TIME_1PDEV
    yet_mb: float = YET_MB
    elt_mb: float = ELT_MB
    pf_mb: float = PF_MB
    context_mb: float = CONTEXT_MB
    device_memory_mb: float = K20_MEMORY_MB


def t_computation(n_dev: int, m: PerfModelInputs) -> float:
    """Eq 5 — perfect compute scalability (paper §V-B/V-C)."""
    return m.compute_time_1pdev / n_dev


def t_transfer(n_dev: int, m: PerfModelInputs) -> float:
    """Eq 6 — per-vdev overheads scale with #devices; the YET body is
    bandwidth-bound and its total is constant."""
    return n_dev * m.net.per_vdev_overhead + m.net.t_4gb


def exec_time_no_mt(n_pdev: int, m: PerfModelInputs) -> float:
    """Eq 4 — sequential transfers, single tenancy, no same-device overlap."""
    return t_transfer(n_pdev, m) + t_computation(n_pdev, m)


def exec_time_multitenancy(n_pdev: int, tenants_per_pdev: int,
                           m: PerfModelInputs) -> float:
    """Eq 9 = max(Eq 7, Eq 8)."""
    nv = n_pdev * tenants_per_pdev
    fully = (t_transfer(nv, m) / tenants_per_pdev
             + tenants_per_pdev * t_computation(nv, m))       # Eq 7
    not_fully = t_transfer(nv, m) + t_computation(nv, m)       # Eq 8
    return max(fully, not_fully)


def memory_per_pdev_mb(n_pdev: int, tenants_per_pdev: int,
                       m: PerfModelInputs, with_context: bool = False) -> float:
    nv = n_pdev * tenants_per_pdev
    ctx = m.context_mb if with_context else 0.0
    return tenants_per_pdev * (m.yet_mb / nv + m.elt_mb + m.pf_mb + ctx)


def feasible(n_pdev: int, tenants_per_pdev: int, m: PerfModelInputs) -> bool:
    return memory_per_pdev_mb(n_pdev, tenants_per_pdev, m,
                              with_context=True) <= m.device_memory_mb


def surface(m: PerfModelInputs, max_pdev: int = MAX_PDEV_PLATFORM,
            max_tenants: int = 12) -> Dict[Tuple[int, int], float]:
    """Execution-time surface over the deployment space (Figs 17/18)."""
    out = {}
    for p in range(1, max_pdev + 1):
        for v in range(1, max_tenants + 1):
            if feasible(p, v, m):
                out[(p, v)] = exec_time_multitenancy(p, v, m)
    return out
