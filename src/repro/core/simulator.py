"""Discrete-event simulator of the tenancy/transfer schedule.

Models one host link (InfiniBand in the paper, host-DMA on TPU) feeding
``n_pdev`` accelerators, each able to overlap DMA with compute (the paper's
multi-tenancy premise), with tenants serialised per device ("the NVIDIA
driver executes them sequentially").

Reproduces the paper's artefacts exactly (tests/test_simulator.py):
  * Fig 8/10 — concurrent streams share the link: BW_eff(n) = BW/n
  * Fig 11b  — 4 pdev, sequential, 1 tenant: makespan = 88 x 35 ms cells
  * Fig 13a  — 2 tenants/pdev: 80 cells;  Fig 13b — 4 tenants: 76 cells
  * Fig 12/14 — utilisation & energy of each schedule

The *executable* counterpart of this simulated schedule is
:mod:`repro.core.pipeline` — see the simulator-vs-executable overlap
contract documented there; benchmarks/pipeline.py measures how closely the
real stack tracks the model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.energymodel import K20, PowerParams
from repro.core.perfmodel import (COMPUTATION_TIME_1PDEV, ELT_MB,
                                  NetworkParams, PF_MB, YET_MB, FDR,
                                  PerfModelInputs)
from repro.core.tenancy import TenancyConfig, VirtualDevicePool

PAPER_STEP_S = 0.035  # one timeline cell in Figs 11/13


@dataclasses.dataclass(frozen=True)
class SimInputs:
    tenancy: TenancyConfig
    net: NetworkParams = FDR
    compute_time_1pdev: float = COMPUTATION_TIME_1PDEV
    yet_mb: float = YET_MB
    elt_mb: float = ELT_MB
    pf_mb: float = PF_MB
    power: PowerParams = K20


@dataclasses.dataclass
class TenantEvent:
    vdev: int
    pdev: int
    slot: int
    transfer_start: float
    transfer_end: float
    compute_start: float
    compute_end: float


@dataclasses.dataclass
class SimResult:
    makespan: float
    events: List[TenantEvent]
    utilization: float          # mean busy fraction across pdevs
    energy_ws: float            # 4-state model integrated over the timeline

    def steps(self, step: float = PAPER_STEP_S) -> int:
        return int(math.ceil(self.makespan / step - 1e-9))


def effective_bandwidth(n_streams: int, link_bw_mb_s: float) -> float:
    """Fig 8/10: n concurrent streams on one link each attain BW/n."""
    return link_bw_mb_s / max(n_streams, 1)


def _per_tenant_times(si: SimInputs) -> Tuple[float, float]:
    """(transfer_seconds, compute_seconds) for one tenant."""
    nv = si.tenancy.n_vdev
    # bandwidth-equivalent of Table II: YET body time scales with slice size;
    # ELT/PF/small/malloc overheads are per tenant
    transfer = (si.net.t_4gb * (si.yet_mb / YET_MB) / nv
                + si.net.per_vdev_overhead
                * (si.elt_mb / ELT_MB * 0 + 1))  # overheads are per-vdev consts
    compute = si.compute_time_1pdev / nv
    return transfer, compute


def simulate(si: SimInputs) -> SimResult:
    """Continuous-time simulation; returns the schedule and its metrics."""
    tc = si.tenancy
    pool = VirtualDevicePool(tc)
    tasks = pool.plan(tc.n_vdev)          # unit work per vdev; sizes equal
    t_tr, t_cp = _per_tenant_times(si)

    events: List[TenantEvent] = []
    if tc.transfer_mode == "sequential":
        # staging order = slot-major (pool.plan order): every pdev's first
        # tenant before any second tenant (paper Fig 13)
        link_free = 0.0
        for t in tasks:
            ts, te = link_free, link_free + t_tr
            link_free = te
            events.append(TenantEvent(t.vdev, t.pdev, t.slot, ts, te, 0.0, 0.0))
    else:  # concurrent: all streams share the link; equal sizes finish together
        total = t_tr * len(tasks)
        for t in tasks:
            events.append(TenantEvent(t.vdev, t.pdev, t.slot, 0.0, total,
                                      0.0, 0.0))

    # compute: tenants serialised per pdev, start when data ready & pdev free
    pdev_free = [0.0] * tc.n_pdev
    for ev in sorted(events, key=lambda e: (e.slot, e.pdev)):
        start = max(ev.transfer_end, pdev_free[ev.pdev])
        ev.compute_start = start
        ev.compute_end = start + t_cp
        pdev_free[ev.pdev] = ev.compute_end

    makespan = max(e.compute_end for e in events)
    busy = sum(e.compute_end - e.compute_start for e in events)
    util = busy / (tc.n_pdev * makespan)
    energy = (busy * si.power.p_busy +
              (tc.n_pdev * makespan - busy) * si.power.p_idle_assigned)
    return SimResult(makespan, events, util, energy)


def simulate_cells(si: SimInputs, step: float = PAPER_STEP_S) -> SimResult:
    """Cell-quantized simulation matching the paper's Fig 11/13 timelines.

    The figures draw each activity as whole 35 ms cells: per-tenant transfer
    = YET slice + the 120 MB ELT copy (sub-cell malloc/small overheads are
    invisible at this resolution), rounded to the nearest cell; per-tenant
    compute likewise.  With Table II FDR constants this reproduces the
    paper's cell counts exactly: 88 / 80 / 76 for 1 / 2 / 4 tenants on
    4 pdevs, with "all data by step 20" (Fig 11b), "first four by 12, all
    by 24" (Fig 13a) and "first round by 8" (Fig 13b).
    """
    tc = si.tenancy
    nv = tc.n_vdev
    tr_cells = round((si.net.t_4gb * (si.yet_mb / YET_MB) / nv
                      + si.net.t_120mb * (si.elt_mb / ELT_MB)) / step)
    cp_cells = round(si.compute_time_1pdev / nv / step)
    pool = VirtualDevicePool(tc)
    tasks = pool.plan(nv)

    events: List[TenantEvent] = []
    if tc.transfer_mode == "sequential":
        link = 0
        for t in tasks:
            events.append(TenantEvent(t.vdev, t.pdev, t.slot,
                                      link * step, (link + tr_cells) * step,
                                      0.0, 0.0))
            link += tr_cells
    else:
        total = tr_cells * nv
        for t in tasks:
            events.append(TenantEvent(t.vdev, t.pdev, t.slot, 0.0,
                                      total * step, 0.0, 0.0))

    pdev_free = [0.0] * tc.n_pdev
    for ev in sorted(events, key=lambda e: (e.slot, e.pdev)):
        start = max(ev.transfer_end, pdev_free[ev.pdev])
        ev.compute_start = start
        ev.compute_end = start + cp_cells * step
        pdev_free[ev.pdev] = ev.compute_end

    makespan = max(e.compute_end for e in events)
    busy = sum(e.compute_end - e.compute_start for e in events)
    util = busy / (tc.n_pdev * makespan)
    energy = (busy * si.power.p_busy +
              (tc.n_pdev * makespan - busy) * si.power.p_idle_assigned)
    return SimResult(makespan, events, util, energy)


def makespan_steps(n_pdev: int, tenants: int, mode: str = "sequential",
                   si: Optional[SimInputs] = None,
                   step: float = PAPER_STEP_S, cells: bool = True) -> int:
    si = si or SimInputs(TenancyConfig(n_pdev, tenants, mode))
    si = dataclasses.replace(si, tenancy=TenancyConfig(n_pdev, tenants, mode))
    res = simulate_cells(si, step) if cells else simulate(si)
    return res.steps(step)


def concurrent_vs_sequential(n_pdev: int = 4,
                             si: Optional[SimInputs] = None,
                             ) -> Dict[str, SimResult]:
    """Fig 11 + Fig 12: both transfer modes for the same hardware."""
    base = si or SimInputs(TenancyConfig(n_pdev, 1))
    out = {}
    for mode in ("concurrent", "sequential"):
        s = dataclasses.replace(base, tenancy=TenancyConfig(n_pdev, 1, mode))
        out[mode] = simulate(s)
    return out
