"""Overlapped multi-tenant execution pipeline (paper Figs 11/13, executable).

The simulator in :mod:`repro.core.simulator` *models* the paper's winning
schedule: with sequential transfers, tenant k+1's host->device staging rides
the link while tenant k's compute occupies its pdev, so the makespan is
``max(transfer chain, compute chains)`` instead of their sum.  Before this
module existed, the executable path did not honour that contract — it staged
every tenant chunk (blocking per chunk) and only then dispatched compute, so
the measured wall time was ``sum(transfers) + compute`` and the simulator's
predicted overlap never materialised.

:class:`PipelineExecutor` is the executable counterpart of the simulated
schedule — the **simulator-vs-executable overlap contract**:

* sequential mode — chunks are staged one at a time (each transfer owns the
  full link, paper Fig 10); the moment chunk k is device-resident its jitted
  compute is *dispatched* (asynchronously) and the executor immediately
  starts staging chunk k+1.  Transfer(k+1) therefore overlaps compute(k),
  which is exactly the double-buffering the simulator's ``simulate()``
  timeline assumes.
* concurrent mode — every transfer is enqueued up front (streams share the
  link, BW/n each, Fig 8); each tenant's compute is dispatched as soon as its
  chunk lands, in staging order.
* per-pdev serialisation — compute for tenants of one pdev is dispatched in
  slot order onto the same device, whose execution stream serialises them
  (the paper: "the NVIDIA driver executes them sequentially").
* straggler reordering — the previous step's slowest tenant is staged first
  (:func:`repro.core.transfer.reorder_for_stragglers`), so its data is ready
  earliest.

Every run returns a :class:`PipelineReport` whose :class:`TenantTimeline`
entries carry per-tenant ``transfer_start/transfer_end/compute_start/
compute_end`` wall-clock timestamps (relative to run start).  A dedicated
waiter thread blocks on each tenant's output *concurrently with the staging
loop* and stamps ``compute_end`` the moment the output is ready, so the
realised-overlap signal used by :meth:`PipelineReport.overlaps` —

    ``compute_start(k) <= transfer_start(k+1) < compute_end(k)``

(transfer k+1 began inside compute k's execution window) — is falsifiable in
both directions: a blocking stage-everything schedule fails the left
inequality (every transfer precedes every compute; this rejection is
structural, independent of timing noise), and a dispatch whose compute
drained before the next chunk was staged fails the right one.  One
measurement caveat on the right inequality: ``compute_end`` is stamped at
waiter-thread wakeup, so gaps shorter than a thread wakeup (~tens of µs)
are not resolved — the signal is meaningful for ms-scale tenant computes,
not µs-scale toys.  There is one waiter per pdev, and a pdev's tenants
complete in dispatch order (its device stream serialises them), so the
stamps carry no cross-pdev ordering skew.
"""
from __future__ import annotations

import dataclasses
import functools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro.core.tenancy import TenantTask, VirtualDevicePool
from repro.core.transfer import StagingEngine, reorder_for_stragglers

StageFn = Callable[[TenantTask], Any]           # task -> host pytree
ComputeFn = Callable[[TenantTask, Any], Any]    # (task, device pytree) -> out


@dataclasses.dataclass
class TenantTimeline:
    """Wall-clock activity windows of one tenant, relative to run start."""
    vdev: int
    pdev: int
    slot: int
    transfer_start: float
    transfer_end: float
    compute_start: float      # jitted-call dispatch time (async)
    compute_end: float        # block_until_ready return time

    @property
    def transfer_s(self) -> float:
        return self.transfer_end - self.transfer_start

    @property
    def compute_s(self) -> float:
        return self.compute_end - self.compute_start


def timeline_overlaps(timeline: Sequence[TenantTimeline]) -> List[bool]:
    """For each consecutive staged pair (k, k+1): did tenant k+1's transfer
    start *inside* tenant k's compute window?  All-True on a multi-tenant
    sequential run means the paper's overlap is realised (see the module
    docstring for why this predicate is falsifiable).  Shared by
    :class:`PipelineReport` and the benchmark harness (which reads the same
    timeline off a risk ``RunReport``)."""
    return [a.compute_start <= b.transfer_start < a.compute_end
            for a, b in zip(timeline, timeline[1:])]


@dataclasses.dataclass
class PipelineReport:
    results: Dict[int, Any]            # vdev -> device output
    timeline: List[TenantTimeline]     # in staging order
    wall_s: float
    mode: str

    def per_tenant_s(self) -> Dict[int, float]:
        return {tl.vdev: tl.compute_s for tl in self.timeline}

    def overlaps(self) -> List[bool]:
        return timeline_overlaps(self.timeline)

    def overlap_realised(self) -> bool:
        # majority-of-pairs, matching every live consumer of
        # timeline_overlaps (benchmarks + tests): noise on a shared host can
        # legitimately drain isolated pairs early, while a blocking schedule
        # structurally scores zero pairs
        ov = self.overlaps()
        return sum(ov) > len(ov) // 2 if ov else False


class CompletionWaiter:
    """Daemon thread that stamps ``TenantTimeline.compute_end`` the moment a
    dispatched device output is ready.

    This is the shared half of the overlap-measurement contract: the
    dispatching thread records ``transfer_*``/``compute_start`` and submits
    ``(output, timeline_entry)``; the waiter blocks on the output
    *concurrently with whatever the dispatcher does next* (staging the next
    chunk, assembling the next tenant's batch) and stamps ``compute_end`` at
    readiness, which is what makes the :func:`timeline_overlaps` predicate
    falsifiable on the right inequality.  Used per-pdev by
    :class:`PipelineExecutor` and as the per-engine waiter of
    :class:`repro.serving.multitenant.MultiTenantScheduler`.

    ``submit`` returns a :class:`threading.Event` set once the entry is
    stamped (or the wait raised), so callers can join a single item without
    closing the waiter.  Device errors surfacing on the blocking wait are
    recorded in :attr:`errors` — the thread keeps serving later items so a
    poisoned output can neither hang subsequent tickets nor leak the thread.
    """

    def __init__(self, clock: Callable[[], float],
                 name: str = "completion-waiter"):
        self._clock = clock
        self._q: "queue.Queue" = queue.Queue()
        self.errors: List[BaseException] = []
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    def submit(self, out: Any, entry: TenantTimeline,
               on_ready: Optional[Callable[[Any], None]] = None
               ) -> threading.Event:
        """Stamp ``entry.compute_end`` when ``out`` is ready; returns an
        event set after the stamp (and optional ``on_ready(out)``) ran."""
        stamped = threading.Event()
        self._q.put((out, entry, on_ready, stamped))
        return stamped

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            out, entry, on_ready, stamped = item
            try:
                jax.block_until_ready(out)
                entry.compute_end = self._clock()
                if on_ready is not None:
                    on_ready(out)
            except BaseException as e:   # device errors surface on block
                self.errors.append(e)    # re-raised by the owner
            finally:
                stamped.set()

    def close(self) -> None:
        """Drain remaining items, then stop and join the thread."""
        self._q.put(None)
        self._thread.join()


class PipelineExecutor:
    """Event-driven executor: stage chunk k+1 while chunk k computes.

    The executor owns a :class:`StagingEngine` (for placement + the staging
    log) but drives its non-blocking ``put``/``wait`` primitives instead of
    the stage-everything entry point, interleaving compute dispatch with the
    transfer chain.
    """

    def __init__(self, pool: VirtualDevicePool, mode: Optional[str] = None):
        self.pool = pool
        self.mode = mode or pool.cfg.transfer_mode
        assert self.mode in ("sequential", "concurrent")
        self.engine = StagingEngine(pool, self.mode)

    # ------------------------------------------------------------------
    def run(self, tasks: Sequence[TenantTask], stage_fn: StageFn,
            compute_fn: ComputeFn,
            straggler_hist: Optional[Dict[int, float]] = None,
            ) -> PipelineReport:
        """Execute every tenant task; returns results + per-tenant timeline.

        ``stage_fn(task)`` builds the host pytree for one tenant (cheap slice
        of pinned host data); ``compute_fn(task, device_tree)`` must be an
        *asynchronously dispatching* call (a jitted function) — the pipeline
        only blocks on outputs after every tenant has been dispatched.
        """
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0
        order = reorder_for_stragglers(tasks, straggler_hist)
        timeline: Dict[int, TenantTimeline] = {}
        results: Dict[int, Any] = {}

        # CompletionWaiter per pdev: blocks on each dispatched output
        # concurrently with the staging loop and stamps compute_end the
        # moment it is ready — this is what makes the overlap predicate
        # falsifiable (see module docstring).  The main thread only writes a
        # tenant's timeline entry before submitting it, the waiter only
        # stamps compute_end after.  One waiter per pdev: tenants of a pdev
        # complete in dispatch order anyway (the device stream serialises
        # them), so within-pdev blocking in dispatch order stamps *exact*
        # completion times, and a slow pdev can no longer inflate another
        # pdev's compute_end (the per-tenant times feed the
        # StragglerDetector, so skew there would mis-steer the next run's
        # staging order).
        waiters: Dict[int, CompletionWaiter] = {
            p: CompletionWaiter(now, name="pipeline-waiter")
            for p in {t.pdev for t in order}}

        def dispatch(task: TenantTask, chunk) -> None:
            self.engine.wait(chunk, t0)    # overlap point: compute of already
            te = now()                     # dispatched tenants keeps running
            out = compute_fn(task, chunk.arrays)
            timeline[task.vdev] = TenantTimeline(
                task.vdev, task.pdev, task.slot,
                chunk.enqueue_s, te, now(), 0.0)
            waiters[task.pdev].submit(
                out, timeline[task.vdev],
                on_ready=functools.partial(results.__setitem__, task.vdev))

        try:
            if self.mode == "sequential":
                # one transfer on the link at a time; compute(k) is already
                # in flight while put+wait stages chunk k+1 (double buffering)
                for task in order:
                    dispatch(task, self.engine.put(task, stage_fn(task), t0))
            else:
                # all transfers share the link from t~0; dispatch each
                # tenant's compute as its chunk lands, in staging order
                chunks = [self.engine.put(task, stage_fn(task), t0)
                          for task in order]
                for task, chunk in zip(order, chunks):
                    dispatch(task, chunk)
        finally:
            # always drain + reap the waiters, even when staging raises
            for w in waiters.values():
                w.close()
        waiter_err = [e for w in waiters.values() for e in w.errors]
        if waiter_err:
            raise waiter_err[0]
        return PipelineReport(results, [timeline[t.vdev] for t in order],
                              now(), self.mode)
