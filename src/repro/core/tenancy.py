"""Virtual-accelerator multi-tenancy (the paper's core concept, §V-D2).

A :class:`VirtualDevicePool` maps ``#v = n_pdev * tenants_per_pdev`` virtual
devices onto ``n_pdev`` physical devices.  Work splits across *all* vdevs;
each pdev serialises its tenants (the paper: "the NVIDIA driver executes them
sequentially"), while tenant k+1's host->device staging overlaps tenant k's
compute — that overlap is where multi-tenancy wins (Fig 13).

On TPU the pdev can also be a *mesh slice* (sharded tenants); the pool only
deals in work decomposition, the staging engine in :mod:`repro.core.transfer`
deals in placement.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class TenancyConfig:
    n_pdev: int                      # physical accelerators (or mesh slices)
    tenants_per_pdev: int = 1        # vGPUs per pGPU
    transfer_mode: str = "sequential"   # sequential | concurrent

    @property
    def n_vdev(self) -> int:
        return self.n_pdev * self.tenants_per_pdev

    def validate(self) -> None:
        assert self.n_pdev >= 1 and self.tenants_per_pdev >= 1
        assert self.transfer_mode in ("sequential", "concurrent")


@dataclasses.dataclass(frozen=True)
class TenantTask:
    """One virtual device's slice of the trial axis.

    ``padded_size`` (when set by :meth:`VirtualDevicePool.plan` with
    ``uniform=True``) is the uniform per-vdev shape every staged chunk is
    padded up to, so an uneven remainder does not produce a second jit trace:
    the executor pads the staged slice with neutral rows and slices the
    result back to ``size``.
    """
    vdev: int
    pdev: int
    slot: int                        # tenant index within its pdev
    start: int                       # trial-range [start, stop)
    stop: int
    padded_size: Optional[int] = None

    @property
    def size(self) -> int:
        return self.stop - self.start

    @property
    def pad(self) -> int:
        """Neutral rows appended when staged (0 without uniform planning)."""
        return 0 if self.padded_size is None else self.padded_size - self.size


class VirtualDevicePool:
    def __init__(self, cfg: TenancyConfig, devices: Optional[Sequence] = None):
        cfg.validate()
        self.cfg = cfg
        self.devices = list(devices) if devices is not None else None
        if self.devices is not None:
            assert len(self.devices) >= cfg.n_pdev, \
                f"need {cfg.n_pdev} devices, have {len(self.devices)}"

    # ------------------------------------------------------------------
    def vdev_to_pdev(self, vdev: int) -> Tuple[int, int]:
        """vdev id -> (pdev, slot).  vdevs are slot-major: vdevs [0, n_pdev)
        are every pdev's first tenant (the paper stages one tenant per pGPU
        first — Fig 13 timeline)."""
        slot, pdev = divmod(vdev, self.cfg.n_pdev)
        return pdev, slot

    def device_of(self, vdev: int):
        pdev, _ = self.vdev_to_pdev(vdev)
        return self.devices[pdev] if self.devices is not None else None

    # ------------------------------------------------------------------
    def uniform_size(self, num_items: int) -> int:
        """Per-vdev chunk shape when every slice is padded to a common size
        (= ceil(num_items / n_vdev)); one shape -> one jit trace."""
        nv = self.cfg.n_vdev
        return -(-num_items // nv)

    def plan(self, num_items: int, uniform: bool = False) -> List[TenantTask]:
        """Even split of the work axis over all vdevs (remainder spread over
        the first vdevs), in *staging order*: slot-major so that every pdev's
        first tenant is staged before any second tenant.

        With ``uniform=True`` every task carries ``padded_size`` =
        :meth:`uniform_size`, so stagers pad ragged remainders to one common
        chunk shape instead of retracing the jitted step per remainder shape.
        """
        nv = self.cfg.n_vdev
        base, rem = divmod(num_items, nv)
        sizes = [base + (1 if v < rem else 0) for v in range(nv)]
        padded = self.uniform_size(num_items) if uniform else None
        tasks, off = [], 0
        for v in range(nv):
            pdev, slot = self.vdev_to_pdev(v)
            tasks.append(TenantTask(v, pdev, slot, off, off + sizes[v],
                                    padded_size=padded))
            off += sizes[v]
        assert off == num_items
        return tasks

    def tasks_by_pdev(self, tasks: Sequence[TenantTask]) -> List[List[TenantTask]]:
        out: List[List[TenantTask]] = [[] for _ in range(self.cfg.n_pdev)]
        for t in tasks:
            out[t.pdev].append(t)
        for lst in out:
            lst.sort(key=lambda t: t.slot)
        return out


def memory_per_pdev_mb(tenants_per_pdev: int, n_pdev: int, yet_mb: float,
                       elt_mb: float, pf_mb: float) -> float:
    """Paper §V-F1 memory-capacity model: each tenant holds its YET slice plus
    a full ELT + PF copy.  (K20: 4 tenants -> 4x(1000+120+1) = 4484 MB.)"""
    nv = n_pdev * tenants_per_pdev
    return tenants_per_pdev * (yet_mb / nv + elt_mb + pf_mb)
