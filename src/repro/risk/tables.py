"""Synthetic YET / ELT / Portfolio generators (paper Section IV-A).

Deterministic (seeded) so tests and benchmarks are reproducible.  The
generator can produce paper-scale data (1M trials x 1000 events, 4 GB packed)
but defaults to reduced sizes; everything is plain numpy on the host — the
pipeline/staging layer owns device placement (that *is* the paper's topic).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.configs.risk_app import RiskAppConfig


@dataclasses.dataclass
class RiskTables:
    """Host-side tables.

    yet        : (T, K) int32 — per-trial event sequences (0 = pad/no-event)
    elt_losses : (E_cat + 1, M) float32 — direct-access tables, row 0 zero
    occ_ret/occ_lim : (M,) float32 — per-ELT occurrence terms (I)
    agg_ret/agg_lim : float — layer aggregate terms (T)
    """
    yet: np.ndarray
    elt_losses: np.ndarray
    occ_ret: np.ndarray
    occ_lim: np.ndarray
    agg_ret: float
    agg_lim: float

    @property
    def num_trials(self) -> int:
        return self.yet.shape[0]

    def nbytes(self) -> Dict[str, int]:
        return {"yet": self.yet.nbytes,
                "elt": self.elt_losses.nbytes,
                "terms": self.occ_ret.nbytes + self.occ_lim.nbytes + 16}


def generate(cfg: RiskAppConfig, seed: int = 0) -> RiskTables:
    rng = np.random.default_rng(seed)
    T, K, M = cfg.num_trials, cfg.events_per_trial, cfg.num_elts
    cat = cfg.event_catalog

    # Year Event Table: event ids; ~10% pad entries (trials vary in length)
    yet = rng.integers(1, cat + 1, size=(T, K), dtype=np.int64)
    pad = rng.random((T, K)) < 0.1
    yet[pad] = 0
    yet = yet.astype(np.int32)

    # Event Loss Tables: heavy-tailed losses; each ELT covers ~30% of events
    elt = np.zeros((cat + 1, M), np.float32)
    for m in range(M):
        covered = rng.random(cat) < 0.3
        losses = rng.lognormal(mean=10.0, sigma=1.5, size=cat).astype(np.float32)
        elt[1:, m] = np.where(covered, losses, 0.0)

    # financial terms: occurrence retention ~ p25 of losses, limit ~ p99
    nz = elt[elt > 0]
    occ_ret = np.full(M, np.percentile(nz, 25), np.float32) * \
        rng.uniform(0.5, 1.5, M).astype(np.float32)
    occ_lim = np.full(M, np.percentile(nz, 99), np.float32) * \
        rng.uniform(0.5, 1.5, M).astype(np.float32)
    # aggregate terms scale with expected annual loss
    mean_event = float(nz.mean()) if nz.size else 1.0
    exp_annual = mean_event * K * 0.9 * 0.3 * M   # pads x coverage x ELTs
    agg_ret = 0.1 * exp_annual
    agg_lim = 2.0 * exp_annual
    return RiskTables(yet, elt, occ_ret, occ_lim, float(agg_ret), float(agg_lim))


def paper_scale_nbytes(cfg: RiskAppConfig) -> Dict[str, float]:
    """Input footprints in MB for the perf model (paper: YET 4 GB, ELT 120 MB,
    PF 4 MB)."""
    yet_mb = cfg.num_trials * cfg.events_per_trial * 4 / 1e6
    elt_mb = (cfg.event_catalog + 1) * cfg.num_elts * 4 / 1e6
    return {"yet_mb": yet_mb, "elt_mb": elt_mb, "pf_mb": 1.0}
