"""Aggregate Risk Analysis engine (paper Algorithm 1-3) with multi-tenancy.

Three execution paths over the same numerics (kernels/ops.aggregate_loss):

* ``run_single`` — one jit'd call over all trials (baseline, Algorithm 1 with
  N=1).
* ``run_tenant_chunked`` — the paper's deployment: the trial axis splits over
  ``n_pdev x tenants_per_pdev`` virtual devices; chunks are staged per the
  configured transfer mode (sequential staging overlaps tenant k+1's transfer
  with tenant k's compute) and each pdev serialises its tenants.
* ``make_sharded_step`` — pjit over a mesh (trials sharded over every mesh
  axis) for the production dry-run; this is the "beyond-paper" scale-out.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.risk_app import RiskAppConfig
from repro.core.tenancy import TenancyConfig, VirtualDevicePool
from repro.core.transfer import StagingEngine, reorder_for_stragglers
from repro.kernels import ops as kops
from repro.risk.tables import RiskTables


@dataclasses.dataclass
class RunReport:
    ylt: np.ndarray
    wall_s: float
    per_tenant_s: Dict[int, float]
    staging_log: List[Dict[str, float]]


def _loss_args(tables: RiskTables):
    return (jnp.asarray(tables.elt_losses), jnp.asarray(tables.occ_ret),
            jnp.asarray(tables.occ_lim), jnp.asarray(tables.agg_ret),
            jnp.asarray(tables.agg_lim))


class AggregateRiskAnalysis:
    def __init__(self, cfg: RiskAppConfig,
                 tenancy: Optional[TenancyConfig] = None,
                 devices: Optional[list] = None):
        self.cfg = cfg
        self.tenancy = tenancy or TenancyConfig(
            n_pdev=max(1, len(devices or jax.devices())),
            tenants_per_pdev=cfg.tenants_per_device,
            transfer_mode=cfg.transfer_mode)
        self.pool = VirtualDevicePool(self.tenancy,
                                      devices or jax.devices())
        self._step = jax.jit(self._trial_losses, static_argnames=("chunk",))

    # ------------------------------------------------------------------
    def _trial_losses(self, yet, elt, occ_ret, occ_lim, agg_ret, agg_lim,
                      chunk: int):
        return kops.aggregate_loss(yet, elt, occ_ret, occ_lim, agg_ret,
                                   agg_lim, chunk=chunk)

    # ------------------------------------------------------------------
    def run_single(self, tables: RiskTables) -> np.ndarray:
        """Whole-YET single-device run (Algorithm 1, N=1)."""
        args = _loss_args(tables)
        ylt = self._step(jnp.asarray(tables.yet), *args,
                         chunk=min(self.cfg.chunk_events,
                                   tables.yet.shape[1]))
        return np.asarray(ylt)

    # ------------------------------------------------------------------
    def run_tenant_chunked(self, tables: RiskTables,
                           straggler_hist: Optional[Dict[int, float]] = None,
                           ) -> RunReport:
        """Multi-tenant execution: stage + compute per the tenancy plan."""
        t_start = time.perf_counter()
        tasks = self.pool.plan(tables.num_trials)
        tasks = reorder_for_stragglers(tasks, straggler_hist)
        engine = StagingEngine(self.pool)
        args_host = (tables.elt_losses, tables.occ_ret, tables.occ_lim,
                     np.float32(tables.agg_ret), np.float32(tables.agg_lim))

        # ELT + terms go to every pdev once (the un-splittable tables that
        # cause the paper's §V-B sub-linear scaling); YET slices per tenant.
        elt_by_pdev = {}
        for p in range(self.tenancy.n_pdev):
            dev = (self.pool.devices[p]
                   if self.pool.devices is not None else None)
            elt_by_pdev[p] = tuple(
                jax.device_put(a, dev) if dev is not None else jnp.asarray(a)
                for a in args_host)

        staged = engine.stage(
            tasks, lambda t: {"yet": tables.yet[t.start:t.stop]})

        chunk = min(self.cfg.chunk_events, tables.yet.shape[1])
        ylt = np.zeros(tables.num_trials, np.float32)
        per_tenant: Dict[int, float] = {}
        results = []
        for sc in staged:  # dispatch all (async) — pdev queues serialise
            t0 = time.perf_counter()
            out = self._step(sc.arrays["yet"], *elt_by_pdev[sc.task.pdev],
                             chunk=chunk)
            results.append((sc.task, out, t0))
        for task, out, t0 in results:
            out.block_until_ready()
            per_tenant[task.vdev] = time.perf_counter() - t0
            ylt[task.start:task.stop] = np.asarray(out)
        return RunReport(ylt, time.perf_counter() - t_start, per_tenant,
                         engine.log)

    # ------------------------------------------------------------------
    def make_sharded_step(self, mesh, chunk: Optional[int] = None):
        """pjit'd analysis step with trials sharded over every mesh axis
        (embarrassingly parallel leading axis -> all axes are data axes)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = tuple(mesh.axis_names)
        c = chunk or self.cfg.chunk_events

        def step(yet, elt, occ_ret, occ_lim, agg_ret, agg_lim):
            yet = jax.lax.with_sharding_constraint(
                yet, NamedSharding(mesh, P(axes,)))
            return kops.aggregate_loss(yet, elt, occ_ret, occ_lim,
                                       agg_ret, agg_lim, chunk=c)

        return jax.jit(step)

    def input_specs(self, num_trials: Optional[int] = None):
        """ShapeDtypeStructs for the dry-run (no allocation)."""
        cfg = self.cfg
        T = num_trials or cfg.num_trials
        K, M, cat = cfg.events_per_trial, cfg.num_elts, cfg.event_catalog
        f32, i32 = jnp.float32, jnp.int32
        return {
            "yet": jax.ShapeDtypeStruct((T, K), i32),
            "elt": jax.ShapeDtypeStruct((cat + 1, M), f32),
            "occ_ret": jax.ShapeDtypeStruct((M,), f32),
            "occ_lim": jax.ShapeDtypeStruct((M,), f32),
            "agg_ret": jax.ShapeDtypeStruct((), f32),
            "agg_lim": jax.ShapeDtypeStruct((), f32),
        }
