"""Aggregate Risk Analysis engine (paper Algorithm 1-3) with multi-tenancy.

Three execution paths over the same numerics (kernels/ops.aggregate_loss):

* ``run_single`` — one jit'd call over all trials (baseline, Algorithm 1 with
  N=1).
* ``run_tenant_chunked`` — the paper's deployment: the trial axis splits over
  ``n_pdev x tenants_per_pdev`` virtual devices and runs on the overlapped
  :class:`repro.core.pipeline.PipelineExecutor`: tenant k's jitted compute is
  dispatched the moment its chunk is device-resident, so tenant k+1's staging
  overlaps tenant k's compute (the paper's winning schedule, Fig 13) and each
  pdev's execution stream serialises its tenants.  ``overlapped=False`` keeps
  the old stage-everything-then-compute path for A/B benchmarking.
* ``make_sharded_step`` — pjit over a mesh (trials sharded over every mesh
  axis) for the production dry-run; this is the "beyond-paper" scale-out.

Hot-path overhead control (all observable, asserted in tests/test_pipeline.py):

* **One trace per deployment** — tenant plans are uniform-padded
  (``VirtualDevicePool.plan(..., uniform=True)``), so ragged trial remainders
  share one chunk shape and the jitted step compiles once; ``trace_count``
  counts actual traces.
* **Resident tables** — the un-splittable ELT + occurrence-term tables (the
  cause of the paper's §V-B sub-linear scaling) are uploaded to each pdev
  once and cached on the engine keyed by table identity, so repeated runs
  (serving bursts, ``examples/risk_realtime.py``) stop re-staging ~120 MB per
  step; ``table_uploads`` counts actual uploads.  Layer aggregate terms stay
  dynamic scalars — what-if pricing perturbs them without touching the cache.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.risk_app import RiskAppConfig
from repro.core.pipeline import PipelineExecutor, TenantTimeline
from repro.core.tenancy import TenancyConfig, TenantTask, VirtualDevicePool
from repro.core.transfer import StagingEngine, reorder_for_stragglers
from repro.kernels import ops as kops
from repro.risk.tables import RiskTables

# resident per-pdev table sets kept per engine (LRU on table identity)
_TABLE_CACHE_SLOTS = 4


@dataclasses.dataclass
class RunReport:
    ylt: np.ndarray
    wall_s: float
    per_tenant_s: Dict[int, float]
    staging_log: List[Dict[str, float]]
    timeline: Optional[List[TenantTimeline]] = None


def _loss_args(tables: RiskTables):
    return (jnp.asarray(tables.elt_losses), jnp.asarray(tables.occ_ret),
            jnp.asarray(tables.occ_lim), jnp.asarray(tables.agg_ret),
            jnp.asarray(tables.agg_lim))


class AggregateRiskAnalysis:
    def __init__(self, cfg: RiskAppConfig,
                 tenancy: Optional[TenancyConfig] = None,
                 devices: Optional[list] = None):
        self.cfg = cfg
        self.tenancy = tenancy or TenancyConfig(
            n_pdev=max(1, len(devices or jax.devices())),
            tenants_per_pdev=cfg.tenants_per_device,
            transfer_mode=cfg.transfer_mode)
        self.pool = VirtualDevicePool(self.tenancy,
                                      devices or jax.devices())
        self._step = jax.jit(self._trial_losses, static_argnames=("chunk",))
        self.trace_count = 0          # incremented at trace time only
        self.table_uploads = 0        # host->device ELT/term table stagings
        # key -> (host refs pinning the key's id()s, {pdev: device arrays})
        self._table_cache: "collections.OrderedDict[Tuple, Tuple]" = \
            collections.OrderedDict()

    # ------------------------------------------------------------------
    def _trial_losses(self, yet, elt, occ_ret, occ_lim, agg_ret, agg_lim,
                      chunk: int):
        self.trace_count += 1         # side effect runs only while tracing
        return kops.aggregate_loss(yet, elt, occ_ret, occ_lim, agg_ret,
                                   agg_lim, chunk=chunk)

    # ------------------------------------------------------------------
    # sampled elements per large array in the cache-staleness tripwire
    _FP_SAMPLES = 256

    @classmethod
    def _table_fingerprint(cls, host: Tuple[np.ndarray, ...]) -> Tuple:
        """Cheap content check guarding the id()-keyed cache against
        in-place mutation.  Small arrays (the per-ELT occurrence terms) are
        fingerprinted in full; the large ELT table by shape/dtype plus a
        strided ``_FP_SAMPLES``-element sample, staying O(1) in table size.
        This is a *tripwire*, not a guarantee: a sparse in-place edit of the
        big table can slip past the sample (see the cache contract in
        :meth:`_resident_tables`)."""
        out = []
        for a in host:
            flat = a.reshape(-1)
            if flat.size <= 4 * cls._FP_SAMPLES:
                out.append((a.shape, str(a.dtype), flat.tobytes()))
            else:
                step = max(1, flat.size // cls._FP_SAMPLES)
                out.append((a.shape, str(a.dtype),
                            flat[::step][:cls._FP_SAMPLES].tobytes()))
        return tuple(out)

    def _resident_tables(self, tables: RiskTables) -> Dict[int, Tuple]:
        """Per-pdev device copies of the un-splittable ELT + occurrence
        terms, cached across runs; LRU-capped at ``_TABLE_CACHE_SLOTS``
        table sets.

        Cache contract: tables handed to the engine are treated as
        **immutable** — derive what-if variants with ``dataclasses.replace``
        and fresh arrays (as ``examples/risk_realtime.py`` does) rather than
        mutating in place.  The cache is keyed by host-array identity (the
        entry pins the arrays, so ids cannot be recycled) and revalidated
        against :meth:`_table_fingerprint`: full content for the small term
        arrays, a strided sample of the big ELT.  Whole-table and term
        mutations therefore trigger a re-upload, but a sparse in-place edit
        of the ELT that misses every sampled element can still serve stale
        device copies — honour the contract."""
        host = (tables.elt_losses, tables.occ_ret, tables.occ_lim)
        key = tuple(id(a) for a in host)
        fp = self._table_fingerprint(host)
        if key in self._table_cache:
            if self._table_cache[key][2] == fp:
                self._table_cache.move_to_end(key)
                return self._table_cache[key][1]
            del self._table_cache[key]      # mutated in place: stale copy
        by_pdev: Dict[int, Tuple] = {}
        for p in range(self.tenancy.n_pdev):
            dev = (self.pool.devices[p]
                   if self.pool.devices is not None else None)
            by_pdev[p] = tuple(
                jax.device_put(a, dev) if dev is not None else jnp.asarray(a)
                for a in host)
            self.table_uploads += 1
        self._table_cache[key] = (host, by_pdev, fp)
        while len(self._table_cache) > _TABLE_CACHE_SLOTS:
            self._table_cache.popitem(last=False)
        return by_pdev

    def clear_table_cache(self) -> None:
        """Release every resident table set (host pins + per-pdev device
        copies).  Long-lived engines cycling through many table sets should
        call this when a working set retires — the LRU cap bounds entry
        count, not bytes, and at paper scale one entry pins ~120 MB per
        pdev."""
        self._table_cache.clear()

    # ------------------------------------------------------------------
    def run_single(self, tables: RiskTables) -> np.ndarray:
        """Whole-YET single-device run (Algorithm 1, N=1)."""
        args = _loss_args(tables)
        ylt = self._step(jnp.asarray(tables.yet), *args,
                         chunk=min(self.cfg.chunk_events,
                                   tables.yet.shape[1]))
        return np.asarray(ylt)

    # ------------------------------------------------------------------
    def run_tenant_chunked(self, tables: RiskTables,
                           straggler_hist: Optional[Dict[int, float]] = None,
                           overlapped: bool = True) -> RunReport:
        """Multi-tenant execution per the tenancy plan.

        ``overlapped=True`` (default) runs the event-driven pipeline —
        compute(k) dispatches as soon as chunk k lands, staging of chunk k+1
        overlaps it.  ``overlapped=False`` is the legacy blocking schedule
        (stage *all* tenants, then dispatch compute), kept only so the
        benchmark harness can measure what the overlap buys.
        """
        t_start = time.perf_counter()
        tasks = self.pool.plan(tables.num_trials, uniform=True)
        resident = self._resident_tables(tables)
        agg_ret = np.float32(tables.agg_ret)
        agg_lim = np.float32(tables.agg_lim)
        chunk = min(self.cfg.chunk_events, tables.yet.shape[1])

        def stage_fn(t: TenantTask):
            sl = tables.yet[t.start:t.stop]
            if t.pad:                 # neutral rows: pad event id 0 -> loss 0
                sl = np.concatenate(
                    [sl, np.zeros((t.pad, sl.shape[1]), sl.dtype)])
            return {"yet": sl}

        def compute_fn(t: TenantTask, arrays):
            elt, occ_ret, occ_lim = resident[t.pdev]
            return self._step(arrays["yet"], elt, occ_ret, occ_lim,
                              agg_ret, agg_lim, chunk=chunk)

        ylt = np.zeros(tables.num_trials, np.float32)
        if overlapped:
            ex = PipelineExecutor(self.pool)
            rep = ex.run(tasks, stage_fn, compute_fn, straggler_hist)
            for t in tasks:
                ylt[t.start:t.stop] = np.asarray(rep.results[t.vdev])[:t.size]
            return RunReport(ylt, time.perf_counter() - t_start,
                             rep.per_tenant_s(), ex.engine.log, rep.timeline)

        # legacy blocking path: stage everything, then compute
        order = reorder_for_stragglers(tasks, straggler_hist)
        engine = StagingEngine(self.pool)
        staged = engine.stage(order, stage_fn, block=True)
        per_tenant: Dict[int, float] = {}
        results = []
        for sc in staged:             # dispatch all (async) — pdevs serialise
            t0 = time.perf_counter()
            out = compute_fn(sc.task, sc.arrays)
            results.append((sc.task, out, t0))
        for task, out, t0 in results:
            out.block_until_ready()
            per_tenant[task.vdev] = time.perf_counter() - t0
            ylt[task.start:task.stop] = np.asarray(out)[:task.size]
        return RunReport(ylt, time.perf_counter() - t_start, per_tenant,
                         engine.log)

    # ------------------------------------------------------------------
    def make_sharded_step(self, mesh, chunk: Optional[int] = None):
        """pjit'd analysis step with trials sharded over every mesh axis
        (embarrassingly parallel leading axis -> all axes are data axes)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = tuple(mesh.axis_names)
        c = chunk or self.cfg.chunk_events

        def step(yet, elt, occ_ret, occ_lim, agg_ret, agg_lim):
            yet = jax.lax.with_sharding_constraint(
                yet, NamedSharding(mesh, P(axes,)))
            return kops.aggregate_loss(yet, elt, occ_ret, occ_lim,
                                       agg_ret, agg_lim, chunk=c)

        return jax.jit(step)

    def input_specs(self, num_trials: Optional[int] = None):
        """ShapeDtypeStructs for the dry-run (no allocation)."""
        cfg = self.cfg
        T = num_trials or cfg.num_trials
        K, M, cat = cfg.events_per_trial, cfg.num_elts, cfg.event_catalog
        f32, i32 = jnp.float32, jnp.int32
        return {
            "yet": jax.ShapeDtypeStruct((T, K), i32),
            "elt": jax.ShapeDtypeStruct((cat + 1, M), f32),
            "occ_ret": jax.ShapeDtypeStruct((M,), f32),
            "occ_lim": jax.ShapeDtypeStruct((M,), f32),
            "agg_ret": jax.ShapeDtypeStruct((), f32),
            "agg_lim": jax.ShapeDtypeStruct((), f32),
        }
