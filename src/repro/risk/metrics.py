"""Portfolio risk metrics from the Year Loss Table (paper §IV-A).

PML (Probable Maximum Loss) at a return period R over T trial-years is the
(1 - 1/R) quantile of the YLT; TVaR is the conditional mean beyond VaR.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp

DEFAULT_RETURN_PERIODS = (10, 50, 100, 250, 500, 1000)


def pml(ylt: jax.Array, return_periods: Sequence[int] = DEFAULT_RETURN_PERIODS,
        ) -> Dict[int, jax.Array]:
    qs = jnp.asarray([1.0 - 1.0 / r for r in return_periods])
    vals = jnp.quantile(ylt.astype(jnp.float32), qs)
    return {r: vals[i] for i, r in enumerate(return_periods)}


def var(ylt: jax.Array, alpha: float = 0.99) -> jax.Array:
    return jnp.quantile(ylt.astype(jnp.float32), alpha)


def tvar(ylt: jax.Array, alpha: float = 0.99) -> jax.Array:
    """Tail value-at-risk: E[loss | loss >= VaR_alpha]."""
    y = ylt.astype(jnp.float32)
    v = jnp.quantile(y, alpha)
    w = (y >= v).astype(jnp.float32)
    return jnp.sum(y * w) / jnp.clip(jnp.sum(w), 1.0)


def expected_loss(ylt: jax.Array) -> jax.Array:
    return jnp.mean(ylt.astype(jnp.float32))


def summary(ylt: jax.Array) -> Dict[str, jax.Array]:
    out = {"mean": expected_loss(ylt), "var99": var(ylt), "tvar99": tvar(ylt)}
    for r, v in pml(ylt).items():
        out[f"pml{r}"] = v
    return out
