"""Optimizers in pure JAX (no optax offline).

Both optimizers keep their state sharded exactly like the parameters (the
state tree reuses the param logical axes), which gives ZeRO-style
optimizer-state sharding for free under FSDP param sharding.

* AdamW — fp32 moments.
* Adafactor — factored second moment over the last two dims (+ optional bf16
  momentum); the choice for the 100B+ archs where full Adam moments would not
  fit HBM (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, opt_state, params, lr) -> (new_params, new_opt_state)
    state_axes: Callable[[Any, Any], Any] = None
    # state_axes(param_axes_tree, param_shape_tree) -> logical axes for state


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** cf)
            vh = v / (1 - b2 ** cf)
            step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": c}

    def state_axes(p_axes, p_shapes):
        del p_shapes
        return {"m": p_axes, "v": p_axes, "count": ()}

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, optional bf16 momentum)
# ---------------------------------------------------------------------------
def adafactor(decay_pow: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, min_dim_factored: int = 128,
              momentum: Optional[float] = 0.9,
              weight_decay: float = 0.0) -> Optimizer:
    def factored(p) -> bool:
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored)

    def init(params):
        def state_for(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        st = {"v": jax.tree.map(state_for, params),
              "count": jnp.zeros((), jnp.int32)}
        if momentum is not None:
            st["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                                   params)
        return st

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta2 = 1.0 - c.astype(jnp.float32) ** (-decay_pow)

        def upd(g, v, p, m):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
                # rank-1 reconstruction of the second moment
                denom = vr[..., :, None] * vc[..., None, :]
                denom = denom / jnp.clip(
                    vr.mean(axis=-1)[..., None, None], 1e-30)
                u = g * jax.lax.rsqrt(jnp.clip(denom, 1e-30))
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.clip(vv, 1e-30))
                new_v = {"v": vv}
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if m is not None:
                mu = momentum * m.astype(jnp.float32) + (1 - momentum) * u
                u = mu
                new_m = mu.astype(jnp.bfloat16)
            else:
                new_m = None
            pf = p.astype(jnp.float32)
            step = u + weight_decay * pf
            return (pf - lr * step).astype(p.dtype), new_v, new_m

        ms = state.get("m")
        if ms is None:
            ms = jax.tree.map(lambda p: None, params)
        flat_p, td = jax.tree.flatten(params)
        flat_g = td.flatten_up_to(grads)
        flat_v = td.flatten_up_to(state["v"])
        flat_m = td.flatten_up_to(ms) if state.get("m") is not None else [None] * len(flat_p)
        res = [upd(g, v, p, m) for g, v, p, m in zip(flat_g, flat_v, flat_p, flat_m)]
        new_p = td.unflatten([r[0] for r in res])
        new_v = td.unflatten([r[1] for r in res])
        out = {"v": new_v, "count": c}
        if state.get("m") is not None:
            out["m"] = td.unflatten([r[2] for r in res])
        return new_p, out

    def state_axes(p_axes, p_shapes):
        def v_axes(axes, shp):
            shape = shp.shape if hasattr(shp, "shape") else shp
            if (len(shape) >= 2 and shape[-1] >= min_dim_factored
                    and shape[-2] >= min_dim_factored):
                return {"vr": tuple(axes[:-1]),
                        "vc": tuple(axes[:-2]) + tuple(axes[-1:])}
            return {"v": tuple(axes)}

        st = {"v": jax.tree.map(v_axes, p_axes, p_shapes, is_leaf=_is_axes),
              "count": ()}
        if momentum is not None:
            st["m"] = p_axes
        return st

    return Optimizer(init, update, state_axes)


def make_optimizer(cfg: ArchConfig) -> Optimizer:
    if cfg.optimizer == "adafactor":
        return adafactor(weight_decay=0.0)
    return adamw(weight_decay=cfg.weight_decay)


def lr_schedule(cfg: ArchConfig, warmup: int = 100, total: int = 10000):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = cfg.learning_rate * jnp.minimum(1.0, s / warmup)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * (0.1 + 0.9 * cos)
    return lr
