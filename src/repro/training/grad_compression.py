"""Gradient compression for the cross-pod (DCN) axis.

At 2+ pods the data-parallel gradient all-reduce crosses the slow inter-pod
links.  We compress only that hop: int8 blockwise quantisation with error
feedback (residual carried to the next step), reduced in int32.  ICI-axis
reductions stay full precision.

Two entry points:
  * quantize/dequantize + error feedback — pure functions, unit-testable.
  * compressed_psum — shard_map-ready collective: q -> psum(int32) -> deq.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8: returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32,
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(g: jax.Array, residual: jax.Array,
                           block: int = 256):
    """Error-feedback compression: quantise (g + residual), carry the error.

    Returns (q, scale, new_residual)."""
    target = g.astype(jnp.float32) + residual.astype(jnp.float32)
    q, scale = quantize_int8(target, block)
    deq = dequantize_int8(q, scale, g.shape)
    return q, scale, (target - deq)


def init_residuals(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_grad_tree(grads: Any, residuals: Any, block: int = 256):
    """Tree-wise error-feedback compression round-trip (the numerics of a
    compressed all-reduce without the collective; used where GSPMD owns the
    reduction).  Returns (decompressed_grads, new_residuals)."""
    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = compress_with_feedback(g, r, block)
        out_g.append(dequantize_int8(q, s, g.shape, g.dtype))
        out_r.append(nr)
    return td.unflatten(out_g), td.unflatten(out_r)


def compressed_psum(x: jax.Array, axis_name: str, block: int = 256,
                    ) -> jax.Array:
    """int8-compressed psum for use inside shard_map over the pod axis:
    quantise locally, reduce the int8 payload in int32, dequantise with the
    mean scale.  Bandwidth on the wire: 1 byte/elem + 4/block for scales."""
    q, scale = quantize_int8(x, block)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    ssum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(1, axis_name)
    # each shard contributed q_i * scale_i; approximate with mean scale
    deq = (qsum.astype(jnp.float32) * (ssum / n)[:, None]).reshape(-1)
    size = 1
    for d in x.shape:
        size *= d
    return deq[:size].reshape(x.shape).astype(x.dtype)
