"""Train-step builder with tenant-microbatch accumulation.

The paper's multi-tenancy maps to training as the microbatch loop: the global
batch is split into ``cfg.microbatches`` tenant chunks processed sequentially
per device, so each tenant's host->device staging can overlap the previous
tenant's compute (the data pipeline side of that overlap lives in
:mod:`repro.core.transfer`).  The loop also bounds activation memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import Sharder
from repro.models.model import ModelBundle
from repro.training.optimizer import Optimizer, lr_schedule, make_optimizer


def init_train_state(bundle: ModelBundle, opt: Optimizer, params) -> Dict[str, Any]:
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def build_train_step(bundle: ModelBundle, sh: Sharder,
                     opt: Optional[Optimizer] = None,
                     lr_fn: Optional[Callable] = None,
                     donate: bool = True) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics) (un-jitted)."""
    cfg = bundle.cfg
    opt = opt or make_optimizer(cfg)
    lr_fn = lr_fn or lr_schedule(cfg)
    n_mb = max(1, cfg.microbatches)

    def loss_of(params, batch):
        return bundle.loss_fn(params, batch, sh)

    def train_step(state, batch):
        params = state["params"]

        if n_mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def split_mb(x):
                b = x.shape[0]
                assert b % n_mb == 0, (b, n_mb)
                return x.reshape((n_mb, b // n_mb) + x.shape[1:])

            mbs = jax.tree.map(split_mb, batch)

            def accum(carry, mb):
                g_acc, l_acc, a_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss, a_acc + metrics["aux"]), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                accum, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            loss = loss_sum / n_mb
            metrics = {"xent": loss - aux_sum / n_mb, "aux": aux_sum / n_mb}

        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        # global-norm clip at 1.0
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


def build_eval_step(bundle: ModelBundle, sh: Sharder) -> Callable:
    def eval_step(params, batch):
        loss, metrics = bundle.loss_fn(params, batch, sh)
        return dict(metrics, loss=loss)
    return eval_step
