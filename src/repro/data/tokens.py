"""Deterministic synthetic token pipeline with staged prefetch.

Offline container => no real corpus; the pipeline synthesises a stationary
Zipf-ish token stream deterministically from (seed, step) so loss curves are
reproducible and restart-consistent (resume at step k regenerates exactly the
batch k).  The host->device staging goes through core.transfer so the
sequential/concurrent tenant modes and prefetch-overlap apply to LM training
exactly as to the risk app.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0


def synth_batch(dc: DataConfig, step: int, cfg: Optional[ArchConfig] = None,
                ) -> Dict[str, np.ndarray]:
    """Batch for one step, deterministic in (seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([dc.seed, step]))
    # Zipf-ish marginal with local repetition structure (so loss can fall)
    base = rng.zipf(1.3, size=(dc.global_batch, dc.seq_len + 1))
    toks = (base % (dc.vocab_size - 2)) + 1
    # inject copy structure: second half repeats first half for 25% of rows
    rep = rng.random(dc.global_batch) < 0.25
    half = (dc.seq_len + 1) // 2
    toks[rep, half:2 * half] = toks[rep, :half]
    toks = toks.astype(np.int32)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg is not None and cfg.num_patches:
        out["patch_embeds"] = rng.standard_normal(
            (dc.global_batch, cfg.num_patches, 1024)).astype(np.float32)
    if cfg is not None and cfg.enc_dec:
        out["frames"] = rng.standard_normal(
            (dc.global_batch, cfg.encoder_seq_len, cfg.d_model)
        ).astype(np.float32)
    return out


class PrefetchFeed:
    """Background producer staging batch k+1 while step k computes — the
    training-side realisation of the paper's sequential-transfer overlap."""

    def __init__(self, dc: DataConfig, cfg: Optional[ArchConfig] = None,
                 sharding: Optional[Any] = None, depth: int = 2,
                 start_step: int = 0):
        self.dc, self.cfg, self.sharding = dc, cfg, sharding
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _stage(self, host: Dict[str, np.ndarray]):
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in host.items()}

    def _producer(self):
        while not self._stop.is_set():
            batch = self._stage(synth_batch(self.dc, self._step, self.cfg))
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
