"""Fused Pallas paged-attention kernels: page-table-aware decode + scatter.

The serving hot path (PRs 3-4) reads the paged KV-cache with a jnp gather
that materialises every sequence's full logical window as a dense
``[C, bucket, Hkv, D]`` tensor per decode step — O(bucket) HBM traffic per
emitted token, regardless of how many tokens are actually live.  That is
exactly the "redundant data movement" tax the paper's sequential-transfer
mode eliminates for the risk pipeline; these kernels eliminate it for
serving by reading pages *in place* through the page table:

* :func:`paged_attention_decode_pallas` — vLLM-style fused decode read.
  Grid ``(C, NB)`` with the page axis innermost/sequential; the page table
  and per-row positions ride a :class:`pltpu.PrefetchScalarGridSpec` so each
  K/V/position BlockSpec maps grid cell ``(c, j)`` straight to physical page
  ``page_table[c, j]`` of the pool — the indirection happens in the index
  map, before the block's HBM->VMEM DMA issues, so only the pages a row
  actually references are ever touched and no dense per-sequence KV exists
  at any point.  Online softmax (flash-style running ``m``/``l``/``acc`` in
  VMEM scratch) accumulates across pages; SENTINEL/TRASH pages are masked
  by construction because their position plane holds ``POS_SENTINEL``,
  which fails the ``kpos <= pos`` validity test in-kernel.
* :func:`paged_prefill_scatter_pallas` — admission-time scatter-write.
  Grid ``(n_stages, nb)``; the destination BlockSpec maps block ``j`` to
  physical page ``pages[j]`` and the pool is aliased input->output
  (``input_output_aliases``), so freshly prefilled KV lands directly in its
  allocated pages (cast to the pool dtype in-kernel) without the separate
  materialise-then-``at[].set`` hop, and untouched pages are never copied.

Numerics: the decode kernel mirrors :func:`repro.kernels.ref.
paged_attention_decode_ref` — same f32 score accumulation, the same
``-1e30`` mask bias added to the scores, the same bf16->f32 cache casts —
but the softmax is the online reassociation, so outputs agree to float32
rounding (~1e-6 relative), not bitwise; greedy decode is token-exact in
practice and ``tests/test_paged_attention.py`` locks both levels in.  The
scatter kernel performs no arithmetic beyond the storage cast and is
bit-exact with the jnp path.

On CPU these run in interpret mode (``interpret=True``), where wall time is
an emulation artefact — the structural win (bytes moved per round) is what
``benchmarks/pipeline.py:bench_paged_attention`` tracks there; on a real
TPU the index-mapped DMAs are the point.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# decode: stream pages through the page table, online softmax across pages
# ---------------------------------------------------------------------------
def _decode_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float,
                   window: Optional[int], n_blocks: int, hkv: int, rep: int):
    j = pl.program_id(1)
    c = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[c]
    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(hkv, rep, d)   # (Hkv, rep, D)
    k = k_ref[0].astype(jnp.float32)                        # (P, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    kpos = kpos_ref[0]                                      # (P,)

    s = jnp.einsum("krd,pkd->krp", q, k,
                   preferred_element_type=jnp.float32) * scale
    # same mask construction as the gather path: a -1e30 *bias* added to the
    # scores (absorbed exactly in f32), validity from the page's position
    # plane — SENTINEL/TRASH pages carry POS_SENTINEL and always fail
    valid = kpos <= pos
    if window is not None:
        valid &= kpos > pos - window
    s = s + jnp.where(valid, 0.0, NEG_INF)[None, None, :]

    m_prev, l_prev, acc = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * alpha + p.sum(axis=-1)
    acc_scr[...] = acc * alpha[..., None] + jnp.einsum(
        "krp,pkd->krd", p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        # all-masked rows degenerate to a uniform average (l == L), exactly
        # like full softmax over an all-(-1e30) row; l == 0 cannot happen
        # but is guarded like the flash kernel
        l_safe = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0] = (acc_scr[...] / l_safe[..., None]).reshape(
            hkv * rep, d).astype(o_ref.dtype)


def paged_attention_decode_pallas(q, k_pool, v_pool, pos_pool, page_table,
                                  positions, *, window: Optional[int] = None,
                                  interpret: bool = True):
    """Fused single-token GQA decode read against a paged KV pool.

    q: (C, H, D) compute dtype (already roped, this step's K/V already
    scattered into the pool); k_pool/v_pool: (NP, P, Hkv, D) storage dtype;
    pos_pool: (NP, P) int32 absolute positions (POS_SENTINEL marks
    invalid); page_table: (C, NB) int32; positions: (C,) int32 absolute
    position of each row's new token.  Returns (C, H, D) float32.

    Each row streams only the NB pages its table names; table padding
    points at the SENTINEL page whose positions mask it out, so ragged
    rings need no per-row block count.
    """
    C, H, D = q.shape
    _, P, Hkv, _ = k_pool.shape
    NB = page_table.shape[1]
    rep = H // Hkv
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(D), window=window,
        n_blocks=NB, hkv=Hkv, rep=rep)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,           # page_table, positions
        grid=(C, NB),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda c, j, pt, ps: (c, 0, 0)),
            pl.BlockSpec((1, P, Hkv, D),
                         lambda c, j, pt, ps: (pt[c, j], 0, 0, 0)),
            pl.BlockSpec((1, P, Hkv, D),
                         lambda c, j, pt, ps: (pt[c, j], 0, 0, 0)),
            pl.BlockSpec((1, P), lambda c, j, pt, ps: (pt[c, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda c, j, pt, ps: (c, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, rep), jnp.float32),
            pltpu.VMEM((Hkv, rep), jnp.float32),
            pltpu.VMEM((Hkv, rep, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((C, H, D), jnp.float32),
        interpret=interpret,
    )(page_table, positions, q, k_pool, v_pool, pos_pool)


# ---------------------------------------------------------------------------
# prefill: scatter freshly computed KV straight into allocated pages
# ---------------------------------------------------------------------------
def _scatter_kernel(pages_ref, kv_ref, pool_in_ref, pool_out_ref):
    del pages_ref, pool_in_ref           # routing happens in the index maps
    pool_out_ref[...] = kv_ref[...].astype(pool_out_ref.dtype)


def paged_prefill_scatter_pallas(pool, pages, values, *,
                                 interpret: bool = True):
    """Write prefill KV blocks into their allocated physical pages.

    pool: (S, NP, P, Hkv, D) storage dtype; pages: (nb,) int32 distinct
    non-reserved page ids; values: (S, nb, P, Hkv, D) compute dtype.
    Returns the pool with ``pool[:, pages[j]] = values[:, j]`` (cast to the
    pool dtype); every other page is bit-untouched.  The pool is aliased
    input->output, so under jit (with the state donated, as the admission
    jit does) the write happens in place — page-block-granular stores, no
    pool copy and no dense scatter intermediate.
    """
    S, _, P, Hkv, D = pool.shape
    nb = pages.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,           # pages
        grid=(S, nb),
        in_specs=[
            pl.BlockSpec((1, 1, P, Hkv, D),
                         lambda i, j, pr: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, P, Hkv, D),
                         lambda i, j, pr: (i, pr[j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, P, Hkv, D),
                               lambda i, j, pr: (i, pr[j], 0, 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},     # pool (after the scalar operand)
        interpret=interpret,
    )(pages, values, pool)


# ---------------------------------------------------------------------------
# mesh dispatch: per-shard kernel invocations along the KV-head axis
# ---------------------------------------------------------------------------
# pallas_call has no GSPMD partitioning rules, so under a mesh the kernels run
# inside shard_map.  Three shapes cover every arch in configs/:
#   * GQA/MHA with Hkv % model-extent == 0 — pools and q both head-sharded;
#     contiguous query-head blocks (H/n = rep·Hkv/n) land exactly on their
#     kv-head group, so each shard is a self-contained decode and the merge
#     is the out-spec all-gather.  No psum: bitwise with the unsharded call.
#   * MQA (Hkv == 1) — pools replicated, q sharded on H; same all-gather.
#   * otherwise — fully replicated specs (every device runs the whole grid).

def _model_axis(sh) -> int:
    """Extent of the "model" mesh axis under ``sh``, 1 when off-mesh."""
    if sh is None or sh.mesh is None or "model" not in sh.mesh.axis_names:
        return 1
    return sh.mesh.shape["model"]


def paged_attention_decode_sharded(q, k_pool, v_pool, pos_pool, page_table,
                                   positions, sh, *,
                                   window: Optional[int] = None,
                                   interpret: bool = True):
    """:func:`paged_attention_decode_pallas` partitioned along KV heads."""
    if _model_axis(sh) == 1:
        return paged_attention_decode_pallas(
            q, k_pool, v_pool, pos_pool, page_table, positions,
            window=window, interpret=interpret)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    H, Hkv = q.shape[1], k_pool.shape[2]
    if sh.extent("kv", Hkv) > 1:
        q_spec, pool_spec = P(None, "model", None), P(None, None, "model", None)
        out_spec = P(None, "model", None)
    elif Hkv == 1 and sh.extent("heads", H) > 1:
        q_spec, pool_spec = P(None, "model", None), P()
        out_spec = P(None, "model", None)
    else:
        q_spec = pool_spec = out_spec = P()
    fn = shard_map(
        functools.partial(paged_attention_decode_pallas,
                          window=window, interpret=interpret),
        mesh=sh.mesh,
        in_specs=(q_spec, pool_spec, pool_spec, P(), P(), P()),
        out_specs=out_spec, check_rep=False)
    return fn(q, k_pool, v_pool, pos_pool, page_table, positions)


def paged_prefill_scatter_sharded(pool, pages, values, sh, *,
                                  interpret: bool = True):
    """:func:`paged_prefill_scatter_pallas` partitioned along KV heads."""
    if _model_axis(sh) == 1:
        return paged_prefill_scatter_pallas(pool, pages, values,
                                            interpret=interpret)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    Hkv = pool.shape[3]
    if sh.extent("kv", Hkv) > 1:
        kv_spec = P(None, None, None, "model", None)
    else:
        kv_spec = P()
    fn = shard_map(
        functools.partial(paged_prefill_scatter_pallas, interpret=interpret),
        mesh=sh.mesh,
        in_specs=(kv_spec, P(), kv_spec),
        out_specs=kv_spec, check_rep=False)
    return fn(pool, pages, values)
