"""Pure-jnp oracles for every kernel in this package.

These are the single source of truth for numerics: the Pallas kernels must
match them (tests sweep shapes/dtypes with assert_allclose), and the model
stack calls them through :mod:`repro.kernels.ops` when the Pallas path is off
(CPU) or unavailable.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality) — chunked form and step oracle
# ---------------------------------------------------------------------------
def ssd_chunked_ref(x, dt, a_log_decay, B, C, chunk: int,
                    initial_state: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba-2, arXiv:2405.21060 §6).

    x : (b, L, H, P)   per-head inputs
    dt: (b, L, H)      positive step sizes (already softplus'ed)
    a_log_decay: (b, L, H)  log a_t = A * dt_t (A negative)
    B : (b, L, H, N)   input projections (already head-expanded)
    C : (b, L, H, N)   output projections
    Returns (y: (b,L,H,P), final_state: (b,H,P,N)).

    Recurrence: h_t = exp(a_t) h_{t-1} + dt_t * (B_t ⊗ x_t);  y_t = C_t · h_t.
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    f32 = jnp.float32

    xc = x.astype(f32).reshape(b, nc, chunk, H, P)
    dtc = dt.astype(f32).reshape(b, nc, chunk, H)
    ac = a_log_decay.astype(f32).reshape(b, nc, chunk, H)
    Bc = B.astype(f32).reshape(b, nc, chunk, H, N)
    Cc = C.astype(f32).reshape(b, nc, chunk, H, N)

    a_cum = jnp.cumsum(ac, axis=2)                      # inclusive (b,nc,Q,H)
    a_tot = a_cum[:, :, -1]                             # (b,nc,H)

    # ---- intra-chunk (quadratic within the chunk) ---------------------------
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]   # (b,nc,l,s,H)
    li = jnp.arange(chunk)
    causal = (li[:, None] >= li[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(seg), 0.0)
    CB = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc)
    M = CB * Lmat * dtc[:, :, None, :, :]               # dt_s enters at source
    y_diag = jnp.einsum("bclsh,bcshp->bclhp", M, xc)

    # ---- per-chunk end states ----------------------------------------------
    decay_states = jnp.exp(a_tot[:, :, None] - a_cum)   # (b,nc,Q,H)
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn",
                        Bc, decay_states * dtc, xc)     # (b,nc,H,P,N)

    # ---- inter-chunk recurrence over chunk index ----------------------------
    h0 = (jnp.zeros((b, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(h, inp):
        a_tot_c, s_c = inp                              # (b,H), (b,H,P,N)
        h_new = jnp.exp(a_tot_c)[:, :, None, None] * h + s_c
        return h_new, h                                 # emit state BEFORE chunk

    a_tot_sw = jnp.moveaxis(a_tot, 1, 0)                # (nc,b,H)
    states_sw = jnp.moveaxis(states, 1, 0)              # (nc,b,H,P,N)
    h_final, h_before = lax.scan(step, h0, (a_tot_sw, states_sw))
    h_before = jnp.moveaxis(h_before, 0, 1)             # (b,nc,H,P,N)

    # ---- inter-chunk output contribution ------------------------------------
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cc, h_before, jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(b, L, H, P)
    return y.astype(x.dtype), h_final


def ssd_recurrent_ref(x, dt, a_log_decay, B, C,
                      initial_state: Optional[jax.Array] = None,
                      ) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step recurrence oracle (same contract as ssd_chunked_ref)."""
    b, L, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    h0 = (jnp.zeros((b, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(h, inp):
        x_t, dt_t, a_t, B_t, C_t = inp
        h = jnp.exp(a_t)[..., None, None] * h + \
            dt_t[..., None, None] * (x_t[..., :, None] * B_t[..., None, :])
        y_t = jnp.einsum("bhn,bhpn->bhp", C_t, h)
        return h, y_t

    xs = tuple(jnp.moveaxis(t.astype(f32), 1, 0) for t in (x, dt, a_log_decay, B, C))
    h_final, ys = lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    return y.astype(x.dtype), h_final


def ssd_decode_step_ref(state, x_t, dt_t, a_t, B_t, C_t):
    """One decode step.  state: (b,H,P,N); x_t: (b,H,P); dt/a: (b,H);
    B_t/C_t: (b,H,N).  Returns (y_t, new_state)."""
    f32 = jnp.float32
    state = state.astype(f32)
    h = jnp.exp(a_t.astype(f32))[..., None, None] * state + \
        dt_t.astype(f32)[..., None, None] * (
            x_t.astype(f32)[..., :, None] * B_t.astype(f32)[..., None, :])
    y = jnp.einsum("bhn,bhpn->bhp", C_t.astype(f32), h)
    return y.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# Paged attention (serving decode hot path) — gather/scatter oracles
# ---------------------------------------------------------------------------
def paged_attention_decode_ref(q, k_pool, v_pool, page_table, positions, *,
                               kpos: Optional[jax.Array] = None,
                               pos_pool: Optional[jax.Array] = None,
                               window: Optional[int] = None) -> jax.Array:
    """Dense-gather paged decode read: the numerics source of truth for
    :func:`repro.kernels.paged_attention.paged_attention_decode_pallas` and
    the ``backend="jnp"`` serving path (which calls this directly).

    q: (C, H, D) compute dtype, already roped; k_pool/v_pool:
    (NP, P, Hkv, D) storage dtype; page_table: (C, NB) int32; positions:
    (C,) int32.  Validity comes from ``kpos`` (C, NB*P) — pass it
    pre-gathered (the serving decode step shares one gather across
    sublayers) or let it be gathered here from ``pos_pool`` (NP, P).
    Returns (C, H, D) float32.

    This is operation-for-operation the dense ring-cache decode math of
    :func:`repro.models.layers.apply_attention_decode` (same einsum
    equations, -1e30 mask bias, bf16->f32 cache casts, full-row softmax)
    applied to the page-table-gathered logical view — it materialises the
    dense [C, NB*P, Hkv, D] KV the fused kernel exists to avoid.
    """
    C, H, D = q.shape
    Hkv = k_pool.shape[2]

    def gather(pool):
        g = pool[page_table]                       # (C, NB, P, ...)
        return g.reshape((g.shape[0], g.shape[1] * g.shape[2])
                         + g.shape[3:])

    k = gather(k_pool)                             # (C, L, Hkv, D)
    v = gather(v_pool)
    if kpos is None:
        kpos = gather(pos_pool[..., None])[..., 0]
    valid = kpos <= positions[:, None]
    if window is not None:
        valid &= kpos > positions[:, None] - window
    bias_pos = jnp.where(valid, 0.0, -1e30)        # (C, L)
    rep = H // Hkv
    qr = q.reshape(C, 1, Hkv, rep, D)
    scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bqhrd,bkhd->bqhrk", qr, k.astype(qr.dtype),
                   preferred_element_type=jnp.float32) * scale
    s = s + bias_pos[:, None, None, None, :]
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhrk,bkhd->bqhrd", pattn, v.astype(qr.dtype),
                   preferred_element_type=jnp.float32)
    return o.reshape(C, H, D)


def paged_scatter_ref(pool, pages, values) -> jax.Array:
    """Scatter oracle for the prefill fused-write kernel: write ``values``
    (S, nb, P, ...) into ``pool`` (S, NP, P, ...) at page ids ``pages``
    (nb,), cast to the pool dtype.  Bit-exact contract: the Pallas kernel
    performs the same cast and the same page-granular stores."""
    return pool.at[:, pages].set(values.astype(pool.dtype))


# ---------------------------------------------------------------------------
# Aggregate Risk Analysis (paper Algorithm 3) — trial-loss oracle
# ---------------------------------------------------------------------------
def aggregate_loss_ref(event_ids, elt_losses, occ_ret, occ_lim, agg_ret, agg_lim):
    """Year-loss for each trial (paper Algorithm 3), pure jnp.

    event_ids : (T, K) int32   — per-trial event sequence (0 = no event pad)
    elt_losses: (E_cat, M) f32 — direct-access loss tables for M ELTs
                                 (row 0 must be zero: the pad event)
    occ_ret/occ_lim : (M,) f32 — per-ELT occurrence terms (financial terms I)
    agg_ret/agg_lim : ()  f32  — layer aggregate terms T
    Returns yl: (T,) f32 — the Year Loss Table.

    Occurrence terms clip each event-occurrence loss per ELT; event losses sum
    across ELTs, accumulate over the trial, then aggregate terms apply:
        l = min(max(l - ret, 0), lim)
    """
    f32 = jnp.float32
    gathered = elt_losses.astype(f32)[event_ids]          # (T, K, M)
    occ = jnp.clip(gathered - occ_ret[None, None, :], 0.0, None)
    occ = jnp.minimum(occ, occ_lim[None, None, :])
    per_event = occ.sum(axis=-1)                          # (T, K)
    agg = per_event.sum(axis=-1)                          # (T,)
    yl = jnp.minimum(jnp.clip(agg - agg_ret, 0.0, None), agg_lim)
    return yl


def aggregate_loss_chunked_ref(event_ids, elt_losses, occ_ret, occ_lim,
                               agg_ret, agg_lim, chunk: int):
    """Chunked variant (paper §IV-B "chunking"): identical numerics, processes
    the event axis in fixed-size chunks — the structure the Pallas kernel
    mirrors (one chunk per VMEM tile)."""
    T, K = event_ids.shape
    assert K % chunk == 0, (K, chunk)
    nck = K // chunk

    def body(acc, i):
        ids = lax.dynamic_slice_in_dim(event_ids, i * chunk, chunk, 1)
        g = elt_losses.astype(jnp.float32)[ids]           # (T, chunk, M)
        occ = jnp.clip(g - occ_ret[None, None, :], 0.0, None)
        occ = jnp.minimum(occ, occ_lim[None, None, :])
        return acc + occ.sum(axis=(1, 2)), None

    acc, _ = lax.scan(body, jnp.zeros((T,), jnp.float32), jnp.arange(nck))
    return jnp.minimum(jnp.clip(acc - agg_ret, 0.0, None), agg_lim)
