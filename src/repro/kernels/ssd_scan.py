"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060 §6).

Layout: inputs are flattened to a (B*H, L, ...) head-major layout outside the
kernel; grid = (B*H, L/Q) with the chunk axis innermost and sequential.  The
recurrent state (P x N) lives in a VMEM scratch buffer that persists across
chunk steps of the same head (TPU grid steps run sequentially on a core), so
the inter-chunk recurrence needs no extra HBM round-trips, and Pallas
pipelines the next chunk's HBM->VMEM fetch against the current chunk's
compute — the same DMA/compute overlap the paper exploits via multi-tenancy.

Per chunk (all fp32 in VMEM):
  intra:  y_d  = ((C B^T) ⊙ L(a)) (dt ⊙ x)        (Q x Q quadratic part)
  carry:  y   += (C h_prev) ⊙ exp(a_cum)
  state:  h    = exp(a_tot) h_prev + B^T ((dt exp(a_tot - a_cum)) ⊙ x)

Validated in interpret mode against kernels.ref.ssd_chunked_ref.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            state, *, n_chunks: int, has_h0: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        if has_h0:
            state[...] = h0_ref[0].astype(jnp.float32)
        else:
            state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)          # (Q,)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    a_cum = jnp.cumsum(a)                     # inclusive (Q,)
    a_tot = a_cum[-1]

    # intra-chunk: Lmat[l,s] = exp(a_cum[l] - a_cum[s]) for l >= s
    seg = a_cum[:, None] - a_cum[None, :]
    li = jax.lax.iota(jnp.int32, Q)
    causal = li[:, None] >= li[None, :]
    lmat = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (Q, Q)
    m = cb * lmat * dt[None, :]
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)      # (Q, P)

    # contribution of the carried state
    h = state[...]                                             # (P, N)
    y += jnp.exp(a_cum)[:, None] * jnp.dot(
        C, h.T, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    decay = (dt * jnp.exp(a_tot - a_cum))[:, None] * B          # (Q, N)
    state[...] = jnp.exp(a_tot) * h + jnp.dot(
        x.T, decay, preferred_element_type=jnp.float32)        # (P, N)

    @pl.when(j == n_chunks - 1)
    def _out():
        hout_ref[0] = state[...]


def ssd_chunked_pallas(x, dt, a_log_decay, B, C, *, chunk: int,
                       initial_state: Optional[jax.Array] = None,
                       interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Same contract as kernels.ref.ssd_chunked_ref.

    x: (b, L, H, P); dt/a: (b, L, H); B/C: (b, L, H, N).
    Returns (y: (b, L, H, P), final_state: (b, H, P, N)).
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    BH = b * H

    # head-major flatten: (BH, L, ...)
    xm = jnp.moveaxis(x, 2, 1).reshape(BH, L, P)
    dtm = jnp.moveaxis(dt, 2, 1).reshape(BH, L)
    am = jnp.moveaxis(a_log_decay, 2, 1).reshape(BH, L)
    Bm = jnp.moveaxis(B, 2, 1).reshape(BH, L, N)
    Cm = jnp.moveaxis(C, 2, 1).reshape(BH, L, N)
    has_h0 = initial_state is not None
    h0 = (initial_state.reshape(BH, P, N).astype(jnp.float32)
          if has_h0 else jnp.zeros((BH, P, N), jnp.float32))

    kernel = functools.partial(_kernel, n_chunks=nc, has_h0=has_h0)
    y, hout = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, P, N), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, P, N), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, L, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xm, dtm, am, Bm, Cm, h0)
    y = jnp.moveaxis(y.reshape(b, H, L, P), 1, 2)
    return y, hout.reshape(b, H, P, N)
