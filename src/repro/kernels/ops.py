"""jit'd dispatch wrappers for the kernel package.

``use_pallas(True)`` (or REPRO_USE_PALLAS=1) routes to the Pallas TPU kernels
(executed in interpret mode on CPU); otherwise the pure-jnp references run.
The model/risk stacks only ever import from here.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


@functools.lru_cache(maxsize=None)
def _ssd_pallas_vjp(chunk: int, interpret: bool):
    """Differentiable wrapper: Pallas forward, reference-VJP backward (the
    backward rematerialises through the jnp oracle — correct by construction;
    a dedicated backward kernel is a recorded perf-iteration TODO)."""
    from repro.kernels import ssd_scan

    @jax.custom_vjp
    def f(x, dt, a, B, C, h0):
        return ssd_scan.ssd_chunked_pallas(x, dt, a, B, C, chunk=chunk,
                                           initial_state=h0,
                                           interpret=interpret)

    def fwd(x, dt, a, B, C, h0):
        return f(x, dt, a, B, C, h0), (x, dt, a, B, C, h0)

    def bwd(res, cts):
        x, dt, a, B, C, h0 = res
        _, vjp = jax.vjp(
            lambda *args: _ref.ssd_chunked_ref(*args[:5], chunk,
                                               initial_state=args[5]),
            x, dt, a, B, C, h0)
        return vjp(cts)

    f.defvjp(fwd, bwd)
    return f

# single source of truth for aggregate_loss lookup strategies; the kernel
# table in kernels/aggregate_loss.py is checked against it at import
AGG_VARIANTS = ("gather", "onehot")


def _env_agg_variant() -> str:
    """Fail fast (at import) on a misconfigured REPRO_AGG_VARIANT instead of
    deferring to an error — or, under ``python -O``, a silent fallback —
    deep inside the Pallas dispatch."""
    v = os.environ.get("REPRO_AGG_VARIANT", "gather")
    if v not in AGG_VARIANTS:
        raise ValueError(
            f"REPRO_AGG_VARIANT={v!r}: must be one of {AGG_VARIANTS}")
    return v


_STATE = {"pallas": os.environ.get("REPRO_USE_PALLAS", "0") == "1",
          "interpret": True,
          "agg_variant": _env_agg_variant()}


def use_pallas(on: bool, interpret: bool = True) -> None:
    _STATE["pallas"] = on
    _STATE["interpret"] = interpret


def pallas_enabled() -> bool:
    return _STATE["pallas"]


def use_aggregate_variant(name: str) -> None:
    """Select the aggregate_loss Pallas lookup strategy: "gather" (per-lane
    jnp.take) or "onehot" (gather-free one-hot x ELT matmul on the MXU).
    Also settable via REPRO_AGG_VARIANT.  No effect on the jnp reference
    path, which is lookup-strategy-free."""
    if name not in AGG_VARIANTS:
        raise ValueError(f"variant {name!r}: must be one of {AGG_VARIANTS}")
    _STATE["agg_variant"] = name


def aggregate_variant() -> str:
    return _STATE["agg_variant"]


# ---------------------------------------------------------------------------
def ssd(x, dt, a_log_decay, B, C, chunk: int,
        initial_state: Optional[jax.Array] = None):
    """Chunked SSD scan; see kernels.ref.ssd_chunked_ref for the contract.

    Pads the sequence up to a chunk multiple (dt=0, a=0 pads are state-neutral:
    decay exp(0)=1 and zero input leave the recurrence unchanged)."""
    L = x.shape[1]
    pad = (-L) % chunk
    if pad:
        padL = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, a_log_decay, B, C = map(padL, (x, dt, a_log_decay, B, C))
        y, h = ssd(x, dt, a_log_decay, B, C, chunk, initial_state)
        return y[:, :L], h
    if _STATE["pallas"]:
        b, _, H, P = x.shape
        N = B.shape[-1]
        h0 = (initial_state if initial_state is not None
              else jnp.zeros((b, H, P, N), jnp.float32))
        fn = _ssd_pallas_vjp(chunk, _STATE["interpret"])
        return fn(x, dt, a_log_decay, B, C, h0)
    return _ref.ssd_chunked_ref(x, dt, a_log_decay, B, C, chunk,
                                initial_state=initial_state)


def ssd_decode_step(state, x_t, dt_t, a_t, B_t, C_t):
    return _ref.ssd_decode_step_ref(state, x_t, dt_t, a_t, B_t, C_t)


def aggregate_loss(event_ids, elt_losses, occ_ret, occ_lim, agg_ret, agg_lim,
                   chunk: int = 128, variant: Optional[str] = None):
    """Year-loss per trial (paper Algorithm 3).

    Pads the event axis to a chunk multiple with event id 0 — the pad event
    row of every ELT is zero by contract, so pads contribute no loss.
    ``variant`` overrides the configured Pallas lookup strategy (see
    :func:`use_aggregate_variant`); ignored on the jnp reference path."""
    K = event_ids.shape[1]
    chunk = min(chunk, K)
    pad = (-K) % chunk
    if pad:
        event_ids = jnp.pad(event_ids, ((0, 0), (0, pad)))
    if _STATE["pallas"]:
        from repro.kernels import aggregate_loss as _agg
        return _agg.aggregate_loss_pallas(
            event_ids, elt_losses, occ_ret, occ_lim, agg_ret, agg_lim,
            chunk=chunk, interpret=_STATE["interpret"],
            variant=variant or _STATE["agg_variant"])
    return _ref.aggregate_loss_chunked_ref(
        event_ids, elt_losses, occ_ret, occ_lim, agg_ret, agg_lim, chunk=chunk)
