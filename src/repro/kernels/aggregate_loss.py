"""Pallas TPU kernel for Aggregate Risk Analysis (paper Algorithm 3).

TPU adaptation of the paper's GPU kernel (DESIGN.md §6):

* The GPU version assigns one thread per trial and reads ELT direct-access
  tables from global memory with per-thread random access, using shared-memory
  "chunking" for the event axis.  TPUs have no per-lane random access to HBM,
  so the ELT tables are tiled into VMEM-resident catalog ranges and events
  gather from the resident tile (vector gather within VMEM).
* The paper's chunking maps to the event-axis grid dimension: each grid step
  processes a (trial_block x event_chunk) tile whose HBM->VMEM fetch is
  pipelined by Pallas against the previous tile's compute — the in-kernel
  mirror of the multi-tenant DMA/compute overlap.
* Grid = (catalog_tiles, trial_blocks, event_chunks), catalog outermost so
  each ELT tile is fetched once; the YLT block accumulates across catalog
  tiles and event chunks, and the layer aggregate terms apply on the last
  visit (revisiting-output accumulation).

Two lookup strategies over the same tiling (selectable via
``kernels.ops.use_aggregate_variant`` / the ``variant=`` kwarg):

* ``gather`` — per-lane ``jnp.take`` from the VMEM-resident ELT tile (the
  original port of the paper's per-thread global-memory reads).
* ``onehot`` — gather-free: local event ids expand to a one-hot matrix that
  multiplies the ELT tile (``(Tb*C, rows_tile) @ (rows_tile, M)``), trading
  the serial per-lane gather for an MXU matmul.  Out-of-tile ids map to the
  all-zero one-hot row, so no separate validity masking of the gathered
  losses is needed.

Both are validated in interpret mode against
kernels.ref.aggregate_loss_chunked_ref over shape sweeps
(tests/test_kernels_aggregate.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ops import AGG_VARIANTS as VARIANTS


def _accumulate(occ_ret_ref, occ_lim_ref, agg_ref, out_ref, g, *,
                r: int, j: int, n_cat: int, n_chunks: int):
    """Shared epilogue: occurrence terms, YLT accumulation, aggregate terms.

    ``g``: (Tb, C, M) losses gathered for this tile (zero where the event id
    falls outside the tile).  Assumes occ_ret >= 0, so zero-loss entries
    contribute nothing and an event's occurrence term is applied exactly once
    (in its owning catalog tile).
    """
    # occurrence terms per ELT:  min(max(l - OccR, 0), OccL)
    occ = jnp.clip(g - occ_ret_ref[...][None, None, :], 0.0, None)
    occ = jnp.minimum(occ, occ_lim_ref[...][None, None, :])
    out_ref[...] += occ.sum(axis=(1, 2))

    @pl.when((r == n_cat - 1) & (j == n_chunks - 1))
    def _agg():
        # layer aggregate terms:  min(max(l_T - AggR, 0), AggL)
        acc = out_ref[...]
        acc = jnp.clip(acc - agg_ref[0], 0.0, None)
        out_ref[...] = jnp.minimum(acc, agg_ref[1])


def _kernel(ids_ref, elt_ref, occ_ret_ref, occ_lim_ref, agg_ref, out_ref, *,
            rows_tile: int, n_cat: int, n_chunks: int):
    r = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when((r == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                                   # (Tb, C) int32
    base = r * rows_tile
    local = ids - base
    valid = (local >= 0) & (local < rows_tile)
    localc = jnp.clip(local, 0, rows_tile - 1)
    elt = elt_ref[...]                                   # (rows_tile, M)
    tb, c = ids.shape
    g = jnp.take(elt, localc.reshape(-1), axis=0)        # (Tb*C, M)
    g = g.reshape(tb, c, -1)
    g = jnp.where(valid[..., None], g, 0.0)
    _accumulate(occ_ret_ref, occ_lim_ref, agg_ref, out_ref, g,
                r=r, j=j, n_cat=n_cat, n_chunks=n_chunks)


def _kernel_onehot(ids_ref, elt_ref, occ_ret_ref, occ_lim_ref, agg_ref,
                   out_ref, *, rows_tile: int, n_cat: int, n_chunks: int):
    """Gather-free lookup: ids -> one-hot x ELT tile on the MXU.

    Each event id in the tile's catalog range becomes a one-hot row; ids
    outside the range (other tiles' events, clipped to -1) match no column
    and yield a zero row, replacing the gather path's explicit masking."""
    r = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when((r == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                                   # (Tb, C) int32
    base = r * rows_tile
    local = ids - base
    valid = (local >= 0) & (local < rows_tile)
    localv = jnp.where(valid, local, -1)
    tb, c = ids.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (tb * c, rows_tile), 1)
    onehot = (localv.reshape(-1, 1) == cols).astype(jnp.float32)
    g = jnp.dot(onehot, elt_ref[...],                    # (Tb*C, M) via MXU
                preferred_element_type=jnp.float32)
    g = g.reshape(tb, c, -1)
    _accumulate(occ_ret_ref, occ_lim_ref, agg_ref, out_ref, g,
                r=r, j=j, n_cat=n_cat, n_chunks=n_chunks)


_KERNELS = {"gather": _kernel, "onehot": _kernel_onehot}
assert set(_KERNELS) == set(VARIANTS), (
    "kernel table out of sync with kernels.ops.AGG_VARIANTS")


def aggregate_loss_pallas(event_ids, elt_losses, occ_ret, occ_lim, agg_ret,
                          agg_lim, *, chunk: int = 128,
                          trial_block: int = 256,
                          rows_tile: Optional[int] = None,
                          interpret: bool = True,
                          variant: str = "gather"):
    """Drop-in equivalent of kernels.ref.aggregate_loss_chunked_ref.

    ``variant``: "gather" (per-lane jnp.take) or "onehot" (gather-free
    one-hot x ELT-tile matmul on the MXU)."""
    if variant not in _KERNELS:
        raise ValueError(f"variant {variant!r}: must be one of {VARIANTS}")
    T, K = event_ids.shape
    rows, M = elt_losses.shape
    chunk = min(chunk, K)
    while K % chunk:
        chunk //= 2
    tb = min(trial_block, T)
    while T % tb:
        tb //= 2
    # ELT tile sized for ~8 MB of VMEM unless overridden
    if rows_tile is None:
        rows_tile = max(256, min(rows, (8 << 20) // max(4 * M, 1)))
    rows_tile = min(rows_tile, rows)
    n_cat = math.ceil(rows / rows_tile)
    rows_pad = n_cat * rows_tile
    if rows_pad != rows:
        elt_losses = jnp.pad(elt_losses, ((0, rows_pad - rows), (0, 0)))
    n_chunks = K // chunk
    agg = jnp.stack([jnp.asarray(agg_ret, jnp.float32),
                     jnp.asarray(agg_lim, jnp.float32)])

    kernel = functools.partial(_KERNELS[variant], rows_tile=rows_tile,
                               n_cat=n_cat, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(n_cat, T // tb, n_chunks),
        in_specs=[
            pl.BlockSpec((tb, chunk), lambda r, i, j: (i, j)),
            pl.BlockSpec((rows_tile, M), lambda r, i, j: (r, 0)),
            pl.BlockSpec((M,), lambda r, i, j: (0,)),
            pl.BlockSpec((M,), lambda r, i, j: (0,)),
            pl.BlockSpec((2,), lambda r, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tb,), lambda r, i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        interpret=interpret,
    )(event_ids.astype(jnp.int32), elt_losses.astype(jnp.float32),
      occ_ret.astype(jnp.float32), occ_lim.astype(jnp.float32), agg)
