"""Pallas TPU flash-attention forward (GQA, causal, sliding-window).

This is the TPU-target kernel behind the pure-JAX blockwise path in
models/attention_core.py (which serves as its HLO stand-in on CPU and as the
backward via custom-vjp recompute).  Classic FlashAttention-2 schedule:
grid = (B, Hq, q_blocks, kv_blocks) with the kv axis innermost/sequential;
online-softmax stats (m, l) and the output accumulator live in VMEM scratch
across kv steps; Pallas pipelines the next K/V tile's HBM->VMEM DMA against
the current tile's MXU compute — the same DMA/compute overlap the paper
obtains from multi-tenancy, here inside one kernel.

GQA: the K/V BlockSpec index maps query head h to kv head h // (Hq/Hkv), so
grouped heads share K/V tiles without materialising the repeat.

Validated in interpret mode against models.attention_core.naive_attention
(tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_kv: int, n_kv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = i * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
    ok = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev, l_prev, acc = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc = acc * alpha[:, None] + jnp.dot(p, v,
                                         preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc

    @pl.when(j == n_kv - 1)
    def _finalize():
        l_safe = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool = True):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    rep = Hq // Hkv
    bq = math.gcd(Sq, block_q)
    bk = math.gcd(Skv, block_kv)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / math.sqrt(D)

    qh = jnp.moveaxis(q, 2, 1)                       # (B, Hq, Sq, D)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, block_q=bq, block_kv=bk,
                               n_kv=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out, 1, 2)
