"""Fit the paper's perf/energy models from recorded telemetry.

The plane records per-tenant transfer and compute windows as spans
(``replay.*`` from simulator/bench replays, ``timeline.*`` from the
live scheduler — see `record_timeline`), each carrying an ``nv`` attr
(total virtual devices in the deployment the sample came from).  The
paper's model is linear in the observables:

* per-tenant transfer  ``t = a/nv + b`` with ``a = t_4gb * yet_mb/4000``
  (bandwidth-bound YET slice) and ``b = per_vdev_overhead`` (Eq 6);
* per-tenant compute  ``t = compute_time_1pdev / nv``       (Eq 5);
* mean device power  ``P = f*p_busy + (1-f)*p_idle_assigned`` for busy
  fraction ``f`` (the 4-state model of Eq 10 with assigned devices).

so least squares over the spans recovers ``PerfModelInputs`` and
``PowerParams`` directly.  ``power.sample`` events carry
``(busy_frac, watts)`` pairs — in a replay the watts column is
synthesised from the model (it stands in for an NVML/DCGM-style power
gauge on real hardware).

`plan_from_telemetry` in `core.planner` drives this end to end:
extract samples -> fit -> plan, picking the transfer mode by simulating
both under the fitted inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import energymodel as em
from repro.core import perfmodel as pm
from repro.core.simulator import SimInputs, SimResult, simulate
from repro.obs.telemetry import Telemetry


@dataclasses.dataclass(frozen=True)
class PhaseSample:
    """One tenant's observed (transfer, compute) at total-vdev count nv."""
    nv: int
    transfer_s: float
    compute_s: float


@dataclasses.dataclass(frozen=True)
class PerfFit:
    """A fitted `PerfModelInputs` plus residuals of the least squares."""
    m: pm.PerfModelInputs
    transfer_rms_s: float
    compute_rms_s: float
    n_samples: int


# -- recording ---------------------------------------------------------
def replay_sim_run(tel: Telemetry, si: SimInputs,
                   pw: Optional[em.PowerParams] = None,
                   base: Optional[float] = None,
                   power_bins: int = 32) -> SimResult:
    """Simulate ``si`` and re-express its schedule as spans on the plane.

    Each `TenantEvent` becomes a ``replay.transfer`` span with a child
    ``replay.compute`` span, tagged with the deployment's ``nv``.  When
    ``pw`` is given, ``power.sample`` events with (busy_frac, watts)
    are recorded too (watts synthesised from the 4-state model — the
    replay stand-in for a hardware power gauge).
    """
    res = simulate(si)
    nv = si.tenancy.n_vdev
    base = tel.now() if base is None else base
    for ev in res.events:
        common = dict(nv=nv, pdev=ev.pdev, vdev=ev.vdev, slot=ev.slot)
        pid = tel.record_span("replay.transfer", base + ev.transfer_start,
                              base + ev.transfer_end, **common)
        tel.record_span("replay.compute", base + ev.compute_start,
                        base + ev.compute_end, parent_id=pid, **common)
    if pw is not None:
        for frac, watts in power_samples(res, si.tenancy.n_pdev, pw,
                                         bins=power_bins):
            tel.event("power.sample", busy_frac=frac, watts=watts)
    return res


def power_samples(res: SimResult, n_pdev: int, pw: em.PowerParams,
                  bins: int = 32) -> List[Tuple[float, float]]:
    """(busy_frac, mean per-device watts) per time bin of a sim run."""
    out: List[Tuple[float, float]] = []
    edges = np.linspace(0.0, res.makespan, bins + 1)
    for lo, hi in zip(edges[:-1], edges[1:]):
        if hi <= lo:
            continue
        busy = sum(max(0.0, min(e.compute_end, hi) - max(e.compute_start, lo))
                   for e in res.events)
        frac = min(1.0, busy / (n_pdev * (hi - lo)))
        watts = frac * pw.p_busy + (1.0 - frac) * pw.p_idle_assigned
        out.append((frac, watts))
    return out


# -- extraction --------------------------------------------------------
def samples_from_telemetry(tel: Telemetry,
                           prefixes: Sequence[str] = ("replay", "timeline"),
                           ) -> List[PhaseSample]:
    """Pair ``<prefix>.transfer``/``.compute`` spans into `PhaseSample`s.

    Spans are grouped by (nv, pdev, vdev) and paired in start order, so
    a tenant that ran k rounds yields k samples.  Spans without an
    ``nv`` attr (live spans from a layer that doesn't know the
    deployment) are skipped.
    """
    samples: List[PhaseSample] = []
    for prefix in prefixes:
        tr: dict = {}
        cp: dict = {}
        for s in tel.spans(prefix=prefix + "."):
            nv = s.attrs.get("nv")
            if nv is None:
                continue
            key = (nv, s.attrs.get("pdev"), s.attrs.get("vdev"))
            if s.name.endswith(".transfer"):
                tr.setdefault(key, []).append(s)
            elif s.name.endswith(".compute"):
                cp.setdefault(key, []).append(s)
        for key, ts in tr.items():
            cs = cp.get(key, [])
            ts.sort(key=lambda s: (s.t_start, s.span_id))
            cs.sort(key=lambda s: (s.t_start, s.span_id))
            for a, b in zip(ts, cs):
                samples.append(PhaseSample(int(key[0]), a.duration,
                                           b.duration))
    return samples


def power_samples_from_telemetry(tel: Telemetry) -> List[Tuple[float, float]]:
    return [(float(s.attrs["busy_frac"]), float(s.attrs["watts"]))
            for s in tel.spans(name="power.sample")
            if "busy_frac" in s.attrs and "watts" in s.attrs]


# -- fitting -----------------------------------------------------------
def fit_perf_inputs(samples: Iterable[PhaseSample], *,
                    name: str = "fitted",
                    yet_mb: float = pm.YET_MB,
                    elt_mb: float = pm.ELT_MB,
                    pf_mb: float = pm.PF_MB,
                    context_mb: float = pm.CONTEXT_MB,
                    device_memory_mb: float = pm.K20_MEMORY_MB) -> PerfFit:
    """Least-squares fit of `PerfModelInputs` from phase samples.

    Transfer regresses on ``[1/nv, 1]`` giving the bandwidth-bound YET
    coefficient and the per-vdev overhead; compute regresses through
    the origin on ``1/nv``.  The recovered overhead cannot be split
    back into Table II's malloc/small/PF/ELT components, so it is
    carried whole in ``t_small`` (``per_vdev_overhead`` is what the
    model consumes).  Needs samples from >= 2 distinct nv.
    """
    samples = list(samples)
    nv = np.asarray([s.nv for s in samples], dtype=float)
    if len(np.unique(nv)) < 2:
        raise ValueError("fit_perf_inputs needs samples from >= 2 distinct"
                         f" deployments (got nv={sorted(set(nv))})")
    tr = np.asarray([s.transfer_s for s in samples], dtype=float)
    cp = np.asarray([s.compute_s for s in samples], dtype=float)

    a_tr = np.stack([1.0 / nv, np.ones_like(nv)], axis=1)
    (slope, intercept), *_ = np.linalg.lstsq(a_tr, tr, rcond=None)
    slope, intercept = max(float(slope), 0.0), max(float(intercept), 0.0)
    t_4gb = slope / (yet_mb / pm.YET_MB)
    tr_rms = float(np.sqrt(np.mean(
        (a_tr @ np.array([slope, intercept]) - tr) ** 2)))

    a_cp = (1.0 / nv)[:, None]
    (c1,), *_ = np.linalg.lstsq(a_cp, cp, rcond=None)
    c1 = max(float(c1), 0.0)
    cp_rms = float(np.sqrt(np.mean((c1 / nv - cp) ** 2)))

    net = pm.NetworkParams(name, t_malloc=0.0, t_small=intercept,
                           t_4mb=0.0, t_120mb=0.0, t_4gb=t_4gb)
    m = pm.PerfModelInputs(net, compute_time_1pdev=c1, yet_mb=yet_mb,
                           elt_mb=elt_mb, pf_mb=pf_mb,
                           context_mb=context_mb,
                           device_memory_mb=device_memory_mb)
    return PerfFit(m, tr_rms, cp_rms, len(samples))


def fit_power_params(samples: Sequence[Tuple[float, float]], *,
                     name: str = "fitted",
                     p_unassigned: float = 0.0) -> em.PowerParams:
    """Least-squares fit of the 2-free-state power model.

    ``watts = f*p_busy + (1-f)*p_idle_assigned`` — needs busy-fraction
    variation across samples.  ``p_unassigned`` is unobservable from an
    assigned device's samples and passes through.
    """
    if len(samples) < 2:
        raise ValueError("fit_power_params needs >= 2 samples")
    f = np.asarray([s[0] for s in samples], dtype=float)
    w = np.asarray([s[1] for s in samples], dtype=float)
    a = np.stack([f, 1.0 - f], axis=1)
    coef, _, rank, _ = np.linalg.lstsq(a, w, rcond=None)
    if rank < 2:
        raise ValueError("power samples have no busy-fraction variation;"
                         " cannot separate p_busy from p_idle_assigned")
    p_busy, p_idle = (float(coef[0]), float(coef[1]))
    return em.PowerParams(name, p_busy=p_busy, p_idle_assigned=p_idle,
                          p_unassigned=p_unassigned)
