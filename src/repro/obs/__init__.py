"""Unified telemetry plane: spans + metrics across the serving stack.

One process-global :class:`~repro.obs.telemetry.Telemetry` instance
(``repro.obs.TELEMETRY``, disabled by default) collects everything the
previously siloed stat surfaces recorded — ``TenantTimeline`` stamps,
engine trace counters, ``PagedKVCache`` page accounting, swap-store and
staging-lane logs, fault injections and heartbeat verdicts — as one
falsifiable schema that `obs.export` can dump (Chrome-trace/Perfetto
JSON, Prometheus text) and `obs.fit` can consume (least-squares fits of
``PerfModelInputs``/``PowerParams`` for ``planner.plan_from_telemetry``).

Naming scheme
=============

Every span and metric name is lowercase, dot-separated:
``<layer>.<noun>[.<detail>]``.  The first segment is the emitting layer
and doubles as the Chrome-trace category:

========== ==========================================================
prefix      layer
========== ==========================================================
``sched``   `serving.multitenant` — scheduler rounds, admission passes
``round``   `serving.continuous` — decode micro-round dispatch/collect
``admit``   `serving.continuous` — batched admission (plan/prefill)
``engine``  `serving.engine` — blocking/dispatch prefill + decode
``kv``      `serving.kvcache` — paged-pool page accounting
``swap``    `serving.swap` — host-tier swap store, per staging lane
``transfer`` `core.transfer` — staging-engine chunk windows
``fault``   `distributed.fault` — injected faults
``heartbeat`` `distributed.fault` — liveness verdicts
``shard``   `distributed.sharding` — per-mesh-shard placements
``trace``   jit compile (trace-time) events, any layer
``timeline`` ``TenantTimeline`` entries re-expressed as spans
``replay``  `obs.fit` — replayed simulator/bench runs
``power``   `obs.fit` — (busy_frac, watts) samples for the energy fit
``journal`` `serving.journal` — WAL appends/bytes (crash safety)
``recovery`` `serving` — checkpoint saves, journal replay, pool restore
========== ==========================================================

Kinds:

* **spans** — closed ``[t_start, t_end)`` intervals on one monotonic
  clock (`time.perf_counter`), with parent/child links from a
  per-thread span stack, e.g. ``sched.step`` > ``round.dispatch`` >
  ``round.cow``.  Retrospective spans (device windows stamped by
  handles, simulator replays) carry ``parent_id=None``.
* **events** — zero-length spans (``fault.round``, ``power.sample``).
* **counters** — monotonically increasing (``kv.pages_allocated``,
  ``trace.decode``, ``transfer.bytes``).  Unit suffixes where not
  obvious: ``*_bytes``, ``*_pages``, ``*_s``.
* **gauges** — last-write-wins (``heartbeat.suspects``,
  ``sched.backlog``).
* **histograms** — count/sum/min/max summaries (``round.steps_live``).

Cost contract: with the plane disabled (the default) every hook is one
attribute check — no span objects, no counter mutations, no
allocations (`tests/test_obs.py` pins this on the decode round path);
enabling it changes no numerics and no jit compile counts.
"""
from repro.obs.telemetry import (NULL_SPAN, Span, Telemetry, TELEMETRY,
                                 get_telemetry, record_timeline)

__all__ = ["NULL_SPAN", "Span", "Telemetry", "TELEMETRY", "get_telemetry",
           "record_timeline"]
