"""Exporters for the telemetry plane.

* :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome-trace JSON
  (the ``traceEvents`` array format), loadable by Perfetto and
  ``chrome://tracing``.  Spans become complete ("X") events with the
  layer prefix as category and parent/span ids in ``args``; counters
  are appended as a final snapshot of "C" events so the metrics are
  visible on the same timeline.
* :func:`prometheus_text` / :func:`write_metrics` — Prometheus text
  exposition of counters, gauges and histogram summaries (names
  sanitised to ``[a-z0-9_]``, ``repro_`` prefix);
  :func:`parse_prometheus_text` is the matching reader used by the
  round-trip tests.
* :func:`stats_line` — the compact one-line form the serving driver
  prints periodically.
"""
from __future__ import annotations

import json
import re
from typing import Any, Dict, Iterable, Optional

from repro.obs.telemetry import Telemetry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def chrome_trace(tel: Telemetry, *, pid: int = 0) -> Dict[str, Any]:
    """Render the plane as a Chrome-trace/Perfetto dict."""
    events = []
    spans = sorted(tel.spans(), key=lambda s: (s.t_start, s.span_id))
    for s in spans:
        args = {str(k): v for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        ev = {"name": s.name, "cat": s.name.split(".", 1)[0],
              "ph": "X", "ts": round(s.t_start * 1e6, 3),
              "dur": round(max(s.duration, 0.0) * 1e6, 3),
              "pid": pid, "tid": s.thread, "args": args}
        events.append(ev)
    t_last = max((s.t_end for s in spans), default=0.0)
    snap = tel.metric_snapshot()
    for name, value in sorted(snap["counters"].items()):
        events.append({"name": name, "cat": "counter", "ph": "C",
                       "ts": round(t_last * 1e6, 3), "pid": pid,
                       "tid": 0, "args": {"value": value}})
    for name, value in sorted(snap["gauges"].items()):
        events.append({"name": name, "cat": "gauge", "ph": "C",
                       "ts": round(t_last * 1e6, 3), "pid": pid,
                       "tid": 0, "args": {"value": value}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"spans_opened": tel.spans_opened,
                          "spans_dropped": tel.spans_dropped}}


def write_chrome_trace(tel: Telemetry, path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tel), f, indent=None,
                  separators=(",", ":"), default=str)


def prometheus_text(tel: Telemetry) -> str:
    """Prometheus text exposition of the plane's metrics."""
    snap = tel.metric_snapshot()
    lines = []
    for name, value in sorted(snap["counters"].items()):
        m = _metric_name(name)
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {value:g}")
    for name, value in sorted(snap["gauges"].items()):
        m = _metric_name(name)
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {value:g}")
    for name, h in sorted(snap["histograms"].items()):
        m = _metric_name(name)
        lines.append(f"# TYPE {m} summary")
        lines.append(f"{m}_count {h['count']:g}")
        lines.append(f"{m}_sum {h['sum']:g}")
        lines.append(f"{m}_min {h['min']:g}")
        lines.append(f"{m}_max {h['max']:g}")
    return "\n".join(lines) + "\n"


def write_metrics(tel: Telemetry, path: str) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(tel))


def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Parse the exposition format back to ``{name: value}``."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name, _, value = line.partition(" ")
        out[name] = float(value)
    return out


def stats_line(tel: Telemetry,
               keys: Optional[Iterable[str]] = None, **extra) -> str:
    """Compact ``k=v`` one-liner over counters+gauges for periodic logs.

    ``keys`` selects metric names (missing ones render as 0); ``extra``
    appends caller-computed fields verbatim.
    """
    snap = tel.metric_snapshot()
    merged = {**snap["counters"], **snap["gauges"]}
    if keys is None:
        keys = sorted(merged)
    parts = []
    for k in keys:
        v = merged.get(k, 0)
        parts.append(f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}")
    for k, v in extra.items():
        parts.append(f"{k}={v}")
    return "obs: " + " ".join(parts)
